"""Profile-store property tests: round-trip identity, schema-mismatch and
corrupt-file cold starts (never a crash), concurrent-writer last-wins
merge, generation monotonicity, surface persist/load with staleness + LOO
eviction, migration-cost calibration, and the RealExecutor's tuned-tile
generation key (zero stale-executable hits after a generation bump)."""

import json
import os

import numpy as np
import pytest

from repro.core.matrix_completion import SurfaceLibrary
from repro.perf.profile_store import (MIN_MIGRATION_SAMPLES, SCHEMA_VERSION,
                                      ProfileStore)

BS_GRID = (1, 2, 4, 8, 16, 32)
MAX_MTL = 8


def _lat_s(bs, mtl, base_ms=5.0):
    b_fac = 1.0 if bs <= 8 else 10.0
    m_fac = 1.0 + 10.0 * (mtl - 1)
    return base_ms * b_fac * m_fac / 1e3


def _fill(lib, key, base_ms=5.0):
    for b in BS_GRID:
        for m in range(1, MAX_MTL + 1):
            lib.observe(key, b, m, _lat_s(b, m, base_ms=base_ms))


# ---------------------------------------------------------------------------
# Document round trip + cold starts
# ---------------------------------------------------------------------------
def test_round_trip_identity(tmp_path):
    a = ProfileStore(str(tmp_path))
    a.put("autotune", "k1", {"config": {"block_q": 64}})
    a.put("migrations", "m1", {"samples": [0.1, 0.2]})
    a.bump_generation("autotune")
    a.save()

    b = ProfileStore(str(tmp_path))      # fresh instance = fresh process
    assert not b.cold_start or b.load()  # touch
    assert b.get("autotune", "k1") == {"config": {"block_q": 64}}
    assert b.get("migrations", "m1") == {"samples": [0.1, 0.2]}
    assert b.generation("autotune") == 1
    assert not b.cold_start


@pytest.mark.parametrize("content", [
    '{"schema": 999, "autotune": {"k": 1}}',     # future schema
    '{"autotune": {"k": 1}}',                    # missing schema
    "not json at all {{{",                       # corrupt
    '["schema", 1]',                             # wrong top-level type
])
def test_invalid_disk_state_is_clean_cold_start(tmp_path, content):
    store = ProfileStore(str(tmp_path))
    os.makedirs(store.root, exist_ok=True)
    with open(store.path, "w") as f:
        f.write(content)
    st = ProfileStore(str(tmp_path))
    assert st.section("autotune") == {}          # never a crash, never junk
    assert st.cold_start
    assert st.generation("autotune") == 0
    st.put("autotune", "fresh", {"v": 1})
    st.save()                                    # save rewrites cleanly
    doc = json.load(open(st.path))
    assert doc["schema"] == SCHEMA_VERSION
    assert doc["autotune"] == {"fresh": {"v": 1}}


def test_concurrent_writers_merge_last_wins(tmp_path):
    a = ProfileStore(str(tmp_path))
    b = ProfileStore(str(tmp_path))
    a.put("autotune", "only_a", 1)
    a.put("autotune", "shared", "A")
    b.put("autotune", "only_b", 2)
    b.put("autotune", "shared", "B")
    a.bump_generation("autotune")                # gen 1
    b.bump_generation("autotune")
    b.bump_generation("autotune")                # gen 2
    a.save()
    b.save()                                     # last writer

    c = ProfileStore(str(tmp_path))
    sec = c.section("autotune")
    assert sec["only_a"] == 1 and sec["only_b"] == 2   # both survived
    assert sec["shared"] == "B"                        # last wins
    assert c.generation("autotune") == 2               # max, never undone


def test_deleted_keys_stay_deleted_across_merge_save(tmp_path):
    a = ProfileStore(str(tmp_path))
    a.put("surfaces", "gone", {"x": 1})
    a.save()
    b = ProfileStore(str(tmp_path))
    b.delete("surfaces", "gone")
    b.save()                                     # merge must not resurrect
    assert ProfileStore(str(tmp_path)).get("surfaces", "gone") is None


# ---------------------------------------------------------------------------
# Surface rows: persist / load round trip, staleness + LOO eviction
# ---------------------------------------------------------------------------
def test_surface_row_round_trip_enables_prediction(tmp_path):
    store = ProfileStore(str(tmp_path))
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill(lib, "job-a")
    assert store.persist_surface(lib, "job-a", signature="net/data",
                                 device_class="gpu", autotune_generation=0)
    store.save()

    fresh = ProfileStore(str(tmp_path))          # fresh process
    lib2 = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    res = fresh.load_surfaces(lib2, device_class="gpu",
                              autotune_generation=0)
    assert res["loaded"] == ["net/data|gpu"] and not res["evicted"]
    # the reloaded history row makes a new sparse tenancy predictable
    for b, m in ((1, 1), (32, 1), (1, 8)):
        lib2.observe("new", b, m, _lat_s(b, m, base_ms=7.0))
    pred = lib2.predict("new")
    assert pred is not None
    est, support = pred
    assert support.all()
    truth = np.array([[_lat_s(b, m, base_ms=7.0)
                       for m in range(1, MAX_MTL + 1)] for b in BS_GRID])
    assert float(np.median(np.abs(est - truth) / truth)) < 0.15


def test_surface_persist_accumulates_same_generation(tmp_path):
    store = ProfileStore(str(tmp_path))
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill(lib, "j")
    store.persist_surface(lib, "j", signature="s", device_class="d",
                          autotune_generation=3)
    store.persist_surface(lib, "j", signature="s", device_class="d",
                          autotune_generation=3)
    rec = store.get("surfaces", "s|d")
    assert np.asarray(rec["cnt"]).max() == 2     # merged, not replaced
    # a different generation REPLACES instead of mixing stale samples in
    store.persist_surface(lib, "j", signature="s", device_class="d",
                          autotune_generation=4)
    rec = store.get("surfaces", "s|d")
    assert rec["autotune_generation"] == 4
    assert np.asarray(rec["cnt"]).max() == 1


def test_stale_generation_rows_evicted_on_load(tmp_path):
    store = ProfileStore(str(tmp_path))
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill(lib, "j")
    store.persist_surface(lib, "j", signature="s", device_class="d",
                          autotune_generation=0)
    # a SIMULATED row (tile_dependent=False): analytic latencies cannot
    # be invalidated by a re-tune, so the generation gate must skip it
    _fill(lib, "sim")
    store.persist_surface(lib, "sim", signature="sim", device_class="d",
                          autotune_generation=0, tile_dependent=False)
    store.save()

    fresh = ProfileStore(str(tmp_path))
    lib2 = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    # resident autotune generation moved on (a re-tune changed the tiles
    # under every measured latency): the row must be evicted, not used
    res = fresh.load_surfaces(lib2, device_class="d", autotune_generation=1,
                              validate=False)
    assert res["loaded"] == ["sim|d"] and res["evicted"] == ["s|d"]
    assert lib2.n_points(("hist", "s", "d")) == 0
    assert lib2.n_points(("hist", "sim", "d")) > 0
    assert fresh.get("surfaces", "s|d") is None  # gone from the store
    # ... and the eviction survived the save
    assert ProfileStore(str(tmp_path)).get("surfaces", "s|d") is None


def test_corrupt_surface_record_evicted_on_load(tmp_path):
    store = ProfileStore(str(tmp_path))
    store.put("surfaces", "bad|d", {"device_class": "d", "signature": "bad",
                                    "bs_values": [1], "mtl_values": [1],
                                    "sum": [[-1.0]], "cnt": [[1]],
                                    "autotune_generation": 0})
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    res = store.load_surfaces(lib, device_class="d", autotune_generation=0)
    assert res["evicted"] == ["bad|d"]


def test_loo_invalid_row_evicted_on_load(tmp_path):
    """A persisted row the completion machinery itself rejects (leave-one-
    out unrecoverable against the other loaded rows) is dropped from the
    store on load instead of poisoning every future run."""
    store = ProfileStore(str(tmp_path))
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill(lib, "good")
    # same shape at most points (passes the median similarity gate), but
    # two wild outliers that leave-one-out cannot recover
    _fill(lib, "broken")
    lib.observe("broken", 4, 2, 100 * _lat_s(4, 2))
    lib.observe("broken", 4, 2, 100 * _lat_s(4, 2))
    lib.observe("broken", 8, 3, 100 * _lat_s(8, 3))
    lib.observe("broken", 8, 3, 100 * _lat_s(8, 3))
    for key, sig in (("good", "good"), ("broken", "broken")):
        store.persist_surface(lib, key, signature=sig, device_class="d",
                              autotune_generation=0)
    store.save()

    fresh = ProfileStore(str(tmp_path))
    lib2 = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    res = fresh.load_surfaces(lib2, device_class="d", autotune_generation=0)
    assert "broken|d" in res["evicted"]
    assert fresh.get("surfaces", "broken|d") is None


# ---------------------------------------------------------------------------
# Migration calibration
# ---------------------------------------------------------------------------
def test_migration_calibration_percentiles(tmp_path):
    store = ProfileStore(str(tmp_path))
    key = "net/data|gpu"
    assert store.migration_cost(key) is None     # nothing measured yet
    for s in (0.10, 0.12, float("nan"), -5.0):
        store.record_migration(key, s)
    # junk (nan / negative) never lands; below min samples -> still None
    assert store.migration_cost(key) is None
    store.record_migration(key, 0.30)
    samples = [0.10, 0.12, 0.30]
    assert len(samples) == MIN_MIGRATION_SAMPLES
    got = store.migration_cost(key, q=0.5)
    assert got == pytest.approx(np.quantile(samples, 0.5))
    assert store.migration_cost(key, q=0.9) <= 0.30 + 1e-12


def test_migration_samples_ring_buffer(tmp_path):
    store = ProfileStore(str(tmp_path))
    for i in range(200):
        store.record_migration("k", 0.001 * (i + 1))
    rec = store.get("migrations", "k")
    assert len(rec["samples"]) == 64             # capped
    assert rec["samples"][-1] == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# Tuned-tile generation keys the AOT executable cache
# ---------------------------------------------------------------------------
def _tiny_executor(**kw):
    import jax
    import jax.numpy as jnp
    from repro.serving.executor import RealExecutor
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 16))

    def fn(params, batch):
        return jnp.tanh(batch["x"] @ params).sum()

    def make_batch(n):
        return {"x": jnp.ones((n, 16), jnp.float32)}

    return RealExecutor(fn, w, make_batch, **kw)


def test_generation_bump_evicts_stale_executables(tmp_path):
    store = ProfileStore(str(tmp_path))
    ex = _tiny_executor(
        tile_generation=lambda: store.generation("autotune"))
    points = [(1, 1), (4, 1), (16, 2)]
    for bs, mtl in points:
        ex.run_step(bs, mtl)
    ex.cache_stats.reset_counters()
    for bs, mtl in points:                       # warm: pure hits
        ex.run_step(bs, mtl)
    assert ex.cache_stats.misses == 0
    assert ex.cache_stats.stale_evictions == 0

    store.bump_generation("autotune")            # a new tuning landed
    ex.cache_stats.reset_counters()
    for bs, mtl in points:
        res = ex.run_step(bs, mtl)
        assert res["compile_time"] > 0.0         # recompiled, not served
    # every resident executable was stale: evicted and recompiled, and
    # NOT ONE stale executable was served
    assert ex.cache_stats.stale_evictions == len(points)
    assert ex.cache_stats.misses == len(points)
    assert ex.cache_stats.stale_hits == 0

    ex.cache_stats.reset_counters()
    for bs, mtl in points:                       # new generation now warm
        ex.run_step(bs, mtl)
    assert ex.cache_stats.misses == 0
    assert ex.cache_stats.stale_hits == 0


def test_autotune_tune_bumps_resident_generation(tmp_path):
    """End to end: a real `autotune.tune` call moves `generation()`, which
    is the default tile_generation the RealExecutor keys on."""
    from repro.perf import autotune
    prev = autotune._state["cache_dir"]      # restore the PRIOR state —
    #        pinning the default would disable a REPRO_AUTOTUNE_CACHE env
    #        override for the rest of the pytest process
    autotune.configure(cache_dir=str(tmp_path), tune_on_miss=False,
                       enabled=True)
    try:
        assert autotune.generation() == 0
        ex = _tiny_executor()                    # default: follows autotune
        ex.run_step(2, 1)
        assert ex.cache_stats.stale_evictions == 0
        autotune.tune("ssd_scan", "float32", iters=1, P=16, N=16, T=64)
        assert autotune.generation() == 1
        ex.cache_stats.reset_counters()
        ex.run_step(2, 1)                        # same point: recompile
        assert ex.cache_stats.stale_evictions == 1
        assert ex.cache_stats.misses == 1
        assert ex.cache_stats.stale_hits == 0
    finally:
        autotune._state["cache_dir"] = prev
        autotune._state["legacy_checked"] = None
        autotune.configure(tune_on_miss=False, enabled=True)
        autotune.reset_counters()


# ---------------------------------------------------------------------------
# The headline acceptance: a second process is strictly cheaper
# ---------------------------------------------------------------------------
def test_second_process_warm_start_strictly_cheaper(tmp_path):
    """Cold run then warm run against the same on-disk store (fresh
    objects everywhere = fresh process): the warm run must reach steady
    state in strictly fewer probes, compile strictly fewer buckets, and
    pay strictly lower compile-stall seconds."""
    from examples.warm_start import serve_once
    cold = serve_once(str(tmp_path))
    warm = serve_once(str(tmp_path))
    assert cold["loaded_rows"] == 0
    assert warm["loaded_rows"] == 1              # the persisted row arrived
    assert warm["probes"] < cold["probes"]
    assert warm["compiles"] < cold["compiles"]
    assert warm["compile_stall_s"] < cold["compile_stall_s"]
