"""SurfaceLibrary tests: soft_impute recovery RMSE on masked entries,
similarity/LOO gating, support masking, and the headline property — a
soft-impute-seeded HybridScaler converges to the same (bs, mtl) as a
fully-probed one in strictly fewer probes."""

import numpy as np
import pytest

from repro.core.controller import DNNScalerController
from repro.core.matrix_completion import SurfaceLibrary, soft_impute

BS_GRID = (1, 2, 4, 8, 16, 32, 64, 128)
MAX_MTL = 10


# ---------------------------------------------------------------------------
# soft_impute: direct RMSE bound on masked entries of a low-rank matrix
# ---------------------------------------------------------------------------
def test_soft_impute_rmse_bound_on_masked_entries():
    rng = np.random.default_rng(0)
    n, m, rank = 24, 16, 2
    M = rng.uniform(0.5, 1.5, (n, rank)) @ rng.uniform(0.5, 1.5, (rank, m))
    mask = rng.random((n, m)) > 0.3          # 30% of entries hidden
    filled = soft_impute(M, mask, rank=rank)
    hidden = ~mask
    assert hidden.sum() > 50                  # the bound means something
    rel_rmse = float(np.sqrt(np.mean(
        ((filled[hidden] - M[hidden]) / M[hidden]) ** 2)))
    assert rel_rmse < 0.10
    # observed entries are reproduced exactly (hard data constraint)
    assert np.allclose(filled[mask], M[mask])


# ---------------------------------------------------------------------------
# Synthetic low-rank latency family: lat(b, m) = base * f(b) * g(m).
# A cliff past b=24 makes the SLO-feasible frontier sharp, so seeded and
# unseeded searches converge to the SAME point and the probe counts are
# comparable apples to apples.
# ---------------------------------------------------------------------------
SLO_S = 0.020


def _lat_s(bs, mtl, base_ms=5.0):
    b_fac = 1.0 if bs <= 24 else 10.0
    m_fac = 1.0 + 10.0 * (mtl - 1)
    return base_ms * b_fac * m_fac / 1e3


class _SurfaceExecutor:
    """Deterministic executor serving the synthetic surface."""

    def run_step(self, bs, mtl):
        lat = _lat_s(bs, mtl)
        items = bs * mtl
        return {"step_time": lat, "items": items,
                "request_latencies": np.full(min(items, 64), lat),
                "power_w": 100.0, "throughput": items / lat}


def _fill_library_row(lib, key):
    for b in BS_GRID:
        for m in range(1, MAX_MTL + 1):
            lib.observe(key, b, m, _lat_s(b, m, base_ms=7.0))


def test_predict_recovers_low_rank_surface_with_support():
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill_library_row(lib, "historic")
    # the target observed only the profiler's three points
    for b, m in ((1, 1), (32, 1), (1, 8)):
        lib.observe("new", b, m, _lat_s(b, m))
    pred = lib.predict("new")
    assert pred is not None
    est, support = pred
    assert support.all()          # the historic row covers the whole grid
    truth = np.array([[_lat_s(b, m) for m in range(1, MAX_MTL + 1)]
                      for b in BS_GRID])
    rel = np.abs(est - truth) / truth
    assert float(np.median(rel)) < 0.15


def test_predict_refuses_dissimilar_history():
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill_library_row(lib, "historic")
    # a target whose scaling shape contradicts the library: batching is
    # FREE for it (flat latency), while the library says x10 past b=24
    for b, m in ((1, 1), (32, 1), (1, 8)):
        lib.observe("alien", b, m, 0.005)
    assert lib.predict("alien") is None


def test_predict_requires_base_point_and_history():
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    lib.observe("solo", 1, 1, 0.005)
    lib.observe("solo", 32, 1, 0.05)
    assert lib.predict("solo") is None        # no other rows at all
    _fill_library_row(lib, "historic")
    lib2 = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill_library_row(lib2, "historic")
    lib2.observe("nobase", 32, 1, 0.05)       # missing the (1,1) normalizer
    assert lib2.predict("nobase") is None


def test_reset_row_drops_stale_share_points():
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill_library_row(lib, "j")
    assert lib.n_points("j") > 0
    lib.reset_row("j")
    assert lib.n_points("j") == 0


def test_off_grid_points_are_dropped():
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    lib.observe("j", 3, 1, 0.005)             # bs=3 not on the grid
    lib.observe("j", 1, 11, 0.005)            # mtl beyond the grid
    lib.observe("j", 1, 1, float("inf"))      # junk latency
    assert lib.n_points("j") == 0


# ---------------------------------------------------------------------------
# The headline: seeded converges to the same point in strictly fewer probes
# ---------------------------------------------------------------------------
def _drive(ctrl, steps=400):
    """Serve the synthetic surface; returns (visited points, last actions)."""
    ex = _SurfaceExecutor()
    visited, last = [], []
    for _ in range(steps):
        act = ctrl.action()
        res = ex.run_step(act.bs, act.mtl)
        visited.append((act.bs, act.mtl))
        last.append((act.bs, act.mtl))
        ctrl.observe(res["step_time"], res)
    return visited, last[-100:]


def _steady(last):
    vals, counts = np.unique(np.array(last), axis=0, return_counts=True)
    return tuple(vals[int(np.argmax(counts))])


def test_seeded_scaler_converges_same_point_in_fewer_probes():
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    _fill_library_row(lib, "historic")

    seeded = DNNScalerController(_SurfaceExecutor(), SLO_S, mode="hybrid",
                                 surface_library=lib, surface_key="new")
    assert seeded._surface is not None        # the completion fired
    # the matrix-completion jump starts at the predicted steady point,
    # not at (1, 1)
    jump = seeded.action()
    assert (jump.bs, jump.mtl) != (1, 1)

    unseeded = DNNScalerController(_SurfaceExecutor(), SLO_S, mode="hybrid")
    assert unseeded._surface is None          # no analytic floor either

    v_seed, last_seed = _drive(seeded)
    v_full, last_full = _drive(unseeded)
    assert _steady(last_seed) == _steady(last_full)
    probes_seed = len(set(v_seed))
    probes_full = len(set(v_full))
    assert probes_seed < probes_full


def test_unknown_share_rung_rejects_with_distinct_reason():
    """Satellite bugfix: an off-grid share rung used to return None with
    a STALE `last_reject` left over from some earlier refusal — callers
    could not tell a bad rung from a cold library.  Now it reports the
    distinct "share" reason, and a valid rung still slices."""
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL,
                         share_values=(0.5, 1.0))
    for share in (0.5, 1.0):
        for b in BS_GRID:
            for m in range(1, MAX_MTL + 1):
                lib.observe("historic", b, m,
                            _lat_s(b, m, base_ms=7.0) / share, share=share)
    for b, m in ((1, 1), (32, 1), (1, 8)):
        lib.observe("new", b, m, _lat_s(b, m), share=1.0)

    # valid rung: the library answers with the (bs, mtl) slice
    pred = lib.predict("new", share=1.0)
    assert pred is not None and lib.last_tier == "library"
    est, support = pred
    assert est.shape == (len(BS_GRID), MAX_MTL)

    # off-grid rung: refused with the DISTINCT reason, not a stale one
    assert lib.predict("new", share=0.33) is None
    assert lib.last_reject == "share"
    assert lib.last_tier is None

    # and a later full-tensor predict is unaffected by the rejection
    assert lib.predict("new") is not None
