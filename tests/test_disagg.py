"""Disaggregated prefill/decode subsystem tests: the interconnect model's
analytic transfer times, KV-transfer fabric pricing/accounting, prefill
pool routing, request conservation through the disagg engine (including
mid-transfer pool revocation), chunked-prefill conservation, and the
HybridScaler's pool-ratio axis."""

import math

import pytest

from repro.configs.base import get_config
from repro.core.scaler import HybridScaler
from repro.serving import device_model as dm
from repro.serving.disagg import (KVTransferFabric, PrefillPool, fabric_for,
                                  place_disagg_fleet, run_disagg_cluster,
                                  run_disagg_serving)
from repro.serving.token_engine import ragged_decode_trace, run_token_serving
from repro.serving.workload import long_prefill_trace

CFG = get_config("gemma2-2b")
PROF = dm.llm_profile(CFG, mode="decode", kv_seq_budget=1024)


def _conserved(rep):
    assert rep["submitted"] == (rep["completed"] + rep["rejected"]
                                + rep["backlog"]), rep
    assert rep["conserved"]


# ---------------------------------------------------------------------------
# interconnect model: analytic latency floor + bytes/bandwidth
# ---------------------------------------------------------------------------
def test_interconnect_transfer_time_is_latency_plus_bandwidth():
    ic = dm.interconnect_for("tpu-v5e")
    for nbytes in (0.0, 1e6, 218e6, 4e9):
        assert ic.transfer_s(nbytes) == pytest.approx(
            ic.latency_s + nbytes / ic.bw_bps, rel=1e-15)
    # more bytes never transfer faster
    assert ic.transfer_s(2e9) > ic.transfer_s(1e9)


def test_dcn_reuses_checkpoint_transfer_bandwidth():
    from repro.serving.cluster import CKPT_TRANSFER_BPS
    dcn = dm.interconnect_for("unknown-device-class")
    assert dcn.bw_bps == pytest.approx(CKPT_TRANSFER_BPS)
    # the cross-pod fallback is strictly slower than the in-pod fabrics
    ici = dm.interconnect_for("tpu-v5e")
    assert dcn.bw_bps < ici.bw_bps and dcn.latency_s > ici.latency_s


# ---------------------------------------------------------------------------
# fabric pricing: kv_bytes_per_item x prefill_len over the interconnect,
# exact vs the analytic formula (rtol 1e-12)
# ---------------------------------------------------------------------------
def test_fabric_prices_kv_handoff_analytically():
    fab = fabric_for(PROF, kv_seq_budget=1024)
    per_tok = PROF.kv_bytes_per_item / 1024
    assert fab.kv_bytes_per_token == pytest.approx(per_tok, rel=1e-15)
    for tokens in (1, 512, 1024, 4096):
        want = fab.interconnect.transfer_s(per_tok * tokens)
        assert fab.transfer_s(tokens) == pytest.approx(want, rel=1e-12)


def test_fabric_charge_accounting_matches_analytic_sums():
    trace = ragged_decode_trace(40, 0, rate_rps=50.0, prefill_mean=512)
    rep = run_disagg_serving(PROF, seed=0, trace=trace, n_prefill=2,
                             kv_seq_budget=1024)
    _conserved(rep)
    fab = fabric_for(PROF, kv_seq_budget=1024)
    busy = sum(fab.transfer_s(r.prefill_tokens) for r in trace)
    nbytes = sum(fab.kv_bytes_per_token * r.prefill_tokens for r in trace)
    got = rep["fabric"]
    assert got["transfers"] == len(trace)
    assert got["busy_s"] == pytest.approx(busy, rel=1e-12)
    assert got["bytes_moved"] == pytest.approx(nbytes, rel=1e-12)


# ---------------------------------------------------------------------------
# pool routing and placement
# ---------------------------------------------------------------------------
def test_pool_routes_to_least_loaded_member():
    pool = PrefillPool(PROF, n_members=2, kv_seq_budget=1024, seed=0)
    m0, done0 = pool.assign(0.0, 2048)
    m1, done1 = pool.assign(0.0, 2048)
    assert {m0, m1} == {0, 1}          # second prefill avoids the busy one
    # third lands on whichever frees first
    m2, _ = pool.assign(0.0, 512)
    assert m2 == (m0 if done0 <= done1 else m1)
    # prefill time scales with prompt length (per-token pricing)
    long = pool.assign(100.0, 2048)[1] - 100.0
    short = pool.assign(200.0, 256)[1] - 200.0
    assert long > short


def test_place_disagg_fleet_tail_convention():
    from repro.serving.cluster import gpu_fleet
    fleet = gpu_fleet(5)
    pre, dec = place_disagg_fleet(fleet, 2)
    assert [s.name for s in pre] == [s.name for s in fleet[-2:]]
    assert [s.name for s in dec] == [s.name for s in fleet[:-2]]
    with pytest.raises(ValueError):
        place_disagg_fleet(fleet, 5)   # nothing left to decode


# ---------------------------------------------------------------------------
# conservation: normal exit, bounded queue, mid-transfer revocation
# ---------------------------------------------------------------------------
def test_disagg_conserves_requests():
    trace = ragged_decode_trace(60, 0, rate_rps=40.0, prefill_mean=512)
    rep = run_disagg_serving(PROF, seed=0, trace=trace, n_prefill=2,
                             kv_seq_budget=1024)
    _conserved(rep)
    assert rep["completed"] == 60 and rep["rejected"] == 0
    assert rep["in_transfer"] == 0     # folded into backlog, drained here


def test_disagg_bounded_queue_rejects_and_conserves():
    trace = ragged_decode_trace(80, 0, rate_rps=500.0, prefill_mean=512)
    rep = run_disagg_serving(PROF, seed=0, trace=trace, n_prefill=1,
                             kv_seq_budget=1024, max_slots=4, max_queue=4)
    _conserved(rep)
    assert rep["rejected"] > 0


def test_mid_transfer_revocation_conserves_into_rejected():
    # long prompts at a burst rate keep several prefills/transfers in
    # flight on each member when the revocation lands
    trace = ragged_decode_trace(60, 0, rate_rps=100.0, prefill_mean=2048)
    base = run_disagg_serving(PROF, seed=0, trace=trace, n_prefill=2,
                              kv_seq_budget=1024)
    rep = run_disagg_serving(PROF, seed=0, trace=trace, n_prefill=2,
                             kv_seq_budget=1024, revoke=(0.3, 1))
    _conserved(rep)
    assert rep["pool"]["dead"] == [1]
    assert rep["rejected"] > 0
    assert rep["completed"] + rep["rejected"] == base["completed"]
    # the survivor keeps serving: the run still finishes every request
    assert rep["backlog"] == 0


def test_disagg_cluster_aggregates_conserve():
    profs = [PROF, dm.llm_profile(get_config("gemma2-2b"), mode="decode",
                                  kv_seq_budget=2048)]
    rep = run_disagg_cluster(profs, seed=0, n_requests=40, rate_rps=40.0,
                             prefill_mean=512, n_prefill=2,
                             kv_seq_budget=1024)
    assert rep["conserved"]
    assert rep["submitted"] == sum(j["submitted"] for j in rep["jobs"])


# ---------------------------------------------------------------------------
# chunked prefill: conservation + per-token pricing beats the monolithic
# padded prefill for prompts far below the serving context
# ---------------------------------------------------------------------------
def test_chunked_prefill_conserves():
    trace = ragged_decode_trace(60, 0, rate_rps=40.0, prefill_mean=512)
    rep = run_token_serving(PROF, policy="continuous", seed=0, trace=trace,
                            prefill_mode="chunked", chunk_tokens=256)
    _conserved(rep)
    assert rep["completed"] == 60


def test_chunked_ttft_beats_padded_cotenant_on_short_prompts():
    prof4k = dm.llm_profile(CFG, mode="decode", kv_seq_budget=4096)
    trace = long_prefill_trace(60, 0, rate_rps=4.0, prefill_mean=2048)
    slo = 0.9 * prof4k.prefill_ms / 1e3   # under the monolithic prefill
    reps = {m: run_token_serving(prof4k, policy="continuous", seed=0,
                                 trace=trace, prefill_mode=m,
                                 chunk_tokens=512, ttft_slo_s=slo,
                                 tpot_slo_s=0.05)
            for m in ("chunked", "cotenant")}
    for rep in reps.values():
        _conserved(rep)
    # co-tenant pays prefill_ms at the FULL kv budget for every prompt,
    # chunked pays per actual token — mean prompts are half the context
    assert reps["cotenant"]["ttft_attainment"] == 0.0
    assert reps["chunked"]["ttft_attainment"] >= 0.9


# ---------------------------------------------------------------------------
# HybridScaler pool-ratio axis: demand-capped like `share`, grows under
# prefill-wait pressure, releases idle rungs
# ---------------------------------------------------------------------------
def _scaler(**kw):
    return HybridScaler(0.05, pool_ladder=(0.25, 0.5, 1.0), **kw)


def test_pool_ratio_starts_top_and_releases_past_demand():
    sc = _scaler()
    assert sc.pool_ratio == 1.0        # boot: full pool, like share
    sc.note_pool_demand(0.3)           # demand caps at the 0.5 rung
    assert sc.observe_pool(0.0, ttft_slo_s=1.0)    # release one rung
    assert sc.pool_ratio == 0.5
    assert not sc.observe_pool(0.0, ttft_slo_s=1.0) or sc.pool_ratio == 0.25


def test_pool_ratio_grows_under_prefill_wait_pressure():
    sc = _scaler()
    sc.note_pool_demand(0.2)           # trough: cap at the bottom rung
    while sc.observe_pool(0.0, ttft_slo_s=1.0):
        pass
    low = sc.pool_ratio
    assert low == 0.25
    sc.note_pool_demand(0.9)           # demand returns: cap lifts
    # no growth on pressure-free windows even with headroom...
    assert not sc.observe_pool(0.1, ttft_slo_s=1.0)
    # ...but p95 prefill+transfer wait over half the TTFT budget grows
    assert sc.observe_pool(0.9, ttft_slo_s=1.0)
    assert sc.pool_ratio > low


def test_pool_ratio_growth_is_demand_capped():
    sc = _scaler()
    sc.note_pool_demand(0.3)           # cap at the 0.5 rung
    while sc.observe_pool(0.0, ttft_slo_s=1.0):
        pass
    for _ in range(5):
        sc.observe_pool(10.0, ttft_slo_s=1.0)
    assert sc.pool_ratio <= 0.5        # pressure never overruns demand


def test_pool_ratio_none_without_ladder():
    sc = HybridScaler(0.05)
    assert sc.pool_ratio is None


# ---------------------------------------------------------------------------
# pool-ratio axis end to end: the controller drives active members
# ---------------------------------------------------------------------------
def test_controller_pool_axis_keeps_attainment_and_conserves():
    trace = long_prefill_trace(80, 0, rate_rps=12.0, prefill_mean=2048)
    prof = dm.llm_profile(CFG, mode="decode", kv_seq_budget=2048)
    rep = run_disagg_serving(prof, seed=0, trace=trace, n_prefill=3,
                             kv_seq_budget=2048, max_slots=16,
                             ttft_slo_s=1.2, tpot_slo_s=0.05,
                             use_controller=True, pool_ladder=(1, 2, 3))
    _conserved(rep)
    assert rep["ttft_attainment"] >= 0.9
    assert 1 <= rep["pool"]["active"] <= 3


# ---------------------------------------------------------------------------
# pool energy: idle floor over the makespan plus dynamic over busy time
# ---------------------------------------------------------------------------
def test_pool_energy_decomposes():
    pool = PrefillPool(PROF, n_members=2, kv_seq_budget=1024, seed=0,
                       device=dm.TPU_V5E)
    pool.assign(0.0, 1024)
    makespan = 10.0
    e = pool.energy_j(makespan)
    dev = dm.TPU_V5E
    busy = sum(pool.busy_s)
    lo = dev.idle_w * makespan          # one member used: idle floor only
    #                                     on it, plus its dynamic draw
    assert e == pytest.approx(lo + (dev.peak_w - dev.idle_w) * busy,
                              rel=1e-9)
    assert math.isfinite(e) and e > 0.0
