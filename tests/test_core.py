"""Unit + property tests for the paper's core: Profiler, Scalers, matrix
completion, Clipper (hypothesis for the invariants)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.clipper import ClipperController
from repro.core.matrix_completion import LatencyEstimator, soft_impute
from repro.core.profiler import Profiler
from repro.core.scaler import ALPHA, BatchScaler, MTScaler
from repro.serving import device_model as dm
from repro.serving.executor import SimExecutor


# ---------------------------------------------------------------------------
# Matrix completion
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(5, 9), st.integers(6, 10), st.randoms(use_true_random=False))
def test_soft_impute_recovers_low_rank(n_rows, n_cols, rnd):
    """Rank-1 structure (the MTL-curve setting: rows are scaled copies) is
    recoverable from one missing entry per row."""
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    u = np.abs(rng.normal(size=(n_rows, 1))) + 0.5
    v = np.abs(rng.normal(size=(1, n_cols))) + 0.5
    M = u @ v
    mask = np.ones(M.shape, bool)
    for i in range(n_rows - 1):
        mask[i, rng.integers(1, n_cols)] = False  # hide one entry per row
    filled = soft_impute(M, mask, rank=1, lam=0.01)
    err = np.abs(filled - M)[~mask] / M[~mask]
    assert np.median(err) < 0.25  # relative error on missing entries


def test_latency_estimator_monotone_curves():
    """Library of increasing curves + 2 observations -> sensible estimates."""
    est = LatencyEstimator(max_mtl=10)
    for slope in (0.3, 0.5, 0.9, 1.2):
        est.add_library_row({m: 10.0 * (1 + slope * (m - 1)) for m in range(1, 11)})
    observed = {1: 8.0, 8: 8.0 * (1 + 0.7 * 7)}
    curve = est.estimate(observed)
    assert curve[0] == pytest.approx(8.0, rel=0.15)
    assert curve[7] == pytest.approx(observed[8], rel=0.25)
    assert np.all(np.diff(curve) > -1.0)  # roughly increasing


def test_latency_estimator_pick_mtl_respects_slo():
    est = LatencyEstimator(max_mtl=10)
    for slope in (0.4, 0.8):
        est.add_library_row({m: 5.0 * (1 + slope * (m - 1)) for m in range(1, 11)})
    observed = {1: 0.010, 8: 0.045}  # ~linear growth
    mtl, curve = est.pick_mtl(observed, slo_s=0.030)
    assert 1 <= mtl <= 10
    assert curve[mtl - 1] < 0.030
    if mtl < 10:
        assert curve[mtl] >= 0.030 or mtl == 10


# ---------------------------------------------------------------------------
# BatchScaler: Algorithm 1 binary search
# ---------------------------------------------------------------------------
class FakeLatency:
    """Deterministic monotone latency(BS) environment."""

    def __init__(self, per_item_ms: float, fixed_ms: float = 0.0):
        self.per_item = per_item_ms
        self.fixed = fixed_ms

    def p95(self, bs: int) -> float:
        return (self.fixed + self.per_item * bs) / 1e3


def run_batch_scaler(env, slo_s, steps=200, max_bs=128):
    sc = BatchScaler(slo_s, max_bs=max_bs, decision_interval=1)
    for _ in range(steps):
        act = sc.action()
        sc.observe(env.p95(act.bs))
    return sc


@settings(max_examples=40, deadline=None)
@given(st.floats(0.05, 4.0), st.floats(5.0, 400.0))
def test_batch_scaler_converges_and_feasible(per_item_ms, slo_ms):
    env = FakeLatency(per_item_ms)
    sc = run_batch_scaler(env, slo_ms / 1e3)
    bs = sc.action().bs
    assert 1 <= bs <= 128
    # final point must satisfy the SLO unless even BS=1 violates it
    if env.p95(1) <= slo_ms / 1e3:
        assert env.p95(bs) <= slo_ms / 1e3 * 1.001
        # and be near-maximal: bs+jump would exceed alpha band or the cap
        ideal = min(int((slo_ms / per_item_ms)), 128)
        assert bs >= max(1, int(ideal * ALPHA) - 1)
    else:
        assert sc.infeasible or bs == 1


def test_batch_scaler_hysteresis_band_stops_changes():
    env = FakeLatency(1.0)          # latency = bs ms
    sc = run_batch_scaler(env, 0.100)  # SLO 100ms -> ideal bs ~100
    bs_trace = []
    for _ in range(20):
        act = sc.action()
        bs_trace.append(act.bs)
        sc.observe(env.p95(act.bs))
    assert len(set(bs_trace)) == 1  # converged, no oscillation


def test_batch_scaler_readjusts_on_slo_change():
    env = FakeLatency(1.0)
    sc = run_batch_scaler(env, 0.100)
    bs_before = sc.action().bs
    sc.set_slo(0.030)               # user tightens the SLO (paper §4.5)
    for _ in range(100):
        act = sc.action()
        sc.observe(env.p95(act.bs))
    bs_after = sc.action().bs
    assert env.p95(bs_after) <= 0.030
    assert bs_after < bs_before


# ---------------------------------------------------------------------------
# MTScaler: AIMD invariants
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.floats(1.0, 20.0), st.floats(10.0, 300.0), st.integers(1, 10))
def test_mt_scaler_aimd_bounds_and_slo(per_inst_ms, slo_ms, start_guess):
    est = LatencyEstimator(max_mtl=10)

    class _FixedEst:
        def pick_mtl(self, observed, slo):
            return start_guess, np.zeros(10)

    sc = MTScaler(slo_ms / 1e3, _FixedEst(), {1: per_inst_ms / 1e3},
                  decision_interval=1)
    env = lambda m: per_inst_ms * m / 1e3   # linear latency in MTL
    for _ in range(100):
        act = sc.action()
        assert 1 <= act.mtl <= 10           # invariant: bounds respected
        sc.observe(env(act.mtl))
    final = sc.action().mtl
    if env(1) <= slo_ms / 1e3:
        assert env(final) <= slo_ms / 1e3 * 1.001
        ideal = min(int(slo_ms / per_inst_ms), 10)
        assert final >= max(1, ideal - 1)   # near-maximal
    else:
        assert final == 1


# ---------------------------------------------------------------------------
# Clipper AIMD
# ---------------------------------------------------------------------------
def test_clipper_additive_increase_multiplicative_decrease():
    c = ClipperController(slo_s=0.050, decision_interval=1)
    c.observe(0.010)
    assert c.bs == 5                 # +4
    c.observe(0.010)
    assert c.bs == 9
    c.observe(0.100)                 # violation -> -10%
    assert c.bs == 8                 # int(9 * 0.9)
    for _ in range(100):
        c.observe(0.001)
    assert c.bs == 128               # capped


# ---------------------------------------------------------------------------
# Profiler decisions on the calibrated simulator
# ---------------------------------------------------------------------------
def test_profiler_prefers_mt_for_small_and_b_for_large():
    small = dm.paper_profile("mobilenet_v1_05", "imagenet")
    large = dm.paper_profile("inception_v4", "imagenet")
    r_small = Profiler(SimExecutor(small, seed=0), probe_steps=5).probe()
    r_large = Profiler(SimExecutor(large, seed=0), probe_steps=5).probe()
    assert r_small.approach == "MT"
    assert r_large.approach == "B"


def test_profiler_agreement_with_paper_table4():
    """>= 28/30 of the paper's Table-4 decisions (the one structural
    disagreement, job 23, is documented in EXPERIMENTS.md)."""
    from repro.serving.workload import PAPER_JOBS
    agree = 0
    for j in PAPER_JOBS:
        res = Profiler(SimExecutor(j.profile(), seed=j.job_id),
                       probe_steps=5).probe()
        agree += res.approach == j.paper_method
    assert agree >= 28, agree


def test_matrix_completion_heldout_accuracy():
    """Fig 4 mechanism: two profiled points + a job library recover the full
    latency(MTL) curve to within ~20% on held-out jobs."""
    from repro.serving.workload import PAPER_JOBS
    est = LatencyEstimator(max_mtl=10)
    for j in PAPER_JOBS[:10]:
        p = j.profile()
        est.add_library_row({m: dm.mt_latency(dm.TESLA_P40, p, 1, m)
                             for m in range(1, 11)})
    errs = []
    for j in PAPER_JOBS[10:]:
        p = j.profile()
        truth = np.array([dm.mt_latency(dm.TESLA_P40, p, 1, m)
                          for m in range(1, 11)])
        pred = est.estimate({1: truth[0], 8: truth[7]})
        errs.append(float(np.mean(np.abs(pred - truth) / truth)))
    assert np.mean(errs) < 0.30, np.mean(errs)
