"""Autotune subsystem + AOT executor tests: cache round-trip (no re-timing),
roofline pruning keeps the measured best, ops fallback with an empty cache,
zero recompiles after RealExecutor warmup, vectorized pricing equivalence,
tail-window equivalence, and HybridScaler surface seeding."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf import autotune
from repro.serving import device_model as dm


@pytest.fixture
def tuner(tmp_path):
    """Point the autotuner at a fresh cache dir; restore defaults after."""
    autotune.configure(cache_dir=str(tmp_path), tune_on_miss=False,
                       enabled=True)
    autotune.reset_counters()
    yield autotune
    autotune.configure(cache_dir=autotune.DEFAULT_CACHE_DIR,
                       tune_on_miss=False, enabled=True)
    autotune.reset_counters()


# Small shape classes so the searches stay test-fast.
SEEDED = [
    ("flash_attention", dict(G=2, hd=32, Tq=128, Tk=128, causal=True)),
    ("decode_attention", dict(G=2, hd=32, S=256)),
    ("ssd_scan", dict(P=32, N=32, T=128)),
]


# ---------------------------------------------------------------------------
# Cache round-trip: the second call comes from disk, no re-timing.
# ---------------------------------------------------------------------------
def test_cache_round_trip_no_retiming(tuner):
    kernel, dims = SEEDED[0]
    e1 = tuner.tune(kernel, "float32", iters=2, **dims)
    stats = tuner.cache_stats()
    assert stats["tunes"] == 1 and stats["timings"] > 0
    n_timed = stats["timings"]

    e2 = tuner.tune(kernel, "float32", iters=2, **dims)   # in-memory hit
    assert e2["config"] == e1["config"]
    assert tuner.cache_stats()["timings"] == n_timed

    # drop the in-memory mirror: the entry must come back from DISK
    tuner.configure(cache_dir=tuner.cache_dir())
    e3 = tuner.tune(kernel, "float32", iters=2, **dims)
    assert e3["config"] == e1["config"]
    assert tuner.cache_stats()["timings"] == n_timed      # still no re-timing
    # entries live in the schema-versioned profile store, and the tuning
    # bumped the tuned-tile generation exactly once
    with open(tuner.cache_path()) as f:
        disk = json.load(f)
    from repro.perf import profile_store
    assert disk["schema"] == profile_store.SCHEMA_VERSION
    assert len(disk["autotune"]) == 1
    assert disk["generations"]["autotune"] == 1
    assert tuner.generation() == 1


# ---------------------------------------------------------------------------
# Pruning never discards the measured-best config on the seeded shapes.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kernel,dims", SEEDED, ids=lambda x: str(x)[:24])
def test_pruning_keeps_measured_best(tuner, kernel, dims):
    """Pruning must not discard meaningfully better configs.  On these tiny
    CPU-interpret shapes candidate timings differ by less than OS jitter,
    so the 'measured best' config itself is nondeterministic — assert the
    noise-robust property instead: the best config SURVIVING pruning times
    within a small factor of the global measured best."""
    full = tuner.tune(kernel, "float32", force=True, prune=False,
                      iters=3, **dims)
    cls = tuner.shape_class(kernel, **dims)
    kept = tuner.prune_candidates(kernel, cls, "float32")
    timed = {k: v for k, v in full["candidates_timed"].items()}
    best_all = min(timed.values())
    best_kept = min(timed[json.dumps(c, sort_keys=True)] for c in kept)
    assert best_kept <= 1.5 * best_all, (kept, timed)
    assert len(kept) <= len(timed)      # pruning is allowed to prune


def test_pruning_always_keeps_default():
    for kernel, dims in SEEDED:
        cls = autotune.shape_class(kernel, **dims)
        kept = autotune.prune_candidates(kernel, cls, "float32", ratio=1.0)
        cands_fn, _ = autotune._KERNELS[kernel]
        if any(c == autotune.DEFAULTS[kernel] for c in cands_fn(cls)):
            assert autotune.DEFAULTS[kernel] in kept


# ---------------------------------------------------------------------------
# ops default lookup: graceful fallback with an empty cache.
# ---------------------------------------------------------------------------
def test_ops_fallback_empty_cache(tuner):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.ssd_scan.ops import ssd_scan

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out_default = flash_attention(q, k, v, causal=True)        # miss -> 128s
    out_explicit = flash_attention(q, k, v, causal=True,
                                   block_q=128, block_k=128)
    np.testing.assert_array_equal(np.asarray(out_default),
                                  np.asarray(out_explicit))

    q1 = jax.random.normal(ks[0], (2, 4, 32))
    kc = jax.random.normal(ks[1], (2, 256, 2, 32))
    vc = jax.random.normal(ks[2], (2, 256, 2, 32))
    pos = jnp.asarray(200, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(decode_attention(q1, kc, vc, pos)),
        np.asarray(decode_attention(q1, kc, vc, pos, block_k=256)))

    x = jax.random.normal(ks[0], (1, 128, 2, 32)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.5)
    Bm = jax.random.normal(ks[3], (1, 128, 16)) * 0.5
    Cm = jax.random.normal(ks[4], (1, 128, 16)) * 0.5
    y0, s0 = ssd_scan(x, dt, A, Bm, Cm)
    y1, s1 = ssd_scan(x, dt, A, Bm, Cm, chunk=128)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))

    stats = tuner.cache_stats()
    assert stats["misses"] > 0          # lookups happened and missed
    assert stats["tunes"] == 0          # ...without tuning (tune_on_miss off)


def test_tuned_config_is_used_by_ops(tuner):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention import ops as fops
    kernel, dims = SEEDED[0]
    entry = tuner.tune(kernel, "float32", iters=1, **dims)
    calls = []
    orig = fops._flash_attention

    def spy(*a, **kw):
        calls.append((kw["block_q"], kw["block_k"]))
        return orig(*a, **kw)

    fops._flash_attention = spy
    try:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 32))
        k = jax.random.normal(ks[1], (1, 128, 1, 32))
        v = jax.random.normal(ks[2], (1, 128, 1, 32))
        flash_attention(q, k, v, causal=True)
    finally:
        fops._flash_attention = orig
    cfg = entry["config"]
    assert calls == [(cfg["block_q"], cfg["block_k"])]


# ---------------------------------------------------------------------------
# RealExecutor AOT: bucketing -> zero recompiles after warmup; compile time
# charged to the engine clock; memory-aware fits.
# ---------------------------------------------------------------------------
def _tiny_executor(**kw):
    from repro.serving.executor import RealExecutor
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 16))

    def fn(params, batch):
        return jnp.tanh(batch["x"] @ params).sum()

    def make_batch(n):
        return {"x": jnp.ones((n, 16), jnp.float32)}

    return RealExecutor(fn, w, make_batch, **kw)


def test_zero_recompiles_after_warmup():
    ex = _tiny_executor()
    probe_points = [(1, 1), (2, 1), (3, 1), (4, 2), (16, 1), (5, 3), (32, 1)]
    for bs, mtl in probe_points:              # warmup: compiles happen here
        ex.run_step(bs, mtl)
    assert ex.cache_stats.misses > 0
    ex.cache_stats.reset_counters()
    for bs, mtl in probe_points * 3:          # steady state: all cache hits
        res = ex.run_step(bs, mtl)
        assert res["compile_time"] == 0.0
    assert ex.cache_stats.misses == 0
    assert ex.cache_stats.hits == len(probe_points) * 3


def test_bucketing_shares_executables():
    ex = _tiny_executor()
    ex.run_step(5, 1)                         # bucket 8
    ex.run_step(7, 1)                         # same bucket -> no compile
    ex.run_step(2, 4)                         # bs*mtl = 8 -> same bucket
    assert ex.cache_stats.misses == 1
    assert ex.cache_stats.hits == 2


def test_compile_time_charged_to_engine_clock():
    from repro.core.controller import StaticController
    from repro.serving.engine import ServingEngine
    ex = _tiny_executor()
    eng = ServingEngine(ex, slo_s=1.0)
    acc = eng.run(StaticController(bs=4, mtl=1), max_steps=5)
    assert acc.compile_stall_s > 0.0          # first step compiled
    assert acc.total_time >= acc.compile_stall_s
    assert acc.summary()["compile_stall_s"] == acc.compile_stall_s


def test_donate_batch_path_runs():
    ex = _tiny_executor(donate_batch=True)
    r1 = ex.run_step(4, 1)
    r2 = ex.run_step(4, 1)
    assert r1["items"] == r2["items"] == 4
    assert r2["compile_time"] == 0.0


def test_fits_memory_aware():
    ex = _tiny_executor()
    assert ex.fits(64, 64) and not ex.fits(4097, 1)     # legacy default
    exm = _tiny_executor(mem_bytes=1e6, act_bytes_per_item=1e4)
    assert exm.fits(1, 1)
    assert not exm.fits(50, 4)                # 200 items * 1e4 B > 1 MB
    # budget big enough for everything the legacy rule rejected
    exl = _tiny_executor(mem_bytes=1e12, act_bytes_per_item=1.0)
    assert exl.fits(4097, 2)


# ---------------------------------------------------------------------------
# Vectorized pricing == scalar pricing; fast tail window == np.quantile.
# ---------------------------------------------------------------------------
def test_fit_profile_matches_model_thr_scan():
    """The vectorized `_fit_profile` must stay bit-equivalent to the
    sequential `_model_thr` scan it replaced — any drift between the
    inlined fit algebra and the pricing formulas skews every
    paper_profile-derived benchmark silently."""
    for dnn, dataset in list(dm.TABLE5)[:4]:
        t = np.array(dm.TABLE5[(dnn, dataset)])
        base_ms = 1e3 / t[0]
        flops = dm.NET_SPECS[dnn][1] * 1e9
        best, best_err = None, np.inf
        for host_frac in np.linspace(0.05, 0.95, 46):
            host = base_ms * host_frac
            gpu1 = base_ms - host
            for amort in np.linspace(0.0, 0.95, 39):
                m = np.array(dm._model_thr(host, gpu1, amort, flops,
                                           dm.TESLA_P40))
                err = np.sum(np.log(m / t) ** 2)
                if err < best_err:
                    best, best_err = (host, gpu1, amort), err
        got = dm._fit_profile(dnn, dataset)
        assert got == pytest.approx(best, rel=1e-12), (dnn, dataset)


def test_grid_pricing_matches_scalar():
    prof = dm.paper_profile("inception_v1", "imagenet")
    bs = np.array([1, 2, 7, 32, 128])
    mtls = np.arange(1, 11)
    grid = dm.mt_latency_grid(dm.TESLA_P40, prof, bs, mtls)
    for i, b in enumerate(bs):
        for j, m in enumerate(mtls):
            assert grid[i, j] == pytest.approx(
                dm.mt_latency(dm.TESLA_P40, prof, int(b), int(m)), rel=1e-12)
    bl = dm.batch_latency_grid(dm.TESLA_P40, prof, bs)
    for i, b in enumerate(bs):
        assert bl[i] == pytest.approx(
            dm.batch_latency(dm.TESLA_P40, prof, int(b)), rel=1e-12)


def test_price_surface_matches_mean_latency():
    from repro.serving.executor import SimExecutor
    prof = dm.paper_profile("resnet_v2_50", "imagenet")
    for mesh in (None, (4, 4)):
        ex = SimExecutor(prof, device=dm.TPU_V5E if mesh else dm.TESLA_P40,
                         mesh_shape=mesh)
        bs, mtls = np.array([1, 4, 16]), np.arange(1, 6)
        surf = ex.price_surface(bs, mtls)
        for i, b in enumerate(bs):
            for j, m in enumerate(mtls):
                assert surf[i, j] == pytest.approx(
                    ex.mean_latency(int(b), int(m)), rel=1e-12)


def test_tail_window_matches_np_quantile():
    from repro.serving.metrics import TailLatencyWindow
    rng = np.random.default_rng(0)
    win = TailLatencyWindow(window=50)
    ref: list = []
    for _ in range(30):
        chunk = rng.exponential(1.0, size=rng.integers(1, 40))
        win.add_many(chunk)
        ref.extend(chunk.tolist())
        expect = float(np.quantile(np.asarray(ref[-50:]), 0.95))
        assert win.p95 == pytest.approx(expect, rel=1e-12)
        assert win.mean == pytest.approx(float(np.mean(ref[-50:])), rel=1e-12)
    win.reset()
    assert win.p95 == 0.0 and len(win) == 0


# ---------------------------------------------------------------------------
# HybridScaler surface seeding: model-infeasible frontier pinned up front.
# ---------------------------------------------------------------------------
def test_seed_surface_pins_infeasible_frontier():
    from repro.core.scaler import HybridScaler
    sc = HybridScaler(0.1, max_bs=8, max_mtl=4, decision_interval=1)
    bs_vals = np.arange(1, 9)
    mtl_vals = np.arange(1, 5)
    # latency = bs * mtl * 20ms: infeasible once bs*mtl > 5
    lat = bs_vals[:, None] * mtl_vals[None, :] * 0.02
    pins = sc.seed_surface(bs_vals, mtl_vals, lat)
    assert pins > 0
    assert sc.is_pinned(6, 1) and sc.is_pinned(8, 4)    # deep infeasible
    assert sc.is_pinned(3, 2)                            # just past frontier
    assert not sc.is_pinned(5, 1) and not sc.is_pinned(2, 2)  # feasible
    assert sc._hi <= 5                                   # BS ceiling at mtl=1


def test_hybrid_controller_seeds_from_sim_surface():
    from repro.core.controller import DNNScalerController
    from repro.serving.executor import SimExecutor
    from repro.serving.workload import PAPER_JOBS
    job = PAPER_JOBS[0]
    ctrl = DNNScalerController(SimExecutor(job.profile(), seed=1),
                               job.slo_s, mode="hybrid")
    assert ctrl._surface is not None
    # the scaler must know at least one model-infeasible point up front
    assert len(ctrl.scaler._dom_counts) > 0
    # and a changed SLO re-derives the frontier instead of losing it
    ctrl.set_slo(job.slo_s * 0.5)
    assert len(ctrl.scaler._dom_counts) > 0


# ---------------------------------------------------------------------------
# models/layers.py defers its blockwise-attention tile sizes to the cache
# (ROADMAP autotune follow-up: explicit kwargs win, empty cache falls back).
# ---------------------------------------------------------------------------
def test_model_flash_attention_defers_blocks_to_cache(monkeypatch):
    import jax
    from repro.models import layers
    from repro.perf import autotune as at

    calls = []

    def fake_lookup(kernel, dtype, **dims):
        calls.append((kernel, dims))
        return {"block_q": 64, "block_k": 64}

    monkeypatch.setattr(at, "lookup", fake_lookup)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 96, 4, 16))
    k = jax.random.normal(ks[1], (1, 96, 2, 16))
    v = jax.random.normal(ks[2], (1, 96, 2, 16))
    out_cached = layers.flash_attention(q, k, v)
    assert calls and calls[0][0] == "flash_attention"
    assert calls[0][1]["Tq"] == 96 and calls[0][1]["G"] == 2
    out_explicit = layers.flash_attention(q, k, v, block_q=64, block_k=64)
    assert len(calls) == 1        # explicit kwargs never consult the cache
    np.testing.assert_allclose(np.asarray(out_cached),
                               np.asarray(out_explicit),
                               rtol=2e-5, atol=2e-5)
    # empty cache: the historical 256/512 defaults
    monkeypatch.setattr(at, "lookup", lambda *a, **kw: None)
    out_default = layers.flash_attention(q, k, v)
    out_legacy = layers.flash_attention(q, k, v, block_q=256, block_k=512)
    np.testing.assert_allclose(np.asarray(out_default),
                               np.asarray(out_legacy), rtol=1e-6)
