"""Tests for the HLO static analyzer (trip-count-aware roofline terms)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.perf.hlo_analysis import analyze_hlo, parse_module
from repro.perf.roofline import Roofline


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    n_layers, d = 7, 64

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    text = _compile_text(f, jax.ShapeDtypeStruct((n_layers, d, d), jnp.float32),
                         jax.ShapeDtypeStruct((4, d), jnp.float32))
    r = analyze_hlo(text)
    expect = 2 * 4 * d * d * n_layers
    assert r["flops"] == pytest.approx(expect, rel=0.01)


def test_unrolled_matches_scanned_flops():
    d = 32

    def scanned(w, x):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    def unrolled(w, x):
        for i in range(5):
            x = x @ w[i]
        return x.sum()

    t1 = _compile_text(scanned, jax.ShapeDtypeStruct((5, d, d), jnp.float32),
                       jax.ShapeDtypeStruct((4, d), jnp.float32))
    t2 = _compile_text(unrolled, jax.ShapeDtypeStruct((5, d, d), jnp.float32),
                       jax.ShapeDtypeStruct((4, d), jnp.float32))
    r1, r2 = analyze_hlo(t1), analyze_hlo(t2)
    assert r1["flops"] == pytest.approx(r2["flops"], rel=0.01)


def test_dynamic_slice_counts_slice_not_buffer():
    big = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)  # 4 MB

    def f(buf, i):
        s = jax.lax.dynamic_slice(buf, (i, 0), (8, 1024))  # 32 KB slice
        return s.sum()

    text = _compile_text(f, big, jax.ShapeDtypeStruct((), jnp.int32))
    r = analyze_hlo(text)
    assert r["hbm_bytes"] < 1e6  # far below the 4 MB buffer


def test_collective_parse_on_synthetic_hlo():
    text = """
HloModule m

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128]{1,0} parameter(0)
  %ag = f32[8,512]{1,0} all-gather(%p0), replica_groups={}, dimensions={1}
  %slice.1 = f32[8,128]{1,0} slice(%ag), slice={[0:8],[0:128]}
  ROOT %ar = f32[8,128]{1,0} all-reduce(%slice.1), to_apply=%add
}
"""
    r = analyze_hlo(text, f32_as_bf16=False)
    assert r["coll_count"]["all-gather"] == 1
    assert r["coll_count"]["all-reduce"] == 1
    assert r["coll_bytes"]["all-gather"] == pytest.approx(8 * 512 * 4)
    assert r["coll_bytes"]["all-reduce"] == pytest.approx(2 * 8 * 128 * 4)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 64), st.integers(4, 64), st.integers(4, 64))
def test_dot_flops_formula(m, n, k):
    def f(a, b):
        return a @ b

    text = _compile_text(f, jax.ShapeDtypeStruct((m, k), jnp.float32),
                         jax.ShapeDtypeStruct((k, n), jnp.float32))
    r = analyze_hlo(text)
    assert r["flops"] == pytest.approx(2 * m * n * k, rel=0.01)


def test_roofline_terms_and_dominance():
    rl = Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=0, chips=256,
                  model_flops=197e12 * 256 * 0.5)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(2.0)
    assert rl.dominant == "memory"
    assert rl.useful_flops_ratio == pytest.approx(0.5)


def test_parse_module_entry_detection():
    text = """
HloModule m

%helper (a: f32[2]) -> f32[2] {
  %a = f32[2]{0} parameter(0)
  ROOT %t = f32[2]{0} tanh(%a)
}

ENTRY %main (p: f32[2]) -> f32[2] {
  %p = f32[2]{0} parameter(0)
  ROOT %c = f32[2]{0} call(%p), to_apply=%helper
}
"""
    comps, entry = parse_module(text)
    assert entry == "main"
    assert set(comps) == {"helper", "main"}


def test_hardened_parser_warns_on_odd_shapes_and_stays_finite():
    """Regression for the parser-hardening sweep: unknown dtypes, bounded
    and unbounded dynamic dims, and degenerate 0-element shapes must each
    produce a conservative estimate plus a `warnings` entry — never a
    crash, a negative count, or a silent garbage number."""
    text = """
HloModule m

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128]{1,0} parameter(0)
  %a = myquant4[8,128]{1,0} add(%p0, %p0)
  %b = f32[<=16,128]{1,0} abs(%a)
  %c = f32[?,128]{1,0} negate(%b)
  %d = f32[8,0]{1,0} exponential(%c)
  ROOT %e = f32[8,128]{1,0} tanh(%d)
}
"""
    r = analyze_hlo(text, f32_as_bf16=False)
    warns = "\n".join(r["warnings"])
    assert "unknown dtype 'myquant4'" in warns        # -> 4-byte fallback
    assert "dynamic dim '<=16'" in warns              # -> counted at bound
    assert "unbounded dynamic dim '?'" in warns       # -> counted as 1
    assert "degenerate 0-element shape" in warns
    assert np.isfinite(r["flops"]) and r["flops"] >= 0
    assert np.isfinite(r["hbm_bytes"]) and r["hbm_bytes"] > 0
    # the unknown-dtype add is byte-counted at the 4-byte fallback:
    # 2 reads + 1 write of 8x128
    assert r["hbm_bytes"] >= 3 * 8 * 128 * 4


def test_clean_module_reports_no_warnings():
    def f(a, b):
        return jnp.tanh(a @ b)

    text = _compile_text(f, jax.ShapeDtypeStruct((16, 32), jnp.float32),
                         jax.ShapeDtypeStruct((32, 8), jnp.float32))
    r = analyze_hlo(text)
    assert r["warnings"] == []
    assert r["n_ops"] > 0
    assert abs(sum(r["op_hist"].values()) - 1.0) < 1e-9
    assert r["op_hist"]["dense"] > 0


def test_warnings_reset_between_analyses():
    bad = """
HloModule m

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %a = qq8[4]{0} add(%p0, %p0)
}
"""
    assert analyze_hlo(bad, f32_as_bf16=False)["warnings"] != []
    clean = """
HloModule m

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %a = f32[4]{0} add(%p0, %p0)
}
"""
    assert analyze_hlo(clean, f32_as_bf16=False)["warnings"] == []


def test_op_class_histogram_buckets():
    text = """
HloModule m

ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %w = f32[8,8]{1,0} parameter(1)
  %d = f32[4,8]{1,0} dot(%p0, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %t = f32[8,4]{1,0} transpose(%d), dimensions={1,0}
  %t2 = f32[4,8]{1,0} transpose(%t), dimensions={1,0}
  ROOT %a = f32[4,8]{1,0} add(%t2, %d)
}
"""
    r = analyze_hlo(text, f32_as_bf16=False)
    assert r["n_ops"] == 4
    assert r["op_hist"]["dense"] == pytest.approx(0.25)
    assert r["op_hist"]["reshuffle"] == pytest.approx(0.5)
    assert r["op_hist"]["elementwise"] == pytest.approx(0.25)
    assert r["op_hist"]["conv"] == 0.0
