"""Spatial-partition subsystem tests: plan legality (share/memory sums,
MIG grid, submesh divisibility), the pricing calibration (uniform spatial
shares == the paper's MTL curves BIT-identically), the HybridScaler's
third (share) axis (bounds, throughput-guarded share moves, SLO held at
convergence, violation escape through share-up), the (bs, mtl, share)
SurfaceLibrary tensor, and the ClusterEngine partition mode
(resize-instead-of-migrate, headroom mediation, conservation)."""

import dataclasses

import numpy as np
import pytest

from repro.core.scaler import HybridScaler
from repro.serving import device_model as dm
from repro.serving import partition as pt
from repro.serving import tenancy
from repro.serving.cluster import ClusterEngine, gpu_fleet, \
    run_partition_cluster
from repro.serving.executor import SimExecutor
from repro.serving.workload import ChurnJob, PAPER_JOBS, \
    mixed_partition_trace

DEV = dm.TESLA_P40
PROF = dm.paper_profile("inception_v1")


# ---------------------------------------------------------------------------
# PartitionPlan legality
# ---------------------------------------------------------------------------
def test_mps_plan_legality():
    assert pt.mps_plan([0.5, 0.25, 0.25]).validate() == []
    errs = pt.mps_plan([0.75, 0.5]).validate()
    assert any("sum" in e for e in errs)
    assert pt.mps_plan([0.5, -0.1]).validate() != []
    # memory slices are checked independently of compute shares
    errs = pt.mps_plan([0.5, 0.25], mem_fractions=[0.9, 0.9]).validate()
    assert any("memory" in e for e in errs)


def test_mig_plan_snaps_to_profile_grid():
    plan = pt.mig_plan([0.5, 0.3, 0.15])
    assert plan.validate() == []
    # 0.5 -> 3g (3/7), 0.3 -> 2g, 0.15 -> 1g
    assert [round(s.share * 7) for s in plan.slices] == [3, 2, 1]
    # hand-built off-grid share is flagged
    bad = pt.PartitionPlan(kind="mig", slices=(
        pt.TenantSlice(share=0.33, tenants=1, isolation=1.0),))
    assert any("MIG" in e for e in bad.validate())


def test_mig_plan_rejects_illegal_combination():
    with pytest.raises(ValueError):
        pt.mig_plan([1.0, 1.0])          # two 7g slices cannot coexist


def test_submesh_plan_wraps_tenancy_plan():
    tp = tenancy.plan((4, 4), 4)
    plan = pt.from_tenancy(tp)
    assert plan.kind == "submesh" and plan.tenants == 4
    assert plan.validate() == []
    assert all(s.isolation == 1.0 for s in plan.slices)
    assert plan.total_share == pytest.approx(1.0)
    # a share that is not a whole-chip submesh is illegal
    bad = pt.PartitionPlan(kind="submesh", slices=(
        pt.TenantSlice(share=0.3, tenants=1, isolation=1.0),),
        mesh_shape=(4, 4))
    assert bad.validate() != []


def test_memory_slices_fit_check():
    plan = pt.mps_plan([0.5, 0.5])
    profs = [PROF, PROF]
    assert plan.fits_memory(DEV, profs, [(1, 1), (1, 1)])
    # a tiny memory slice cannot hold a big batch
    tiny = pt.mps_plan([0.5, 0.5], mem_fractions=[0.99, 0.01])
    assert not tiny.fits_memory(DEV, profs, [(1, 1), (128, 4)])


def test_share_ladders_and_snap():
    assert pt.share_ladder("mps") == tuple((k + 1) / 8 for k in range(8))
    assert all(any(abs(r - c) < 1e-9 for c, _ in pt.MIG_PROFILES)
               for r in pt.share_ladder("mig"))
    assert pt.snap("mps", 0.8) == pytest.approx(0.75)
    assert pt.snap("mig", 0.5) == pytest.approx(3 / 7)
    assert pt.snap("mps", 0.01) == pytest.approx(0.125)  # floor rung


def test_mig_split_for_instances_is_heterogeneous():
    sl = pt.TenantSlice(share=1.0, inv_share=1.0, tenants=1, isolation=1.0)
    subs = pt.split_for_instances(sl, 3, kind="mig")
    assert len(subs) == 3
    assert sorted(round(s.share * 7) for s in subs) == [2, 2, 3]
    # the synchronized step is gated by the smallest sub-slice
    lat = pt.part_instances_latency(DEV, PROF, 4, subs)
    worst = max(dm.part_latency(DEV, PROF, 4, 1, inv_share=s.inv_share,
                                tenants=s.tenants, isolation=1.0)
                for s in subs)
    assert lat == pytest.approx(worst)


# ---------------------------------------------------------------------------
# Pricing calibration: uniform spatial shares == MTL curves, bit for bit
# ---------------------------------------------------------------------------
def test_uniform_partition_pricing_is_bit_identical_to_mtl():
    bs = np.array([1, 2, 4, 8, 16, 32, 64, 128])
    for prof in (PROF, dm.paper_profile("mobilenet_v1_05", "caltech"),
                 dm.paper_profile("textclassif", "sentiment140")):
        for m in range(1, 11):
            part = dm.part_latency_grid(DEV, prof, bs, [1],
                                        inv_share=float(m), tenants=m)
            mt = dm.mt_latency_grid(DEV, prof, bs, [m])
            assert np.array_equal(part, mt), (prof.name, m)


def test_sole_tenant_partition_equals_mt_grid():
    bs = np.array([1, 4, 32])
    mtls = list(range(1, 11))
    part = dm.part_latency_grid(DEV, PROF, bs, mtls)
    mt = dm.mt_latency_grid(DEV, PROF, bs, mtls)
    assert np.array_equal(part, mt)


def test_isolation_removes_cross_tenant_interference():
    shared = dm.part_latency(DEV, PROF, 8, 1, inv_share=2.0, tenants=2,
                             isolation=0.0)
    isolated = dm.part_latency(DEV, PROF, 8, 1, inv_share=2.0, tenants=2,
                               isolation=1.0)
    assert isolated < shared            # MIG/submesh drops the eps/chi terms
    # a bigger slice is never slower
    big = dm.part_latency(DEV, PROF, 8, 1, inv_share=1.0 / 0.75, tenants=2)
    small = dm.part_latency(DEV, PROF, 8, 1, inv_share=4.0, tenants=2)
    assert big < small


def test_sim_executor_partition_pricing_and_memory():
    ts = pt.TenantSlice(share=0.5, inv_share=2.0, tenants=2, isolation=0.0)
    ex = SimExecutor(PROF, device=DEV, partition=ts)
    assert ex.mean_latency(4, 1) == pytest.approx(
        dm.part_latency(DEV, PROF, 4, 1, inv_share=2.0, tenants=2))
    # uniform slice == the MTL=2 curve (the executor-level calibration)
    assert ex.mean_latency(4, 1) == dm.mt_latency(DEV, PROF, 4, 2)
    # memory: the tenant only sees its slice
    whole = SimExecutor(PROF, device=DEV)
    sliver = SimExecutor(PROF, device=DEV, partition=pt.TenantSlice(
        share=0.02, mem_fraction=0.02, tenants=2))
    assert whole.fits(64, 4) and not sliver.fits(64, 4)
    # resize reprices without a rebuild
    ex.set_partition(pt.TenantSlice(share=1.0, inv_share=1.0, tenants=2))
    assert ex.mean_latency(4, 1) == pytest.approx(
        dm.part_latency(DEV, PROF, 4, 1, inv_share=1.0, tenants=2))


# ---------------------------------------------------------------------------
# 3-D HybridScaler: the share axis
# ---------------------------------------------------------------------------
LADDER = (0.25, 0.5, 0.75, 1.0)
SLO = 0.1


def _lat3(bs, mtl, share):
    """Deterministic multiplicative surface: monotone up in bs/mtl, down
    in share."""
    return 0.01 * bs * (1 + 0.5 * (mtl - 1)) / share


def _drive(sc, steps=400, demand_cap=None):
    """Serve the synthetic 3-D surface closed-loop; returns trace of
    (bs, mtl, share)."""
    trace = []
    for _ in range(steps):
        act = sc.action()
        share = act.share if act.share is not None else 1.0
        lat = _lat3(act.bs, act.mtl, share)
        items = act.bs * act.mtl
        if demand_cap is not None:
            # open-loop demand cap: served items per second of serving
            # cannot exceed the arrival rate, however big the slice
            items = min(items, demand_cap * lat)
        trace.append((act.bs, act.mtl, share))
        sc.observe(lat, {"step_time": lat, "items": items})
    return trace


def test_share_axis_bounds_and_convergence_holds_slo():
    sc = HybridScaler(SLO, decision_interval=1, share_ladder=LADDER)
    sc.set_granted_share(0.5)
    trace = _drive(sc, steps=600)
    for bs, mtl, share in trace:
        assert 1 <= bs <= 128 and 1 <= mtl <= 10
        assert share in LADDER
    # converged: the point actually served in the tail never violates SLO
    for bs, mtl, share in trace[-50:]:
        assert _lat3(bs, mtl, share) <= SLO * 1.01
    assert not sc.infeasible


def test_share_up_is_demand_capped_by_throughput_guard():
    """A share-up probe that buys no served items (open-loop demand cap)
    must be reverted and pinned — the throughput-guarded move property."""
    sc = HybridScaler(SLO, decision_interval=1, share_ladder=LADDER,
                      max_bs=1, max_mtl=1)   # isolate the share axis
    sc.set_granted_share(0.5)
    # demand far below capacity: items/time is flat in share
    _drive(sc, steps=200, demand_cap=5.0)
    act = sc.action()
    # the scaler did not ratchet to max share it cannot use
    assert act.share <= 0.5 + 1e-9


def test_violation_at_floor_escapes_through_share_up():
    sc = HybridScaler(SLO, decision_interval=1, share_ladder=LADDER,
                      max_bs=1, max_mtl=1)
    sc.set_granted_share(0.25)
    sc.observe(2.0 * SLO)                # gross violation at (1, 1)
    sc.observe(2.0 * SLO)
    assert sc.action().share > 0.25      # grew the slice instead of
    assert not sc.infeasible             # declaring infeasible
    # infeasible only once the whole ladder is exhausted and (1, 1) at the
    # full device still violates
    for _ in range(8):
        sc.observe(2.0 * SLO)
    assert sc.infeasible
    assert sc.action().bs == 1 and sc.action().mtl == 1
    # at the full device already: infeasible without a ladder escape
    sc2 = HybridScaler(SLO, decision_interval=1, share_ladder=LADDER,
                       max_bs=1, max_mtl=1)
    sc2.set_granted_share(1.0)
    for _ in range(8):
        sc2.observe(2.0 * SLO)
    assert sc2.infeasible


def test_share_cap_bounds_requests():
    sc = HybridScaler(SLO, decision_interval=1, share_ladder=LADDER,
                      max_bs=1, max_mtl=1)
    sc.set_granted_share(0.25)
    sc.set_share_cap(0.5)
    for _ in range(400):
        sc.observe(2.0 * SLO)            # always begging for more
        assert sc.action().share <= 0.5 + 1e-9


def test_dominance_pins_extend_down_the_share_axis():
    sc = HybridScaler(SLO, decision_interval=1, share_ladder=LADDER)
    sc.set_granted_share(1.0)            # rung 3
    sc._dom_counts[(8, 2, 2)] = sc.persist_pins   # failed at share 0.75
    # same work at a SMALLER share is dominated ...
    assert sc.is_pinned(8, 2, si=1) and sc.is_pinned(16, 3, si=0)
    # ... but a larger share is not
    assert not sc.is_pinned(8, 2, si=3)


def test_no_ladder_keeps_scaler_exactly_2d():
    sc = HybridScaler(SLO, decision_interval=1)
    assert sc.action().share is None
    sc.set_granted_share(0.5)            # no-ops without a ladder
    sc.set_share_cap(0.25)
    assert sc.action().share is None


# ---------------------------------------------------------------------------
# SurfaceLibrary: the (bs, mtl, share) tensor
# ---------------------------------------------------------------------------
def test_surface_library_share_tensor_roundtrip_and_predict():
    from repro.core.matrix_completion import SurfaceLibrary
    shares = (1.0, 0.5, 0.25)
    lib = SurfaceLibrary(bs_values=(1, 2, 4, 8), max_mtl=4,
                         share_values=shares)
    assert lib.shape == (4, 4, 3)

    def lat(b, m, s, base=5.0):
        return base * (1 + 0.3 * (b - 1)) * (1 + 0.5 * (m - 1)) / s / 1e3

    for b in (1, 2, 4, 8):
        for m in range(1, 5):
            for s in shares:
                lib.observe("historic", b, m, lat(b, m, s, 7.0), share=s)
    for b, m, s in ((1, 1, 1.0), (4, 1, 1.0), (1, 2, 0.5), (2, 1, 0.25)):
        lib.observe("new", b, m, lat(b, m, s), share=s)
    full = lib.predict("new")
    assert full is not None and full[0].shape == (4, 4, 3)
    est, support = lib.predict("new", share=0.5)
    assert est.shape == (4, 4)
    truth = np.array([[lat(b, m, 0.5) for m in range(1, 5)]
                      for b in (1, 2, 4, 8)])
    rel = np.abs(est - truth) / truth
    assert float(np.median(rel)) < 0.2
    # off-grid share observations are dropped, like off-grid bs
    before = lib.n_points("new")
    lib.observe("new", 1, 1, 0.005, share=0.33)
    assert lib.n_points("new") == before


def test_surface_library_share_row_persists_through_store(tmp_path):
    from repro.core.matrix_completion import SurfaceLibrary
    from repro.perf.profile_store import ProfileStore
    shares = (1.0, 0.5)
    lib = SurfaceLibrary(bs_values=(1, 2, 4), max_mtl=3,
                         share_values=shares)
    for b in (1, 2, 4):
        for m in (1, 2, 3):
            for s in shares:
                lib.observe("t", b, m, 0.004 * b * m / s, share=s)
    store = ProfileStore(str(tmp_path))
    assert store.persist_surface(lib, "t", signature="net/x",
                                 device_class="gpu", tile_dependent=False)
    store.save()
    lib2 = SurfaceLibrary(bs_values=(1, 2, 4), max_mtl=3,
                          share_values=shares)
    res = ProfileStore(str(tmp_path)).load_surfaces(
        lib2, device_class="gpu", validate=False)
    assert len(res["loaded"]) == 1 and not res["evicted"]
    assert lib2.n_points(("hist", "net/x", "gpu")) == 18
    # a 2-D library refuses the 3-D record (grid mismatch -> eviction)
    lib_2d = SurfaceLibrary(bs_values=(1, 2, 4), max_mtl=3)
    res = ProfileStore(str(tmp_path)).load_surfaces(
        lib_2d, device_class="gpu", validate=False)
    assert len(res["evicted"]) == 1


# ---------------------------------------------------------------------------
# ClusterEngine partition mode
# ---------------------------------------------------------------------------
def _static_factory(bs=1, mtl=1):
    from repro.core.controller import StaticController
    return lambda job, executor: StaticController(bs=bs, mtl=mtl)


def _tenant(k, base, admit, depart, rate):
    return ChurnJob(job=dataclasses.replace(base, job_id=700 + k),
                    admit_s=admit, depart_s=depart, arrival_rate=rate)


def test_partition_uniform_grants_price_like_mtl():
    """Two tenants on one MPS device: each executor's pricing equals the
    paper's MTL=2 curve — the engine-level face of the calibration."""
    trace = [_tenant(0, PAPER_JOBS[2], 0.0, None, None),
             _tenant(1, PAPER_JOBS[2], 0.0, None, None)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(),
                        partition="mps", seed=0)
    prof = PAPER_JOBS[2].profile()
    for st in eng.states:
        assert st.executor.mean_latency(4, 1) == pytest.approx(
            dm.mt_latency(dm.TESLA_P40, prof, 4, 2))
    assert eng.partition_plan(0).validate() == []


def test_partition_admission_resizes_instead_of_migrating():
    """Churn on a full device: the partition path absorbs every share
    change with cheap resizes — zero kill+relaunch migrations — and the
    recorded equivalent-migration cost strictly exceeds what was paid."""
    base = PAPER_JOBS[2]
    trace = [_tenant(k, base, 0.0 if k < 4 else 3.0,
                     6.0 if k == 1 else None, 50.0)
             for k in range(5)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(),
                        partition="mps", seed=0, max_queue=500)
    rep = eng.run(sim_time_limit=15.0)
    agg = rep["aggregate"]
    assert agg["conserved"]
    assert agg["migrations"] == 0
    assert agg["resizes"] > 0
    assert agg["resize_stall_s"] < agg["resize_equiv_migration_stall_s"]
    # legality holds after all the churn
    for d in range(len(eng.fleet)):
        assert eng.partition_plan(d).validate() == []
        assert eng._headroom(d) >= -pt.SHARE_TOL


def test_partition_mig_grants_stay_on_grid():
    trace = [_tenant(k, PAPER_JOBS[2], 0.0, None, None) for k in range(3)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(),
                        partition="mig", seed=0)
    eng.run(sim_time_limit=5.0)
    for j in eng.residents[0]:
        share = eng._grant[j]
        assert any(abs(share - c) < 1e-9 for c, _ in pt.MIG_PROFILES)
    assert eng.partition_plan(0).validate() == []


def test_mig_admission_never_oversubscribes_the_device():
    """Regression: floor-sized MIG residents cannot shrink, so piling
    tenants onto one device used to push the share sum past 1.  Now
    residents step down the profile grid, and once the tenant count
    outgrows the grid the device explicitly falls back to
    time-multiplexed 1/k shares (reported as a legal 'mps' plan)."""
    trace = [_tenant(k, PAPER_JOBS[2], 0.0 if k < 2 else 0.5 + 0.1 * k,
                     None, None) for k in range(9)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(),
                        partition="mig", seed=0)
    eng.run(sim_time_limit=6.0)
    plan = eng.partition_plan(0)
    assert plan.total_share <= 1.0 + pt.SHARE_TOL
    assert plan.validate() == []
    assert 0 in eng._timeshared          # 9 tenants > 7 compute slices
    assert plan.kind == "mps"            # reported as time-multiplexed
    # grants really are the equal time-share
    shares = {round(eng._grant[j], 6) for j in eng.residents[0]}
    assert shares == {round(1.0 / 9, 6)}


def test_off_ladder_grant_does_not_trigger_snapback_resizes():
    """Regression: a 1/3 grant is off the eighths ladder; the scaler used
    to snap its report down to 0.25 and the engine read the difference as
    a shrink request, charging an unrequested resize on the next step."""
    from repro.serving.cluster import paper_controller_factory
    trace = [_tenant(k, PAPER_JOBS[2], 0.0, None, 10.0) for k in range(3)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=paper_controller_factory(
                            "hybrid", share_ladder=pt.MPS_LADDER),
                        partition="mps", seed=0, max_queue=200)
    eng.run(sim_time_limit=2.0)
    # no job hands back its 1/3 grant unprompted in the first steps
    assert not any(kind == "resize" and t < 0.5
                   for t, kind, _, _ in eng.churn_log)
    """The acceptance bar, at test scale: same trace, same pricing model,
    heterogeneous shares + resizes vs uniform 1/k + migrations."""
    trace = mixed_partition_trace(horizon_s=120.0, n_light=5, seed=1)
    kw = dict(trace=list(trace), n_devices=2, horizon_s=120.0, seed=1)
    uni = run_partition_cluster("uniform", **kw)
    het = run_partition_cluster("het", **kw)
    assert uni["aggregate"]["conserved"] and het["aggregate"]["conserved"]
    assert (het["aggregate"]["goodput"] > uni["aggregate"]["goodput"])
    assert het["aggregate"]["migrations"] == 0
    assert (het["aggregate"]["resize_stall_s"]
            < het["aggregate"]["resize_equiv_migration_stall_s"])
