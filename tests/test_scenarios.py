"""Scenario-matrix subsystem tests: the per-slice power model's
calibration properties, consolidate-vs-spread energy accounting, spot
revocation (evacuation, grace windows, forced kills) with request
conservation in both engines, time-varying traffic integrals, and the
scenario record -> replay round trip."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core.controller import StaticController
from repro.perf.profile_store import ProfileStore
from repro.serving import device_model as dm
from repro.serving import replay as rp
from repro.serving.cluster import (ClusterEngine, DeviceSpec,
                                   VectorClusterEngine, gpu_fleet,
                                   run_churn_cluster, run_scenario_cluster,
                                   spot_fleet)
from repro.serving.engine import OpenLoopQueue
from repro.serving.workload import (PAPER_JOBS, ChurnJob, Preemption,
                                    make_rate_fn, scenario_trace,
                                    spot_revocation_trace)

DEV = dm.TESLA_P40
PROF = dm.paper_profile("inception_v1")


def _static_factory(bs=1, mtl=1):
    return lambda job, executor: StaticController(bs=bs, mtl=mtl)


def _tenant(k, admit=0.0, depart=None, rate=50.0, jid_base=700):
    base = PAPER_JOBS[0]
    return ChurnJob(job=dataclasses.replace(base, job_id=jid_base + k),
                    admit_s=admit, depart_s=depart, arrival_rate=rate)


def _assert_conserved(rep):
    for r in rep["per_job"]:
        assert r["submitted"] == (r["completed"] + r["rejected"]
                                  + r["backlog"]), r
    assert rep["aggregate"]["conserved"]


# ---------------------------------------------------------------------------
# per-slice power model properties
# ---------------------------------------------------------------------------
def test_slice_power_full_share_is_whole_device_power():
    for bs in (1, 4, 16, 64):
        for mtl in (1, 2, 4):
            assert dm.slice_power(DEV, PROF, bs, mtl) \
                == dm.power(DEV, PROF, bs, mtl)


def test_slice_power_monotone_in_share():
    shares = (0.125, 0.25, 0.375, 0.5, 0.75, 1.0)
    for bs in (1, 8, 32):
        draws = [dm.slice_power(DEV, PROF, bs, 1, share=s,
                                inv_share=1.0 / s, tenants=2)
                 for s in shares]
        assert all(b >= a - 1e-12 for a, b in zip(draws, draws[1:])), draws


def test_step_energy_monotone_in_bs():
    """Energy PER STEP (power x step latency) grows with batch size: a
    bigger batch holds the device busy longer at no lower draw."""
    bs = (1, 2, 4, 8, 16, 32, 64, 128)
    for mtl in (1, 2, 4):
        e = [dm.power(DEV, PROF, b, mtl) * dm.mt_latency(DEV, PROF, b, mtl)
             for b in bs]
        assert all(y >= x - 1e-12 for x, y in zip(e, e[1:])), e


def test_power_monotone_in_mtl():
    for bs in (1, 8, 32):
        draws = [dm.power(DEV, PROF, bs, m) for m in range(1, 11)]
        assert all(b >= a - 1e-12 for a, b in zip(draws, draws[1:])), draws


def test_uniform_slices_sum_to_whole_device_power():
    """k uniform tenants at share 1/k, mtl=1 sum to the MTL-k whole-device
    draw — the calibration invariant slice_power pins: spatial
    multiplexing at equal aggregate share burns what MTL burns."""
    for bs in (1, 4, 16, 64):
        for k in range(1, 9):
            total = k * dm.slice_power(DEV, PROF, bs, 1, share=1.0 / k,
                                       inv_share=float(k), tenants=k)
            whole = dm.power(DEV, PROF, bs, k)
            assert abs(total - whole) <= 1e-9 * whole, (bs, k)


def test_power_bounded_by_idle_and_peak():
    for bs in (1, 16, 128):
        for share in (0.25, 1.0):
            w = dm.slice_power(DEV, PROF, bs, 1, share=share,
                               inv_share=1.0 / share, tenants=2)
            assert share * DEV.idle_w - 1e-12 <= w \
                <= share * DEV.peak_w + 1e-12


# ---------------------------------------------------------------------------
# cluster-level energy accounting: idle floor once per powered device,
# power-gated (never-resident) devices draw nothing
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_pack_gates_idle_devices_and_energy_decomposes():
    pack = run_scenario_cluster("steady", power_policy="pack",
                                seed=3, horizon_s=80.0)["aggregate"]
    spread = run_scenario_cluster("steady", power_policy="spread",
                                  seed=3, horizon_s=80.0)["aggregate"]
    # pack consolidates 4-6 light tenants onto a subset of the 4 devices;
    # spread pays the idle floor everywhere
    assert pack["devices_powered"] < spread["devices_powered"] == 4
    for a in (pack, spread):
        assert a["energy_j"] == pytest.approx(
            a["idle_energy_j"] + a["dynamic_energy_j"], rel=1e-12)
        # the idle floor is charged at most once per powered device:
        # total powered seconds can never exceed devices_powered x makespan
        assert a["device_powered_s"] \
            <= a["devices_powered"] * a["makespan_s"] + 1e-6
        assert a["idle_energy_j"] \
            <= DEV.idle_w * a["device_powered_s"] + 1e-6
    assert pack["idle_energy_j"] < spread["idle_energy_j"]


# ---------------------------------------------------------------------------
# spot revocation: evacuation, grace windows, forced kills, conservation
# ---------------------------------------------------------------------------
def _spot_pair_fleet():
    """Device 0 is spot (with a resident), device 1 is fixed and empty."""
    dev = DEV
    return [DeviceSpec(device=dataclasses.replace(dev, spot=True),
                       name="spot/0"),
            DeviceSpec(device=dev, name="fixed/1")]


def test_revocation_evacuates_with_exactly_one_migration():
    fleet = _spot_pair_fleet()
    trace = [_tenant(0, rate=80.0)]
    pre = [Preemption(device=0, at_s=15.0, grace_s=5.0, restore_s=40.0)]
    eng = ClusterEngine([], fleet, churn=trace,
                        controller_factory=_static_factory(),
                        anticipate=True, seed=0, preemptions=pre)
    rep = eng.run(sim_time_limit=60.0)
    _assert_conserved(rep)
    a = rep["aggregate"]
    assert a["preemptions"] == 1
    assert a["preempt_evacuated"] == 1
    assert a["preempt_killed"] == 0
    j = rep["per_job"][0]
    assert j["preempted"] == 0
    assert j["device"].startswith("fixed")
    # evacuation is ONE migration round, charged exactly once
    evicts = [e for e in eng.churn_log if e[1] == "evict"]
    assert len(evicts) == 1 and j["migrations"] == 1
    assert evicts[0][0] == pytest.approx(15.0)


def test_revocation_with_nowhere_to_go_kills_at_grace_deadline():
    """The whole fleet is revoked: the resident serves through the grace
    window on the doomed device, then its stranded backlog moves to
    `rejected` — conservation survives the kill, and the kill never fires
    before the deadline."""
    fleet = [DeviceSpec(device=dataclasses.replace(DEV, spot=True),
                        name="spot/0")]
    trace = [_tenant(0, rate=400.0)]
    pre = [Preemption(device=0, at_s=10.0, grace_s=4.0, restore_s=None)]
    eng = ClusterEngine([], fleet, churn=trace,
                        controller_factory=_static_factory(),
                        anticipate=True, seed=0, preemptions=pre)
    rep = eng.run(sim_time_limit=60.0)
    _assert_conserved(rep)
    j = rep["per_job"][0]
    a = rep["aggregate"]
    assert j["preempted"] == 1
    assert a["preempt_killed"] == 1 and a["preempt_evacuated"] == 0
    assert j["rejected"] > 0                  # the stranded backlog
    assert j["backlog"] == 0 and not j["active"]
    # grace honored: killed at (or just past) the deadline, never before
    assert j["drained_at"] >= 10.0 + 4.0 - 1e-9
    kills = [e for e in eng.churn_log if e[1] == "revoke-kill"]
    assert len(kills) == 1


def test_doomed_job_that_drains_early_is_not_killed():
    """A doomed tenant whose backlog empties inside the grace window
    drains normally: no forced kill, no preempted flag, no double-drain."""
    fleet = [DeviceSpec(device=dataclasses.replace(DEV, spot=True),
                        name="spot/0")]
    trace = [_tenant(0, rate=1.0)]            # trivially drainable
    pre = [Preemption(device=0, at_s=10.0, grace_s=8.0, restore_s=None)]
    eng = ClusterEngine([], fleet, churn=trace,
                        controller_factory=_static_factory(),
                        anticipate=True, seed=0, preemptions=pre)
    rep = eng.run(sim_time_limit=60.0)
    _assert_conserved(rep)
    j = rep["per_job"][0]
    assert j["preempted"] == 0
    assert rep["aggregate"]["preempt_killed"] == 0
    assert j["drained_at"] is not None
    # drains at the end of the step in flight when the backlog empties,
    # so allow one step latency past the clipped departure
    assert j["drained_at"] <= 18.0 + 0.5
    assert sum(1 for e in eng.churn_log if e[1] == "drain") == 1
    assert not any(e[1] == "revoke-kill" for e in eng.churn_log)


def test_restore_returns_device_to_pool():
    """After the restore edge, new admissions may land on the once-revoked
    device again."""
    fleet = _spot_pair_fleet()
    trace = [_tenant(0, rate=50.0),
             _tenant(1, admit=30.0, rate=50.0)]
    pre = [Preemption(device=0, at_s=10.0, grace_s=2.0, restore_s=20.0)]
    eng = ClusterEngine([], fleet, churn=trace,
                        controller_factory=_static_factory(),
                        anticipate=True, seed=0, preemptions=pre)
    rep = eng.run(sim_time_limit=60.0)
    _assert_conserved(rep)
    assert any(e[1] == "restore" for e in eng.churn_log)
    # the late tenant lands on the restored (now empty) spot device
    assert rep["per_job"][1]["device"].startswith("spot")


@pytest.mark.slow
def test_spot_revocation_conservation_both_engines_bit_identical():
    """The scenario trace under spot revocation: exact and vectorized
    engines conserve requests and produce the SAME report bit for bit."""
    reps = {}
    for vec in (False, True):
        reps[vec] = run_scenario_cluster(
            "flash", spot=True, power_policy="spread",
            seed=3, horizon_s=100.0, vectorized=vec)
        _assert_conserved(reps[vec])
    assert reps[False] == reps[True]
    assert reps[False]["aggregate"]["preemptions"] >= 1


def test_churn_entry_spot_equality_exact_vs_vector():
    """Preemption conformance on the NON-partition churn path too."""
    fleet = _spot_pair_fleet()
    trace = [_tenant(0, rate=80.0), _tenant(1, rate=40.0)]
    pre = [Preemption(device=0, at_s=12.0, grace_s=4.0, restore_s=35.0)]
    reps = {}
    for cls in (ClusterEngine, VectorClusterEngine):
        eng = cls([], fleet, churn=list(trace),
                  controller_factory=_static_factory(),
                  anticipate=True, seed=0, preemptions=pre)
        reps[cls.__name__] = eng.run(sim_time_limit=50.0)
        _assert_conserved(reps[cls.__name__])
    assert reps["ClusterEngine"] == reps["VectorClusterEngine"]


def test_preemption_unknown_device_rejected():
    with pytest.raises(ValueError):
        ClusterEngine([], gpu_fleet(2), churn=[_tenant(0)],
                      controller_factory=_static_factory(),
                      preemptions=[Preemption(device=7, at_s=1.0)])


def test_spot_fleet_and_revocation_trace():
    fleet = spot_fleet(4, 2)
    assert [s.device.spot for s in fleet] == [False, False, True, True]
    # Device.share preserves the spot flag (dataclasses.replace path)
    assert fleet[3].device.share(0.5).spot is True
    pre = spot_revocation_trace(fleet, horizon_s=100.0, grace_s=7.0,
                                seed=0)
    assert [p.device for p in sorted(pre, key=lambda p: p.device)] == [2, 3]
    for p in pre:
        assert 20.0 <= p.at_s <= 80.0
        assert p.grace_s == 7.0
        assert p.restore_s is None or p.restore_s > p.at_s + p.grace_s
    assert spot_revocation_trace(gpu_fleet(3), horizon_s=100.0) == []


# ---------------------------------------------------------------------------
# time-varying traffic specs
# ---------------------------------------------------------------------------
def test_make_rate_fn_steady_is_constant():
    fn, piecewise, breaks = make_rate_fn(42.0, None)
    assert piecewise is None and breaks is None
    assert fn(0.0) == fn(17.3) == 42.0
    fn2, _, _ = make_rate_fn(42.0, {"kind": "steady"})
    assert fn2(5.0) == 42.0


def test_make_rate_fn_diurnal_shape():
    spec = {"kind": "diurnal", "period_s": 100.0, "peak_mult": 1.5,
            "trough_mult": 0.5, "phase_s": 0.0}
    fn, piecewise, breaks = make_rate_fn(10.0, spec)
    assert breaks is None and piecewise == pytest.approx(100.0 / 16)
    assert fn(0.0) == pytest.approx(5.0)       # trough at phase
    assert fn(50.0) == pytest.approx(15.0)     # peak half a period later
    assert fn(100.0) == pytest.approx(5.0)
    # mean over one period is the midpoint of the swing
    ts = np.linspace(0.0, 100.0, 10_001)
    assert np.mean([fn(t) for t in ts]) == pytest.approx(10.0, rel=1e-3)


def test_make_rate_fn_flash_step_and_breaks():
    spec = {"kind": "flash", "at_s": 50.0, "duration_s": 10.0, "mult": 3.0}
    fn, piecewise, breaks = make_rate_fn(10.0, spec)
    assert fn(49.9) == 10.0 and fn(50.0) == 30.0
    assert fn(59.9) == 30.0 and fn(60.0) == 10.0
    assert list(breaks(0.0, 100.0)) == [50.0, 60.0]
    assert list(breaks(52.0, 55.0)) == []
    # the registered breaks make the queue's integral EXACT on windows
    # straddling the spike edges
    q = OpenLoopQueue(fn, max_queue=10, seed=0, step_breaks=breaks)
    assert q.expected_arrivals(45.0, 65.0) \
        == pytest.approx(5 * 10.0 + 10 * 30.0 + 5 * 10.0, abs=1e-9)


def test_scenario_trace_traffic_wiring():
    for traffic, kind in (("steady", None), ("diurnal", "diurnal"),
                          ("flash", "flash")):
        trace = scenario_trace(traffic, horizon_s=100.0, seed=3)
        assert len(trace) == 6
        kinds = {(e.traffic or {}).get("kind") for e in trace}
        assert kinds == {kind}
        assert sum(1 for e in trace if e.depart_s is not None) == 1
        assert sum(1 for e in trace if e.admit_s > 0.0) == 1
    with pytest.raises(ValueError):
        scenario_trace("tsunami", horizon_s=100.0)
    with pytest.raises(ValueError):
        run_scenario_cluster("tsunami")


# ---------------------------------------------------------------------------
# record -> replay round trip for the scenario entry
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_scenario_record_then_replay_exact(tmp_path):
    store = ProfileStore(str(tmp_path / "store"))
    rep = run_scenario_cluster("flash", spot=True, power_policy="spread",
                               seed=3, horizon_s=100.0,
                               record="sc1", record_store=store)
    recorded = json.loads(json.dumps(rp.load_trace(store, "sc1")))
    meta = recorded["init"]["meta"]
    assert meta["entry"] == "scenario" and meta["traffic"] == "flash"
    assert meta["spot"] is True
    # the churn serializer round-trips the traffic spec
    assert all(e["traffic"]["kind"] == "flash"
               for e in recorded["init"]["churn"])
    assert rp.replay_run(recorded) == rep
    assert rp.replay_run(recorded, vectorized=True) == rep
    # counterfactual: fewer devices drops revocations of removed devices
    fewer = rp.replay_run(recorded, policy="fewer-devices")
    assert fewer["aggregate"]["devices"] == 3
    assert fewer["aggregate"]["conserved"]


def test_churn_serializer_round_trips_traffic_and_legacy_dicts():
    e = ChurnJob(job=PAPER_JOBS[2], admit_s=1.0, depart_s=9.0,
                 arrival_rate=25.0,
                 traffic={"kind": "flash", "at_s": 5.0,
                          "duration_s": 2.0, "mult": 3.0})
    assert rp.deserialize_churn(
        json.loads(json.dumps(rp.serialize_churn(e)))) == e
    # a pre-scenario recorded dict (no "traffic" key) still deserializes
    legacy = rp.serialize_churn(ChurnJob(job=PAPER_JOBS[2]))
    legacy.pop("traffic")
    assert rp.deserialize_churn(legacy).traffic is None


# ---------------------------------------------------------------------------
# carbon-aware power pricing: a time-varying $/J signal changes WHEN pack
# consolidates — off-peak-cheap energy defers power-gating (idle silicon is
# nearly free to keep warm), and the report prices the run
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_offpeak_cheap_signal_defers_pack_gating_vs_flat():
    kw = dict(power_policy="pack", n_devices=3, horizon_s=60.0, seed=3)
    flat = run_scenario_cluster("diurnal", power_price_fn=lambda t: 1e-7,
                                **kw)["aggregate"]
    # first half of the run at 2% of the peak price: packing's
    # consolidation is deferred while energy is nearly free
    cheap = run_scenario_cluster(
        "diurnal",
        power_price_fn=lambda t: 2e-9 if t < 30.0 else 1e-7,
        **kw)["aggregate"]
    # flat pricing gates like classic pack; the off-peak window keeps
    # more devices powered for longer
    assert cheap["device_powered_s"] > flat["device_powered_s"]
    assert cheap["devices_powered"] >= flat["devices_powered"]
    # both runs are priced: signal over powered intervals + dynamic joules
    for a in (flat, cheap):
        assert a["power_cost_usd"] > 0.0
        assert a["cost_per_good_request"] > 0.0
        assert a["conserved"]
    # a neutral run (no price signal) reports None, not zero
    plain = run_scenario_cluster("diurnal", **kw)["aggregate"]
    assert plain["power_cost_usd"] is None
    assert plain["cost_per_good_request"] is None
