"""Distribution tests: sharding resolver rules + a real multi-device pjit run
in a subprocess (8 placeholder CPU devices so the main process keeps 1)."""

import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.distributed import sharding as shd
from repro.models import api


class _FakeMeshInfo:
    """MeshInfo stand-in with given axis sizes (no devices needed)."""

    def __init__(self, sizes):
        self._sizes = sizes

    @property
    def axis_sizes(self):
        return dict(self._sizes)

    @property
    def model(self):
        return self._sizes.get("model", 1)

    @property
    def data(self):
        return self._sizes.get("data", 1)

    @property
    def has_pod(self):
        return "pod" in self._sizes

    @property
    def batch_axes(self):
        return ("pod", "data") if self.has_pod else ("data",)

    @property
    def batch_size(self):
        import numpy as np
        return int(np.prod([self._sizes[a] for a in self.batch_axes]))


MINFO = _FakeMeshInfo({"data": 16, "model": 16})


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mode", ["train", "infer"])
def test_param_specs_divisible(arch, mode):
    """Every sharded dim must be divisible by its mesh axes product."""
    cfg = get_config(arch)
    abstract = api.param_specs(cfg)
    specs = shd.param_specs(abstract, cfg, MINFO, mode)

    def check(leaf, spec):
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            prod = 1
            for a in axes:
                prod *= MINFO.axis_sizes[a]
            assert dim % prod == 0, (arch, mode, leaf.shape, spec)

    jax.tree.map(check, abstract, specs,
                 is_leaf=lambda x: isinstance(x, P))


def test_train_mode_has_fsdp():
    cfg = get_config("qwen2_72b")
    abstract = api.param_specs(cfg)
    specs = shd.param_specs(abstract, cfg, MINFO, "train")
    flat = [s for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))]
    n_data = sum(1 for s in flat if "data" in [a for ax in s if ax
                                               for a in ((ax,) if isinstance(ax, str) else ax)])
    assert n_data > len(flat) * 0.5  # most params data-sharded (FSDP)


def test_infer_mode_fsdp_only_when_needed():
    big = get_config("mixtral_8x22b")      # 280 GB bf16 -> needs data shard
    small = get_config("gemma2_2b")        # fits TP-only
    for cfg, expect_fsdp in ((big, True), (small, False)):
        abstract = api.param_specs(cfg)
        specs = shd.param_specs(abstract, cfg, MINFO, "infer")
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        has_data = any("data" in [a for ax in s if ax
                                  for a in ((ax,) if isinstance(ax, str) else ax)]
                       for s in flat)
        assert has_data == expect_fsdp, cfg.name


def test_cache_specs_long_context_seq_sharded():
    cfg = get_config("mamba2_1p3b")
    shape = INPUT_SHAPES["long_500k"]
    cache_abs = jax.eval_shape(lambda: api.init_cache(cfg, 1, shape.seq_len))
    specs = shd.cache_specs_tree(cache_abs, cfg, MINFO, 1, shape.seq_len)
    # mamba states have no seq axis; check a windowed arch instead
    cfg2 = get_config("mixtral_8x22b")
    cache2 = jax.eval_shape(lambda: api.init_cache(cfg2, 1, shape.seq_len))
    specs2 = shd.cache_specs_tree(cache2, cfg2, MINFO, 1, shape.seq_len)
    k_spec = specs2[0]["k"]
    # (count, B, KV, S, hd): sequence axis at index 3
    assert k_spec[3] is not None  # sequence axis sharded


SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, InputShape
from repro.distributed.sharding import MeshInfo
from repro.launch import steps as steps_lib
from repro.models import api

mesh = jax.make_mesh((4, 2), ("data", "model"))
minfo = MeshInfo(mesh)
cfg = get_config("smollm_360m", tiny=True).replace(num_heads=4, num_kv_heads=2,
                                                   head_dim=32, d_model=128,
                                                   d_ff=256, vocab_size=512)
shape = InputShape("t", 64, 8, "train")
with mesh:
    fn, arg_specs, in_sh, _ = steps_lib.make_train_step(cfg, minfo, shape,
                                                        num_microbatches=2)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng, cfg)
    from repro.training import adamw
    opt = adamw.init(params)
    batch = api.make_batch(rng, cfg, shape)
    params = jax.device_put(params, in_sh[0])
    opt = jax.device_put(opt, in_sh[1])
    batch = jax.device_put(batch, in_sh[2])
    p2, o2, m = fn(params, opt, batch)
    loss1 = float(m["loss"])
    p3, o3, m2 = fn(p2, o2, batch)
    loss2 = float(m2["loss"])
assert np.isfinite(loss1) and np.isfinite(loss2), (loss1, loss2)
assert loss2 < loss1 + 0.5
print("MULTIDEV_OK", loss1, loss2)
"""


def test_multidevice_train_step_executes():
    """Actually executes the sharded train step on 8 placeholder devices."""
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_PROG],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


DECODE_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, InputShape
from repro.distributed.sharding import MeshInfo
from repro.launch import steps as steps_lib
from repro.models import api

mesh = jax.make_mesh((4, 2), ("data", "model"))
minfo = MeshInfo(mesh)
cfg = get_config("mixtral_8x22b", tiny=True)
B, S = 8, 128
shape = InputShape("d", S, B, "decode")
rng = jax.random.PRNGKey(0)
params = api.init_params(rng, cfg)

# reference: single-logical-device decode via the internal put path
prefix = jax.random.randint(rng, (B, S - 1), 0, cfg.vocab_size, jnp.int32)
_, cache = api.prefill(params, {"tokens": prefix}, cfg, capacity=S)
tok = jax.random.randint(jax.random.PRNGKey(1), (B,), 0, cfg.vocab_size, jnp.int32)
pos = jnp.asarray(S - 1, jnp.int32)
ref_logits, ref_cache = api.decode_step(params, cache, tok, pos, cfg)

# sharded decode step (append-outside-scan + shard_map cache write)
with mesh:
    fn, arg_specs, _, _ = steps_lib.make_decode_step(cfg, minfo, shape)
    logits, new_cache = fn(params, cache, tok, pos)
np.testing.assert_allclose(np.asarray(logits, np.float32),
                           np.asarray(ref_logits, np.float32),
                           atol=5e-2, rtol=5e-2)
for a, b in zip(jax.tree.leaves(new_cache), jax.tree.leaves(ref_cache)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-2, rtol=5e-2)
print("DECODE_SHARDED_OK")
"""


def test_multidevice_decode_matches_reference():
    """The sharded append-decode (shard_map cache write) must equal the
    single-device reference decode bit-for-bit-ish."""
    r = subprocess.run([sys.executable, "-c", DECODE_PROG],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "DECODE_SHARDED_OK" in r.stdout, r.stdout + r.stderr
