"""Token-level continuous batching tests: request conservation, TTFT/TPOT
accounting, the continuous-vs-static goodput contract, slot caps (controller
and memory), KV-cache admission on both executors, and the bench harness's
unknown-suite / no-fresh-rows failure modes (satellite #5)."""

import dataclasses
import json

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.serving import device_model as dm
from repro.serving.executor import SimExecutor
from repro.serving.token_engine import (TokenRequest, build_token_controller,
                                        memory_slot_cap, ragged_decode_trace,
                                        run_continuous, run_static,
                                        run_token_cluster, run_token_serving)

CFG = get_config("gemma2-2b")
PROF = dm.llm_profile(CFG, mode="decode", kv_seq_budget=1024)
# the bench operating point: inside continuous capacity at 16 slots,
# past the static engine's saturation cliff
TRACE = ragged_decode_trace(120, 0, rate_rps=12.0)
SLO = dict(ttft_slo_s=1.0, tpot_slo_s=0.05)


def _executor(seed=0):
    return SimExecutor(PROF, dm.TPU_V5E, seed=seed)


# ---------------------------------------------------------------------------
# Conservation — mirrored from the cluster engines' invariant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["continuous", "static"])
def test_conservation(policy):
    rep = run_token_serving(PROF, policy=policy, trace=TRACE, max_slots=16,
                            static_bs=16, **SLO)
    assert rep["conserved"]
    assert rep["submitted"] == len(TRACE)
    assert rep["completed"] == len(TRACE)       # both engines drain fully
    assert rep["backlog"] == 0 and rep["rejected"] == 0
    assert not rep["truncated"]


def test_conservation_with_bounded_queue():
    rep = run_continuous(TRACE, _executor(), max_slots=2, max_queue=3, **SLO)
    assert rep["conserved"]
    assert rep["rejected"] > 0                  # the bound actually bit
    assert rep["submitted"] == len(TRACE)


def test_cluster_conservation_and_aggregation():
    rep = run_token_cluster([PROF, PROF], trace=TRACE, max_slots=16, **SLO)
    assert rep["conserved"]
    assert rep["n_jobs"] == 2
    assert rep["submitted"] == 2 * len(TRACE)
    assert rep["tokens_out"] == sum(j["tokens_out"] for j in rep["jobs"])
    # different seeds per job: the noise streams must actually differ
    assert rep["jobs"][0]["makespan_s"] != rep["jobs"][1]["makespan_s"]


# ---------------------------------------------------------------------------
# Per-token latency accounting
# ---------------------------------------------------------------------------
def test_ttft_tpot_recording():
    rep = run_continuous(TRACE, _executor(), max_slots=16, **SLO)
    assert rep["completed"] == len(TRACE)
    # the engine works on its own copies; the caller's trace stays virgin
    assert all(r.admit_s == -1.0 for r in TRACE)
    for r in rep["requests"]:
        assert r.arrival_s <= r.admit_s <= r.first_token_s < r.finish_s
        assert r.ttft_s > 0 and r.tpot_s > 0
        # decode time is bounded by residency after the first token
        assert r.decode_time_s <= r.finish_s - r.first_token_s + 1e-9
    # token conservation: every completed request emitted all its tokens
    assert rep["tokens_out"] == sum(r.decode_tokens for r in TRACE)


def test_timeslice_prefill_is_slower_than_cotenant():
    """Serial prefill stalls the whole tenant per admission; co-resident
    prefill only inflates decode steps — makespan must reflect that."""
    ts = run_continuous(TRACE, _executor(), max_slots=16,
                        prefill_mode="timeslice", **SLO)
    co = run_continuous(TRACE, _executor(), max_slots=16,
                        prefill_mode="cotenant", **SLO)
    assert ts["conserved"] and co["conserved"]
    assert ts["makespan_s"] > co["makespan_s"]


# ---------------------------------------------------------------------------
# The contract: continuous beats static bucketed batching on ragged decode
# ---------------------------------------------------------------------------
def test_continuous_beats_static_goodput():
    cont = run_token_serving(PROF, policy="continuous", trace=TRACE,
                             max_slots=16, **SLO)
    stat = run_token_serving(PROF, policy="static", trace=TRACE,
                             static_bs=16, **SLO)
    assert cont["goodput_tokens_s"] >= 1.5 * stat["goodput_tokens_s"]
    assert cont["ttft_attainment"] >= 0.95
    assert cont["tpot_attainment"] >= 0.95


def test_static_holds_slots_until_longest_member_drains():
    """Two requests, decode lengths 1 and 100, same batch: under static
    batching the short one still finishes first but the BATCH (and the
    engine clock) is held for the long tail."""
    trace = [TokenRequest(0, 0.0, 256, 1), TokenRequest(1, 0.0, 256, 100)]
    rep = run_static(trace, _executor(), bs=2, **SLO)
    assert rep["conserved"] and rep["completed"] == 2
    by_id = {r.req_id: r for r in rep["requests"]}
    assert by_id[0].finish_s < by_id[1].finish_s
    assert rep["steps"] == 100                  # full-bs steps for the max
    # continuous frees the short request's slot after one step
    rep2 = run_continuous(trace, _executor(), max_slots=2, **SLO)
    assert rep2["tokens_out"] == 101 == rep["tokens_out"]


# ---------------------------------------------------------------------------
# Slot caps: controller and memory admission
# ---------------------------------------------------------------------------
def test_controller_slot_cap_respected():
    ex = _executor()
    ctrl = build_token_controller(ex, SLO["tpot_slo_s"], max_slots=8)
    rep = run_continuous(TRACE, ex, max_slots=8, controller=ctrl, **SLO)
    assert rep["conserved"]
    assert rep["mean_live_slots"] <= 8.0 + 1e-9
    assert ctrl.action().bs <= 8


def test_memory_slot_cap_charges_kv_bytes():
    ex = _executor()
    unlimited = memory_slot_cap(ex, 4096)
    # a profile whose KV cache is ~1/4 of HBM can hold very few slots
    fat = dataclasses.replace(PROF, kv_bytes_per_item=4e9)
    ex_fat = SimExecutor(fat, dm.TPU_V5E, seed=0)
    capped = memory_slot_cap(ex_fat, 4096)
    assert capped < unlimited
    assert ex_fat.fits(capped, 1) and not ex_fat.fits(capped + 1, 1)
    # and a profile that cannot fit even one slot refuses loudly
    huge = dataclasses.replace(PROF, kv_bytes_per_item=1e12)
    with pytest.raises(ValueError):
        memory_slot_cap(SimExecutor(huge, dm.TPU_V5E, seed=0), 4096)


def test_real_executor_fits_charges_kv_bytes():
    jax = pytest.importorskip("jax")
    from repro.serving.executor import RealExecutor
    kw = dict(fn=lambda p, b: b, params=np.zeros(16, np.float32),
              make_batch=lambda n: np.zeros((n, 4), np.float32),
              mem_bytes=100e6, act_bytes_per_item=1e6)
    no_kv = RealExecutor(**kw)
    with_kv = RealExecutor(**kw, kv_bytes_per_item=10e6)
    # 16 items: 16 MB activations fits either way without KV ...
    assert no_kv.fits(16, 1)
    # ... but 16 slots x 10 MB KV pages blow the 100 MB budget
    assert not with_kv.fits(16, 1)
    assert with_kv.fits(8, 1)                   # 8 + 80 <= 100


def test_sim_token_step_prices_like_batch():
    """A decode step with s live slots is priced as a bs=s batch — the
    memoized token path must agree with the partition-aware latency grid."""
    ex = _executor()
    lat = ex.token_step_latency(8, 1)
    grid = dm.token_latency_grid(ex.device, ex.profile, [8], [1])
    assert lat == pytest.approx(float(grid[0, 0]))
    r = ex.run_token_step(8, 1)
    assert r["tokens"] == 8 and r["items"] == 8
    # co-resident prefill tenants inflate the step (never speed it up)
    assert ex.token_step_latency(8, 1, prefill_tenants=2) > lat


# ---------------------------------------------------------------------------
# Harness failure modes (satellite #5): --check must fail loudly, not skip
# ---------------------------------------------------------------------------
def _write_baseline(tmp_path, suite, rows):
    path = tmp_path / f"BENCH_{suite}.json"
    path.write_text(json.dumps({
        "suite": suite,
        "rows": [{"name": n, "us_per_call": 0.0, "derived": d}
                 for n, d in rows]}))
    return path


def test_check_fails_on_unknown_suite(tmp_path, capsys):
    from benchmarks.run import check_against
    _write_baseline(tmp_path, "ghost_suite", [("ghost/x", "thr=12.0")])
    assert check_against(str(tmp_path)) == 1
    assert "UNKNOWN suite" in capsys.readouterr().out


def test_check_fails_on_no_fresh_rows(tmp_path, capsys, monkeypatch):
    import benchmarks.run as runmod
    _write_baseline(tmp_path, "empty_suite", [("e/x", "goodput=5.0")])
    monkeypatch.setattr(runmod, "suites",
                        lambda: {"empty_suite": lambda: []})
    assert runmod.check_against(str(tmp_path)) == 1
    assert "NO FRESH ROWS" in capsys.readouterr().out


def test_check_still_skips_ungated_baselines(tmp_path, monkeypatch):
    """Wall-clock-only baselines (no gated metric) stay cheap no-ops."""
    import benchmarks.run as runmod
    _write_baseline(tmp_path, "wallclock", [("w/x", "steps=100")])
    called = []
    monkeypatch.setattr(runmod, "suites", lambda: {
        "wallclock": lambda: called.append(1) or [("w/x", 0.0, "steps=1")]})
    assert runmod.check_against(str(tmp_path)) == 0
    assert not called                           # never re-ran the suite
