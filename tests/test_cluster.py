"""ClusterEngine tests: request conservation, simulated-clock monotonicity,
contention (adding a job never speeds another job up), placement, and the
cluster-level controller policies."""

import pytest

from repro.core.controller import StaticController
from repro.serving import device_model as dm
from repro.serving.cluster import (ClusterEngine, DeviceSpec, gpu_fleet,
                                   place, paper_controller_factory,
                                   run_paper_cluster)
from repro.serving.workload import PAPER_JOBS


def _static_factory(bs=1, mtl=1):
    return lambda job, executor: StaticController(bs=bs, mtl=mtl)


JOBS2 = [PAPER_JOBS[0], PAPER_JOBS[2]]          # inception v1 + v4


# ---------------------------------------------------------------------------
# Conservation: every submitted request is completed or rejected exactly once
# ---------------------------------------------------------------------------
def test_closed_loop_conservation():
    eng = ClusterEngine(JOBS2, gpu_fleet(1),
                        controller_factory=_static_factory())
    rep = eng.run(sim_time_limit=10.0)
    for r in rep["per_job"]:
        assert r["submitted"] == r["completed"]
        assert r["rejected"] == 0 and r["backlog"] == 0
        assert r["completed"] > 0


def test_open_loop_conservation_with_rejections():
    rates = {j.job_id: 500.0 for j in JOBS2}    # overload: force drops
    eng = ClusterEngine(JOBS2, gpu_fleet(1),
                        controller_factory=_static_factory(),
                        arrival_rates=rates, max_queue=50)
    rep = eng.run(sim_time_limit=20.0)
    total_rejected = 0
    for r in rep["per_job"]:
        assert r["submitted"] == r["completed"] + r["rejected"] + r["backlog"]
        total_rejected += r["rejected"]
    assert total_rejected > 0                   # the overload actually bit


# ---------------------------------------------------------------------------
# Lockstep simulated time
# ---------------------------------------------------------------------------
def test_global_event_order_is_monotone():
    eng = ClusterEngine(list(PAPER_JOBS[:4]), gpu_fleet(2),
                        controller_factory=_static_factory())
    eng.run(sim_time_limit=10.0)
    times = [t for t, _ in eng.event_log]
    assert times == sorted(times)
    assert len({jid for _, jid in eng.event_log}) == 4   # all jobs ran


def test_per_job_clocks_strictly_increase():
    eng = ClusterEngine(JOBS2, gpu_fleet(2),
                        controller_factory=_static_factory())
    eng.run(sim_time_limit=10.0)
    for st in eng.states:
        trace_t = [t for t, *_ in st.acc.trace]
        assert all(b > a for a, b in zip(trace_t, trace_t[1:]))
        assert st.clock == pytest.approx(trace_t[-1])


def test_instance_stalls_accounted_globally_and_per_job():
    eng = ClusterEngine([PAPER_JOBS[0]], gpu_fleet(1),
                        controller_factory=_static_factory(mtl=4),
                        instance_launch_s=2.0)
    eng.run(sim_time_limit=5.0)
    assert eng.stall_time == pytest.approx(2.0 * 3)      # 1 -> 4 instances
    assert eng.states[0].stall_time == pytest.approx(2.0 * 3)


# ---------------------------------------------------------------------------
# Contention: a neighbour can only ever slow you down
# ---------------------------------------------------------------------------
def test_adding_a_job_never_increases_another_jobs_throughput():
    alone = ClusterEngine([PAPER_JOBS[0]], gpu_fleet(1),
                          controller_factory=_static_factory(), seed=0)
    ra = alone.run(sim_time_limit=30.0)["per_job"][0]
    shared = ClusterEngine(JOBS2, gpu_fleet(1),
                           controller_factory=_static_factory(), seed=0)
    rs = next(r for r in shared.run(sim_time_limit=30.0)["per_job"]
              if r["job_id"] == PAPER_JOBS[0].job_id)
    assert rs["throughput"] <= ra["throughput"] * 1.001


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------
def test_placement_covers_all_jobs_and_prefers_feasibility():
    fleet = gpu_fleet(4)
    assign = place(list(PAPER_JOBS[:8]), fleet)
    assert len(assign) == 8
    assert all(0 <= d < 4 for d in assign)
    # the tightest-SLO job of the batch should not share with 3+ others
    tight = min(range(8), key=lambda i: PAPER_JOBS[i].slo_s)
    assert assign.count(assign[tight]) <= 3


def test_tpu_submesh_fleet_runs():
    fleet = [DeviceSpec(device=dm.TPU_V5E, mesh_shape=(4, 4), name="pod0")]
    eng = ClusterEngine(JOBS2, fleet, controller_factory=_static_factory())
    rep = eng.run(sim_time_limit=5.0)
    assert all(r["completed"] > 0 for r in rep["per_job"])


# ---------------------------------------------------------------------------
# Real-executor cluster mode: two tiny wall-clock models under the same
# lockstep event loop (smoke scale — closes the ROADMAP item)
# ---------------------------------------------------------------------------
def test_real_executor_cluster_smoke():
    import jax
    import jax.numpy as jnp
    from repro.serving.executor import RealExecutor

    def make_real(width):
        w = jax.random.normal(jax.random.PRNGKey(width), (width, width))

        def fn(params, batch):
            return jnp.tanh(batch["x"] @ params).sum()

        def make_batch(n):
            return {"x": jnp.ones((n, width), jnp.float32)}

        return RealExecutor(fn, w, make_batch)

    execs = {}

    def factory(job, spec, share, mesh, seed):
        # one wall-clock executor per job (16- and 32-wide models);
        # serving and profiling probes share the AOT executable cache
        if job.job_id not in execs:
            execs[job.job_id] = make_real(16 * (1 + len(execs)))
        return execs[job.job_id]

    eng = ClusterEngine(JOBS2, gpu_fleet(2),
                        controller_factory=_static_factory(bs=2),
                        executor_factory=factory)
    # warmup under the lockstep event loop: both jobs pop in global clock
    # order and compile their bucket executable exactly once.  (The loop
    # then rightly favours whichever job's clock the compile stall left
    # behind, so steady state is driven per job below.)
    eng.run(sim_time_limit=1e9, max_steps=60)
    assert len(execs) == 2
    assert {jid for _, jid in eng.event_log} == \
        {j.job_id for j in JOBS2}
    assert all(ex.cache_stats.misses > 0 for ex in execs.values())
    for ex in execs.values():
        ex.cache_stats.reset_counters()
    for _ in range(20):                               # steady state
        for st in eng.states:
            eng._step(st)
    rep = eng.report()
    # zero recompiles after warmup: every step reuses an AOT executable
    for ex in execs.values():
        assert ex.cache_stats.misses == 0
        assert ex.cache_stats.hits > 0
    # per-job clocks advance strictly monotonically on wall-clock steps
    for st in eng.states:
        trace_t = [t for t, *_ in st.acc.trace]
        assert all(b > a for a, b in zip(trace_t, trace_t[1:]))
    for r in rep["per_job"]:
        assert r["completed"] > 0
        assert r["submitted"] == r["completed"]       # closed loop


# ---------------------------------------------------------------------------
# End-to-end policy smoke (kept tiny; the full 30-job run lives in
# examples/cluster_serve.py and benchmarks)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_hybrid_not_worse_than_paper_on_mixed_slice():
    jobs = [PAPER_JOBS[i] for i in (0, 3, 4, 5)]   # MT-heavy slice
    fleet = gpu_fleet(2)
    rep_a = run_paper_cluster("auto", jobs=jobs, fleet=fleet,
                              sim_time_limit=120.0)
    rep_h = run_paper_cluster("hybrid", jobs=jobs, fleet=fleet,
                              sim_time_limit=120.0)
    thr_a = rep_a["aggregate"]["aggregate_throughput"]
    thr_h = rep_h["aggregate"]["aggregate_throughput"]
    assert thr_h >= 0.95 * thr_a
