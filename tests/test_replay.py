"""Trace recording + counterfactual replay: determinism (record -> replay
under the unchanged policy reproduces report() exactly), serializer round
trips, and the what-if policy dispatch."""

import dataclasses
import json

import pytest

from repro.perf.profile_store import ProfileStore
from repro.serving import replay as rp
from repro.serving.cluster import (DeviceSpec, gpu_fleet, run_churn_cluster,
                                   run_paper_cluster)
from repro.serving.workload import PAPER_JOBS, ChurnJob, churn_trace


@pytest.fixture()
def store(tmp_path):
    return ProfileStore(str(tmp_path / "store"))


def _roundtrip(trace):
    """What the profile store does to a trace: a JSON disk round trip.
    Python floats survive it bit-exactly, so replay sees the same inputs."""
    return json.loads(json.dumps(trace))


def test_serializers_round_trip():
    job = dataclasses.replace(PAPER_JOBS[3], job_id=77)
    assert rp.deserialize_job(_roundtrip(rp.serialize_job(job))) == job
    e = ChurnJob(job=job, admit_s=3.25, depart_s=None, arrival_rate=12.5)
    assert rp.deserialize_churn(_roundtrip(rp.serialize_churn(e))) == e
    for spec in (gpu_fleet(1)[0],
                 DeviceSpec(device=gpu_fleet(1)[0].device,
                            mesh_shape=(4, 4), name="tpu0")):
        assert rp.deserialize_spec(_roundtrip(rp.serialize_spec(spec))) \
            == spec


def test_record_then_replay_reproduces_report_exactly(store):
    trace = churn_trace(horizon_s=40.0, n_initial=3, n_churn=4,
                        mean_lifetime_s=15.0, seed=1)
    rep = run_churn_cluster("dynamic", trace=trace, n_devices=3,
                            horizon_s=40.0, seed=1,
                            record="t1", record_store=store)
    recorded = _roundtrip(rp.load_trace(store, "t1"))
    assert recorded["version"] == rp.TRACE_VERSION
    assert recorded["init"]["meta"] == {"entry": "churn",
                                        "policy": "dynamic",
                                        "mode": "hybrid"}
    assert recorded["event_count"] > 0
    assert rp.replay_run(recorded) == rep
    # and through the vectorized engine: conformance makes it identical too
    assert rp.replay_run(recorded, vectorized=True) == rep


def test_record_persists_to_disk(store):
    trace = churn_trace(horizon_s=30.0, n_initial=2, n_churn=2, seed=3)
    rep = run_churn_cluster("dynamic", trace=trace, n_devices=2,
                            horizon_s=30.0, seed=3,
                            record="t2", record_store=store)
    # a FRESH store object reading the same root must replay identically
    reread = ProfileStore(store.root)
    assert rp.replay_run(rp.load_trace(reread, "t2")) == rep


def test_replay_paper_entry(store):
    rep = run_paper_cluster("hybrid", jobs=PAPER_JOBS[:6],
                            fleet=gpu_fleet(3), sim_time_limit=20.0,
                            seed=0, record="p1", record_store=store)
    recorded = _roundtrip(rp.load_trace(store, "p1"))
    assert recorded["init"]["meta"]["entry"] == "paper"
    assert rp.replay_run(recorded) == rep


def test_replay_counterfactuals(store):
    trace = churn_trace(horizon_s=40.0, n_initial=3, n_churn=4,
                        mean_lifetime_s=15.0, seed=1)
    run_churn_cluster("dynamic", trace=trace, n_devices=3,
                      horizon_s=40.0, seed=1,
                      record="t3", record_store=store)
    recorded = _roundtrip(rp.load_trace(store, "t3"))

    fewer = rp.replay_run(recorded, policy="fewer-devices")
    assert fewer["aggregate"]["devices"] == 2      # 80% of 3, floored

    mt = rp.replay_run(recorded, policy="uniform-mtl")
    assert mt["aggregate"]["mode"] == "MT"

    mig = rp.replay_run(recorded, policy="mig")
    assert mig["aggregate"]["partition"] == "mig"

    with pytest.raises(ValueError):
        rp.replay_run(recorded, policy="no-such-policy")


def test_replay_diff_table(store):
    trace = churn_trace(horizon_s=30.0, n_initial=2, n_churn=3, seed=2)
    run_churn_cluster("dynamic", trace=trace, n_devices=2,
                      horizon_s=30.0, seed=2,
                      record="t4", record_store=store)
    recorded = _roundtrip(rp.load_trace(store, "t4"))
    rows = rp.replay_diff(recorded,
                          policies=("baseline", "fewer-devices"))
    assert [r["policy"] for r in rows] == ["recorded", "baseline",
                                           "fewer-devices"]
    # determinism again, through the diff path
    assert rows[1]["goodput"] == rows[0]["goodput"]
    assert rows[1]["goodput_vs_recorded"] == 1.0
    table = rp.diff_table(rows)
    assert table.count("\n") == len(rows) + 1      # header + rule + rows
    assert "fewer-devices" in table


def test_missing_trace_raises(store):
    with pytest.raises(KeyError):
        rp.load_trace(store, "nope")
