"""Vectorized lockstep simulator: conformance with the object-based
reference engine, the bulk fast path's statistical agreement, fleet-wide
pricing, max_steps truncation reporting, the serve-time feasibility
snapshot, and the arrival/window accounting property tests."""

import dataclasses

import numpy as np
import pytest

from repro.core.controller import StaticController
from repro.serving import device_model as dm
from repro.serving.cluster import (ClusterEngine, VectorClusterEngine,
                                   gpu_fleet, paper_controller_factory,
                                   run_churn_cluster, run_partition_cluster)
from repro.serving.engine import OpenLoopQueue
from repro.serving.metrics import TailLatencyWindow
from repro.serving.workload import (PAPER_JOBS, ChurnJob, churn_trace,
                                    mixed_partition_trace)


def _static_cf(job, ex):
    return StaticController(bs=8, mtl=1)


# ---------------------------------------------------------------------------
# conformance: the vectorized engine must be BIT-identical to the reference
# (same reports, same event order, same churn log) — argmin over the clock
# array replaces the heap, nothing else may change.
# ---------------------------------------------------------------------------
def _pair(jobs, fleet, *, seed=0, **kw):
    eo = ClusterEngine(jobs, list(fleet), seed=seed, **kw)
    ev = VectorClusterEngine(jobs, list(fleet), seed=seed, **kw)
    return eo, ev


def _assert_identical(eo, ev, ro, rv):
    assert ro == rv
    assert eo.event_log == ev.event_log
    assert eo.churn_log == ev.churn_log
    assert eo.steps_run == ev.steps_run


def test_vector_conformance_paper_scenario():
    jobs = PAPER_JOBS[:12]
    eo, ev = _pair(jobs, gpu_fleet(5),
                   controller_factory=paper_controller_factory("hybrid"))
    _assert_identical(eo, ev, eo.run(sim_time_limit=30.0),
                      ev.run(sim_time_limit=30.0))
    assert len(eo.event_log) > 100     # the scenario actually stepped


@pytest.mark.parametrize("policy", ["dynamic", "surface"])
def test_vector_conformance_churn_scenario(policy):
    trace = churn_trace(horizon_s=40.0, n_initial=3, n_churn=4,
                        mean_lifetime_s=15.0, seed=1)
    ro = run_churn_cluster(policy, trace=list(trace), n_devices=3,
                           horizon_s=40.0, seed=1)
    rv = run_churn_cluster(policy, trace=list(trace), n_devices=3,
                           horizon_s=40.0, seed=1, vectorized=True)
    assert ro == rv
    assert ro["aggregate"]["admissions"] > 0


def test_vector_conformance_partition_scenario():
    trace = mixed_partition_trace(horizon_s=40.0, n_light=3, seed=1)
    ro = run_partition_cluster("het", trace=list(trace), n_devices=2,
                               horizon_s=40.0, seed=1)
    rv = run_partition_cluster("het", trace=list(trace), n_devices=2,
                               horizon_s=40.0, seed=1, vectorized=True)
    assert ro == rv


@pytest.mark.slow
def test_vector_conformance_bench_cluster_full():
    """The BENCH_cluster scenario (12 jobs x 5 devices, 90 s) under every
    controller mode, pinned bit-identical."""
    jobs = PAPER_JOBS[:12]
    for mode in ("auto", "hybrid", "B", "MT", "clipper"):
        eo, ev = _pair(jobs, gpu_fleet(5),
                       controller_factory=paper_controller_factory(mode))
        _assert_identical(eo, ev, eo.run(sim_time_limit=90.0),
                          ev.run(sim_time_limit=90.0))


@pytest.mark.slow
def test_vector_conformance_bench_churn_full():
    """The BENCH_churn scenario (14 tenancies on 5 devices, 120 s) under
    every placement policy, pinned bit-identical."""
    trace = churn_trace(horizon_s=120.0, n_initial=4, n_churn=10,
                        mean_lifetime_s=30.0, seed=1)
    for policy in ("union", "dynamic", "surface"):
        ro = run_churn_cluster(policy, trace=list(trace), n_devices=5,
                               horizon_s=120.0, seed=1)
        rv = run_churn_cluster(policy, trace=list(trace), n_devices=5,
                               horizon_s=120.0, seed=1, vectorized=True)
        assert ro == rv


# ---------------------------------------------------------------------------
# the bulk fast path (static fleets): statistically equivalent, not
# bit-identical — same latency law, chunked RNG
# ---------------------------------------------------------------------------
def _static_scenario(n):
    jobs = [dataclasses.replace(PAPER_JOBS[0], job_id=10_000 + i)
            for i in range(n)]
    return jobs, gpu_fleet(n)


def test_bulk_path_statistical_agreement():
    jobs, fleet = _static_scenario(20)
    eo, ev = _pair(jobs, fleet, controller_factory=_static_cf)
    ro = eo.run(sim_time_limit=2.0)
    rv = ev.run(sim_time_limit=2.0)
    ao, av = ro["aggregate"], rv["aggregate"]
    assert not ao["truncated"] and not av["truncated"]
    assert ao["conserved"] and av["conserved"]
    ratio = av["aggregate_throughput"] / ao["aggregate_throughput"]
    assert 0.97 < ratio < 1.03
    # the bulk path really engaged (it prices whole fleets per round, so
    # its event_log stays empty)
    assert not ev.event_log and len(eo.event_log) > 100


def test_bulk_falls_back_to_exact_near_step_budget():
    """When the step budget would truncate the run, the bulk path must
    decline (truncation semantics stay honest) — and the exact vector path
    is then bit-identical to the reference, truncated flag included."""
    jobs, fleet = _static_scenario(5)
    eo, ev = _pair(jobs, fleet, controller_factory=_static_cf)
    ro = eo.run(sim_time_limit=5.0, max_steps=40)
    rv = ev.run(sim_time_limit=5.0, max_steps=40)
    assert ro == rv
    assert ro["aggregate"]["truncated"] is True


# ---------------------------------------------------------------------------
# fleet-wide pricing: one vectorized call == the scalar loop
# ---------------------------------------------------------------------------
def test_fleet_step_latency_matches_scalar_loop():
    devices, profiles = [], []
    for i, j in enumerate(PAPER_JOBS[:10]):
        devices.append(dm.TESLA_P40 if i % 2 else dm.TESLA_P40.share(0.5))
        profiles.append(j.profile())
    for bs, mtl in ((1, 1), (8, 1), (4, 3), (32, 10)):
        got = dm.fleet_step_latency(devices, profiles, bs, mtl)
        want = np.array([dm.mt_latency(d, p, bs, mtl)
                         for d, p in zip(devices, profiles)])
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=0.0)
    # mtl=1 degenerates to the batch path up to exact IEEE identities
    got1 = dm.fleet_step_latency(devices, profiles, 8, 1)
    want1 = np.array([dm.batch_latency(d, p, 8)
                      for d, p in zip(devices, profiles)])
    assert np.array_equal(got1, want1)


# ---------------------------------------------------------------------------
# max_steps truncation is reported, not silent
# ---------------------------------------------------------------------------
def test_truncated_flag_set_when_step_budget_hit():
    jobs = PAPER_JOBS[:4]
    eng = ClusterEngine(jobs, gpu_fleet(2),
                        controller_factory=_static_cf, seed=0)
    rep = eng.run(sim_time_limit=60.0, max_steps=20)
    assert rep["aggregate"]["truncated"] is True
    assert eng.steps_run == 20


def test_truncated_flag_clear_on_horizon_completion():
    jobs = PAPER_JOBS[:4]
    eng = ClusterEngine(jobs, gpu_fleet(2),
                        controller_factory=_static_cf, seed=0)
    rep = eng.run(sim_time_limit=2.0)
    assert rep["aggregate"]["truncated"] is False


def test_bench_check_fails_on_truncated_row(tmp_path, monkeypatch):
    """--check must flag a fresh row carrying truncated=1 even when every
    gated metric still clears its threshold."""
    import json

    from benchmarks import run as brun

    def fake_suite():
        return [("fake/row", 0.0, "thr=100.0/s,truncated=1")]

    monkeypatch.setattr(brun, "suites", lambda: {"fake": fake_suite})
    (tmp_path / "BENCH_fake.json").write_text(json.dumps({
        "suite": "fake",
        "rows": [{"name": "fake/row", "us_per_call": 0.0,
                  "derived": "thr=100.0/s"}],
    }))
    assert brun.check_against(str(tmp_path)) >= 1


# ---------------------------------------------------------------------------
# feasibility snapshot: report() reflects the placement the job was
# actually served under, not whatever co-residents exist at report time
# ---------------------------------------------------------------------------
def test_feasibility_snapshot_survives_later_coresidents():
    # a compute-bound profile whose bs=1 latency sits just under the SLO
    # on a whole Tesla P40 but blows through it on a 1/4 slice (the
    # steady-state floor scales with 1/share)
    prof = dm.JobProfile(name="steady-bound", host_ms=0.1, gpu1_ms=3.0,
                         amort=0.3, flops=26.0e9, param_bytes=50e6)
    tight = dataclasses.replace(PAPER_JOBS[0], job_id=501, slo_ms=4.0,
                                profile_override=prof)
    churn = [ChurnJob(job=tight, admit_s=0.0, depart_s=10.0)]
    # after the tight job departs, a crowd lands on the same device
    for k in range(3):
        churn.append(ChurnJob(
            job=dataclasses.replace(PAPER_JOBS[2], job_id=510 + k),
            admit_s=20.0, depart_s=None))
    eng = ClusterEngine([], gpu_fleet(1), churn=churn,
                        controller_factory=_static_cf, seed=0)
    rep = eng.run(sim_time_limit=40.0)
    row = next(r for r in rep["per_job"] if r["job_id"] == 501)
    # served alone -> feasible; the stale recomputation would price it
    # against the 3 co-residents it never shared the device with
    assert row["feasible"] is True
    assert eng._feasible_now(0) is False


# ---------------------------------------------------------------------------
# piecewise arrival integral (OpenLoopQueue bugfix): the Poisson mean is
# the integral of rate_fn over the window, not rate_fn(win_start) * window
# ---------------------------------------------------------------------------
def test_expected_arrivals_constant_rate_bit_identical():
    q_off = OpenLoopQueue(lambda t: 7.5, max_queue=10, seed=0)
    q_on = OpenLoopQueue(lambda t: 7.5, max_queue=10, seed=0,
                         piecewise_s=0.37)
    for a, b in ((0.0, 1.0), (2.0, 13.5), (5.0, 5.0), (3.0, 2.0)):
        assert q_off.expected_arrivals(a, b) == q_on.expected_arrivals(a, b)
        if b > a:
            assert q_on.expected_arrivals(a, b) == 7.5 * (b - a)


def test_expected_arrivals_piecewise_matches_brute_force():
    def rate(t):
        return 20.0 + 15.0 * np.sin(0.7 * t)

    q = OpenLoopQueue(rate, max_queue=10, seed=0, piecewise_s=0.05)
    trapezoid = getattr(np, "trapezoid", None) or np.trapz
    for a, b in ((0.0, 4.0), (1.3, 9.7), (6.0, 6.4)):
        tt = np.linspace(a, b, 20001)
        want = float(trapezoid([rate(t) for t in tt], tt))
        got = q.expected_arrivals(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-3)


def test_expected_arrivals_burst_boundary_not_mispriced():
    """The original bug: a stall-stretched window that starts in the burst
    phase was priced at the burst rate for its WHOLE length."""
    period, burst, base = 30.0, 60.0, 20.0

    def rate(t):
        return burst if (t % period) / period < 0.3 else base

    legacy = OpenLoopQueue(rate, max_queue=10, seed=0)
    fixed = OpenLoopQueue(rate, max_queue=10, seed=0,
                          piecewise_s=period / 8.0)
    # window [0, 30]: 30% at 60/s + 70% at 20/s = 960 expected arrivals
    exact = 0.3 * period * burst + 0.7 * period * base
    assert legacy.expected_arrivals(0.0, period) == burst * period  # 1800
    got = fixed.expected_arrivals(0.0, period)
    # trapezoid knots straddle the jump; error bounded by one segment
    assert abs(got - exact) < (burst - base) * (period / 8.0)
    assert abs(got - exact) < 0.2 * abs(burst * period - exact)

    # a queue that REGISTERS the jump points gets the exact left-Riemann
    # integral — no residual mispricing at all, on any window
    def breaks(a, b):
        out, t = [], np.floor(a / period) * period
        while t <= b:
            for x in (t, t + 0.3 * period):
                if a < x < b:
                    out.append(x)
            t += period
        return out

    stepped = OpenLoopQueue(rate, max_queue=10, seed=0, step_breaks=breaks)
    assert abs(stepped.expected_arrivals(0.0, period) - exact) <= 1e-9
    # hand-integrated windows straddling jumps at odd offsets:
    # [3, 47.5]: 6s@60 + 21s@20 + 9s@60 + 8.5s@20
    assert abs(stepped.expected_arrivals(3.0, 47.5)
               - (360.0 + 420.0 + 540.0 + 170.0)) <= 1e-9
    # [8.9, 9.1] straddles the burst-off edge at 9.0
    assert abs(stepped.expected_arrivals(8.9, 9.1)
               - (0.1 * burst + 0.1 * base)) <= 1e-9
    # constant sub-window: bit-identical to the single-point product
    assert stepped.expected_arrivals(10.0, 20.0) \
        == legacy.expected_arrivals(10.0, 20.0)


def test_poisson_split_statistical_agreement():
    """Sampling arrivals in one window == splitting the window into
    sub-intervals (Poisson superposition), in expectation."""
    def rate(t):
        return 40.0 if t < 5.0 else 10.0

    means = []
    for seed in range(300):
        q = OpenLoopQueue(rate, max_queue=10**9, seed=seed,
                          piecewise_s=1.0)
        q.step(0.0, 10.0, 0)
        means.append(q.submitted)
    mean_target = q.expected_arrivals(0.0, 10.0)
    # the trapezoid knot straddling the jump shaves the exact 250 to 235;
    # the sampler must hit ITS integral, and that integral must be within
    # one segment's worth of the exact one
    assert abs(mean_target - 250.0) <= (40.0 - 10.0) * 1.0 / 2.0
    assert abs(np.mean(means) - mean_target) < 3 * np.sqrt(250.0 / 300)


# ---------------------------------------------------------------------------
# TailLatencyWindow.add_many wrap-around property: whatever the call
# pattern, p95 == np.quantile over the last `window` of the full stream
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tail_window_oversize_add_many_matches_quantile(seed):
    rng = np.random.default_rng(seed)
    win = TailLatencyWindow(window=50)
    stream: list = []
    # first call alone exceeds the window, then assorted smaller calls
    sizes = [120] + [int(x) for x in rng.integers(1, 60, size=12)]
    for sz in sizes:
        batch = rng.exponential(0.05, size=sz)
        win.add_many(batch)
        stream.extend(batch.tolist())
        want = float(np.quantile(np.asarray(stream[-50:]), 0.95))
        np.testing.assert_allclose(win.p95, want, rtol=1e-12)
        assert len(win) == min(len(stream), 50)
