"""Learned HLO cost model (perf.cost_model): training, persistence with
staleness eviction, the zero-probe prediction tier's no-promises contract,
and the warm-start acceptance scenario."""

import numpy as np
import pytest

from benchmarks.costmodel_benches import (BS_GRID, DEVICE_CLASS, MAX_MTL,
                                          _dense_records, _paper_pairs,
                                          _store_excluding, _truth_grid,
                                          warmstart_scenario)
from repro.core.matrix_completion import SurfaceLibrary
from repro.perf import cost_model as cm
from repro.perf.profile_store import ProfileStore
from repro.serving import device_model as dm

MTLS = tuple(range(1, MAX_MTL + 1))


@pytest.fixture(scope="module")
def records():
    return _dense_records(_paper_pairs())


@pytest.fixture(scope="module")
def model(records):
    m = cm.train_cost_model(_store_excluding(records, ""), DEVICE_CLASS)
    assert m is not None
    return m


# -- training + prediction ---------------------------------------------------
def test_train_refuses_below_min_rows(tmp_path, records):
    st = ProfileStore(str(tmp_path))
    for sk, rec in list(records.items())[:3]:
        st.put("surfaces", sk, rec)
    assert cm.train_cost_model(st, DEVICE_CLASS) is None
    assert cm.train_cost_model(st, "unknown-device-class") is None


def test_heldout_prediction_within_paper_contract(records):
    """Spot-check three architecture-family folds of the full LOO the
    costmodel bench pins: the held-out surface must be finite, positive,
    and within the <= 0.30 median relative error contract."""
    for dnn, ds in (("mobilenet_v1_05", "imagenet"),
                    ("resnet_v2_101", "caltech"),
                    ("inception_v2", "imagenet")):
        sig = f"{dnn}/{ds}"
        fold = cm.train_cost_model(_store_excluding(records, sig),
                                   DEVICE_CLASS)
        assert sig not in fold.train_signatures
        est = np.asarray(fold.predict_surface(
            cm.features_for_signature(sig), BS_GRID, MTLS))
        assert np.isfinite(est).all() and (est > 0).all()
        truth = _truth_grid(dnn, ds)
        assert np.median(np.abs(est - truth) / truth) <= 0.30, sig


def test_features_for_signature_covers_paper_table():
    for dnn, ds in _paper_pairs():
        feat = cm.features_for_signature(f"{dnn}/{ds}")
        assert feat is not None
        vec = feat.vector(dm.TESLA_P40.peak_flops, dm.TESLA_P40.hbm_bw)
        assert vec.shape == (cm.FEATURE_DIM,) and np.isfinite(vec).all()
    assert cm.features_for_signature("no-such-arch/imagenet") is None


# -- persistence + staleness eviction (satellite: stale model bugfix) --------
def test_record_round_trip(model):
    clone = cm.CostModel.from_record(model.to_record())
    feat = cm.features_for_signature("resnet_v2_50/imagenet")
    np.testing.assert_allclose(
        np.asarray(clone.predict_surface(feat, BS_GRID, MTLS)),
        np.asarray(model.predict_surface(feat, BS_GRID, MTLS)))


def test_load_absent_record_is_a_noop(tmp_path):
    st = ProfileStore(str(tmp_path))
    assert cm.load_cost_model(st, DEVICE_CLASS) is None
    assert st.evictions == 0
    assert not (tmp_path / "profile_store.json").exists()


def test_malformed_record_evicted_at_load(tmp_path, model):
    st = ProfileStore(str(tmp_path))
    for wreck in (
        {"schema": cm.COST_MODEL_SCHEMA + 1},            # future schema
        dict(model.to_record(), W=[[0.0] * 3] * 2),      # wrong shape
        dict(model.to_record(), mu=[float("nan")] * cm.FEATURE_DIM),
        "not-a-dict",
    ):
        before = st.evictions
        cm.save_cost_model(st, model)
        st.put(cm.COST_MODEL_SECTION, DEVICE_CLASS, wreck)
        assert cm.load_cost_model(st, DEVICE_CLASS) is None
        # evicted, not just skipped: the poisoned record must never be
        # served again (nor re-judged on every boot)
        assert st.evictions == before + 1
        assert st.get(cm.COST_MODEL_SECTION, DEVICE_CLASS) is None


def test_stale_generation_evicted_only_when_tile_dependent(tmp_path,
                                                           records):
    st = ProfileStore(str(tmp_path))
    tuned = cm.train_cost_model(_store_excluding(records, ""), DEVICE_CLASS,
                                autotune_generation=1, tile_dependent=True)
    cm.save_cost_model(st, tuned)
    assert cm.load_cost_model(st, DEVICE_CLASS,
                              autotune_generation=1) is not None
    assert cm.load_cost_model(st, DEVICE_CLASS,
                              autotune_generation=2) is None
    assert st.evictions == 1
    # simulated-latency models (tile_dependent=False) survive re-tunes
    sim = cm.train_cost_model(_store_excluding(records, ""), DEVICE_CLASS)
    cm.save_cost_model(st, sim)
    assert cm.load_cost_model(st, DEVICE_CLASS,
                              autotune_generation=7) is not None


# -- the prediction tier: seed, never promise --------------------------------
def _library_with_model(model, key="job"):
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    lib.set_cost_model(model)
    lib.register_features(
        key, cm.features_for_signature("mobilenet_v2_1/imagenet"))
    return lib


def test_model_tier_serves_cold_library_with_no_support(model):
    lib = _library_with_model(model)
    pred = lib.predict("job")
    assert pred is not None and lib.last_tier == "model"
    est, support = pred
    assert est.shape == (len(BS_GRID), MAX_MTL)
    assert np.isfinite(est).all() and (est > 0).all()
    assert not support.any()         # a prior is never probed history


def test_allow_model_false_restricts_to_library_tier(model):
    lib = _library_with_model(model)
    assert lib.predict("job", allow_model=False) is None
    assert lib.last_tier is None


def test_model_tier_needs_registered_features(model):
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    lib.set_cost_model(model)
    assert lib.predict("never-registered") is None
    assert lib.last_tier is None


def test_cold_library_without_model_still_refuses(model):
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
    assert lib.predict("job") is None and lib.last_tier is None


def test_model_tier_respects_share_slicing(model):
    lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL,
                         share_values=(0.5, 1.0))
    lib.set_cost_model(model)
    lib.register_features(
        "job", cm.features_for_signature("mobilenet_v2_1/imagenet"))
    est, support = lib.predict("job", share=0.5)
    assert est.shape == (len(BS_GRID), MAX_MTL) and not support.any()
    # satellite bugfix: an off-grid rung is a DISTINCT rejection, even
    # when the model tier answered at the tensor level
    assert lib.predict("job", share=0.33) is None
    assert lib.last_reject == "share" and lib.last_tier is None


# -- acceptance: cold process reaches steady state in fewer probes -----------
@pytest.mark.slow
def test_warm_start_beats_refusal_path_in_probes(records):
    """A cold process with a trained model must reach the HybridScaler
    steady point for a held-out Table-4 architecture in strictly fewer
    probes than the similarity-only (library-refusal) path — with the
    no-promises invariants asserted inside the scenario (all-False
    support, no pinned frontier, same steady regime)."""
    probes_model, probes_refusal, steady, _ = warmstart_scenario(records)
    assert probes_model < probes_refusal
    assert steady[1] >= 2            # a real MT climb, not a trivial point


# ---------------------------------------------------------------------------
# OPSIG from the served module's own HLO: the gemma2-2b signature must
# resolve through a LIVE lowering (op counts and histogram from the real
# decode module, nothing like the static depth-scaled fingerprint), and
# fall back to the static table when lowering is unavailable
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_gemma2_decode_signature_resolves_via_live_hlo():
    from repro.configs.base import get_config

    cfg = get_config("gemma2-2b")
    static_n_ops, static_hist = cm._llm_opsig(cfg)
    feat = cm.features_for_signature("gemma2-2b/decode")
    assert feat is not None
    # a real lowered module has far more ops than 14 x num_layers, and
    # its op-class mix is measured, not the canned (0.55, 0.35, 0.10)
    assert feat.n_ops > 2 * static_n_ops
    assert feat.op_hist != pytest.approx(static_hist)
    assert abs(sum(feat.op_hist) - 1.0) < 1e-6
    assert feat.flops > 0
    # memoized: the second resolution is the same object, no re-lowering
    assert cm.features_for_signature("gemma2-2b/decode") is feat


def test_live_hlo_falls_back_to_static_fingerprint(monkeypatch):
    from repro.configs.base import get_config

    cfg = get_config("gemma2-2b")
    monkeypatch.setitem(cm._MODULE_FEATURES, ("gemma2-2b", "prefill"), None)
    monkeypatch.setattr("repro.perf.hlo_analysis.hlo_for_module",
                        lambda *a, **k: None)
    cm._MODULE_FEATURES.pop(("gemma2-2b", "prefill"), None)
    feat = cm.features_for_signature("gemma2-2b/prefill")
    assert feat is not None
    n_ops, hist = cm._llm_opsig(cfg)
    assert feat.n_ops == pytest.approx(n_ops)
    assert feat.op_hist == pytest.approx(hist)
    # don't leave the poisoned memo behind for other tests
    cm._MODULE_FEATURES.pop(("gemma2-2b", "prefill"), None)
