"""Per-architecture smoke tests (reduced same-family configs): one forward /
train step on CPU asserting shapes + finiteness, plus prefill/decode
consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, InputShape, get_config
from repro.models import api

SMOKE_SHAPE = InputShape("smoke", seq_len=64, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch, tiny=True)
    assert cfg.num_layers <= 6 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = api.init_params(rng, cfg)
    batch = api.make_batch(rng, cfg, SMOKE_SHAPE)

    def loss_fn(p):
        loss, m = api.train_loss(p, batch, cfg, remat=False)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), arch
    assert 0.0 < float(loss) < 20.0
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch, rng):
    cfg = get_config(arch, tiny=True)
    params = api.init_params(rng, cfg)
    batch = api.make_batch(rng, cfg, SMOKE_SHAPE)
    B = SMOKE_SHAPE.global_batch
    logits, cache = jax.jit(
        lambda p, b: api.prefill(p, b, cfg, capacity=96))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.asarray(64, jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t, q: api.decode_step(p, c, t, q, cfg))(
            params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["smollm_360m", "gemma2_2b", "mamba2_1p3b",
                                  "zamba2_1p2b", "mixtral_8x22b"])
def test_decode_matches_prefill(arch, rng):
    """Prefilling [t0..tN] must equal prefilling [t0..tN-1] then decoding tN."""
    cfg = get_config(arch, tiny=True)
    params = api.init_params(rng, cfg)
    T = 32
    tokens = jax.random.randint(rng, (1, T), 0, cfg.vocab_size, jnp.int32)

    full_logits, _ = api.prefill(params, {"tokens": tokens}, cfg, capacity=T + 4)
    part_logits, cache = api.prefill(params, {"tokens": tokens[:, :-1]}, cfg,
                                     capacity=T + 4)
    step_logits, _ = api.decode_step(params, cache, tokens[:, -1],
                                     jnp.asarray(T - 1, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-1, rtol=2e-1)
    # argmax agreement is the serving-relevant property
    assert int(jnp.argmax(step_logits)) == int(jnp.argmax(full_logits))


def test_training_reduces_loss():
    from repro.training.loop import train
    cfg = get_config("smollm_360m", tiny=True)
    out = train(cfg, steps=30, batch_size=4, seq_len=128, log_every=0)
    assert out["losses"][-1] < out["losses"][0] - 0.15


def test_checkpoint_roundtrip(tmp_path, rng):
    from repro.training import adamw, checkpoint
    cfg = get_config("smollm_360m", tiny=True)
    params = api.init_params(rng, cfg)
    opt = adamw.init(params)
    p = str(tmp_path / "ckpt.npz")
    checkpoint.save(p, 7, params, opt)
    step, params2, opt2 = checkpoint.load(p, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_param_count_analytic_close_to_actual():
    for arch in ("smollm_360m", "gemma2_2b", "mamba2_1p3b", "qwen3_moe_30b_a3b"):
        cfg = get_config(arch, tiny=True)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.25, (arch, actual, analytic)


def test_moe_aux_loss_and_capacity():
    from repro.models.moe import capacity
    assert capacity(256, 8, 2) >= 64
    cfg = get_config("qwen3_moe_30b_a3b", tiny=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = api.make_batch(jax.random.PRNGKey(1), cfg, SMOKE_SHAPE)
    loss, metrics = api.train_loss(params, batch, cfg, remat=False)
    assert float(metrics["aux"]) > 0.0  # load-balance loss active


@pytest.mark.parametrize("arch", ["smollm_360m", "mixtral_8x22b",
                                  "mamba2_1p3b", "gemma2_2b"])
def test_pallas_kernel_path_matches_xla(arch, rng):
    """kernel_impl='pallas' (interpret mode on CPU) must reproduce the XLA
    path end-to-end: prefill logits and one decode step."""
    cfg_x = get_config(arch, tiny=True)
    cfg_p = cfg_x.replace(kernel_impl="pallas")
    params = api.init_params(rng, cfg_x)
    tokens = jax.random.randint(rng, (2, 64), 0, cfg_x.vocab_size, jnp.int32)

    lx, cx = api.prefill(params, {"tokens": tokens}, cfg_x, capacity=96)
    lp, cp = api.prefill(params, {"tokens": tokens}, cfg_p, capacity=96)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(lx, np.float32), atol=3e-2, rtol=3e-2)

    tok = jnp.argmax(lx, -1).astype(jnp.int32)
    pos = jnp.asarray(64, jnp.int32)
    dx, _ = api.decode_step(params, cx, tok, pos, cfg_x)
    dp, _ = api.decode_step(params, cp, tok, pos, cfg_p)
    np.testing.assert_allclose(np.asarray(dp, np.float32),
                               np.asarray(dx, np.float32), atol=5e-2, rtol=5e-2)
    assert int(jnp.argmax(dp)) == int(jnp.argmax(dx))
