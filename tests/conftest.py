import os

# Tests run on the host's real device(s); the 512-device override belongs to
# launch/dryrun.py ONLY.  A couple of distribution tests spawn subprocesses
# that set their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
