import os

# Tests run on the host's real device(s); the 512-device override belongs to
# launch/dryrun.py ONLY.  A couple of distribution tests spawn subprocesses
# that set their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ---------------------------------------------------------------------------
# hypothesis compat shim: when the real package is missing (it is not baked
# into the container image — `pip install -r requirements-dev.txt` gets the
# real thing), install a minimal stand-in so the property-test modules still
# collect and run.  Property tests degrade to fixed-example tests: each
# strategy contributes its boundary values plus a midpoint, and @given runs
# the cartesian product of those examples.  This conftest is imported before
# any test module, so the fake lands in sys.modules in time.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import functools
    import itertools
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    def _integers(lo, hi):
        return _Strategy(sorted({lo, (lo + hi) // 2, hi}))

    def _floats(lo, hi):
        return _Strategy([lo, (lo + hi) / 2.0, hi])

    def _sampled_from(seq):
        seq = list(seq)
        idx = sorted({0, len(seq) // 2, len(seq) - 1})
        return _Strategy([seq[i] for i in idx])

    def _randoms(use_true_random=False):
        return _Strategy([random.Random(s) for s in (0, 1, 2)])

    def _given(*strats):
        def deco(fn):
            # NOT functools.wraps: pytest would introspect the wrapped
            # signature (via __wrapped__) and demand fixtures for the
            # strategy parameters — the wrapper must look zero-arg
            def run():
                for ex in itertools.product(*(s.examples for s in strats)):
                    fn(*ex)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

    def _settings(*args, **kw):
        def deco(fn):
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.randoms = _randoms
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
