"""Golden perf regression tests against the committed BENCH_*.json
baselines: the churn refactor (or any future one) must not silently shift
the static 30-job cluster numbers, and the churn suite's own baseline is
pinned the same way.  Uses the same comparison as
``python -m benchmarks.run --check`` so the gate is identical in CI and
on the command line."""

import json
import os

import pytest

from benchmarks.run import _parse_metrics, check_against

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _committed(suite):
    path = os.path.join(REPO, f"BENCH_{suite}.json")
    assert os.path.exists(path), f"missing committed baseline {path}"
    return json.load(open(path))


def test_parse_metrics():
    m = _parse_metrics("thr=2362.9/s,meet_slo=12/12,stall=158.6s")
    assert m["thr"] == pytest.approx(2362.9)
    assert m["stall"] == pytest.approx(158.6)
    assert _parse_metrics("x1.21") == {}


@pytest.mark.slow
def test_static_cluster_bench_matches_committed_baseline():
    """Re-run the 30-job static cluster bench and hold every throughput
    row within tolerance of the committed BENCH_cluster.json — the churn
    refactor must leave the static baseline untouched."""
    committed = _committed("cluster")
    baseline = {r["name"]: _parse_metrics(r["derived"])
                for r in committed["rows"]}
    assert any("thr" in v for v in baseline.values())
    assert check_against(REPO, tol=0.10, only={"cluster"}) == 0


@pytest.mark.slow
def test_churn_bench_matches_committed_baseline():
    assert check_against(REPO, tol=0.10, only={"churn"}) == 0


@pytest.mark.slow
def test_partition_bench_matches_committed_baseline():
    """The spatial-partitioning suite is pinned like cluster/churn: its
    deterministic goodput/thr rows must hold against BENCH_partition.json,
    and the committed baseline itself must show heterogeneous shares
    beating the uniform-MTL baseline."""
    committed = _committed("partition")
    rows = {r["name"]: _parse_metrics(r["derived"])
            for r in committed["rows"]}
    assert (rows["partition/het"]["goodput"]
            > rows["partition/uniform"]["goodput"])
    assert check_against(REPO, tol=0.10, only={"partition"}) == 0


@pytest.mark.slow
def test_scenarios_bench_matches_committed_baseline():
    """The scenario matrix is pinned like cluster/churn/partition: its
    deterministic goodput rows and lower-is-better jpg rows must hold
    against BENCH_scenarios.json, and the committed baseline itself must
    already show the matrix properties — every cell >= 0.95 attainment,
    conserved, and power-packed cells measurably cheaper per good request
    than spread at equal goodput."""
    committed = _committed("scenarios")
    rows = {r["name"]: _parse_metrics(r["derived"])
            for r in committed["rows"]}
    cells = {n: m for n, m in rows.items()
             if "attain" in m and "jpg" in m}
    assert len(cells) == 12                     # 3 traffics x 2 x 2
    for name, m in cells.items():
        assert m["attain"] >= 0.95, name
        assert "conserved=yes" in next(
            r["derived"] for r in committed["rows"] if r["name"] == name)
    for traffic in ("steady", "diurnal", "flash"):
        for cap in ("fixed", "spot"):
            pack = cells[f"scenarios/{traffic}/{cap}/pack"]
            spread = cells[f"scenarios/{traffic}/{cap}/spread"]
            assert pack["jpg"] < spread["jpg"]
            assert abs(pack["goodput"] - spread["goodput"]) \
                <= 0.02 * spread["goodput"]
    # the exact-vs-vector conformance row must be present and passing
    assert any(r["name"] == "scenarios/exact_vs_vector"
               and "bit_identical=True" in r["derived"]
               for r in committed["rows"])
    # re-running the suite (with its in-process asserts) must hold within
    # the same gate CI applies
    assert check_against(REPO, tol=0.10, only={"scenarios"}) == 0


@pytest.mark.slow
def test_costmodel_bench_matches_committed_baseline():
    """The cost-model suite is pinned like kernels: its deterministic
    leave-one-job-out `medrelerr=` row is compared under the
    lower-is-better envelope, the committed baseline itself must meet the
    <=0.30 held-out accuracy contract, and re-running exercises the
    warm-start scenario's in-process asserts (strict probe reduction,
    all-False support, no pinned frontier)."""
    committed = _committed("costmodel")
    rows = {r["name"]: _parse_metrics(r["derived"])
            for r in committed["rows"]}
    assert rows["costmodel/loo"]["medrelerr"] <= 0.30
    warm = next(m for n, m in rows.items() if "/warmstart/" in n)
    assert warm["probes_model"] < warm["probes_refusal"]
    assert check_against(REPO, tol=0.10, only={"costmodel"}) == 0


@pytest.mark.slow
def test_kernels_bench_matches_committed_baseline(tmp_path):
    """The kernels suite is gated too (closing the 'only cluster/churn
    are pinned' gap): its deterministic pallas-vs-reference `maxerr=`
    rows are compared under the lower-is-better envelope, and every
    committed row (including the autotuned ones) must still be produced.
    Runs against a cold autotune store in a tmpdir so the repo stays
    clean and the tuning path itself is exercised."""
    committed = _committed("kernels")
    assert any("maxerr" in _parse_metrics(r["derived"])
               for r in committed["rows"])
    from repro.perf import autotune
    prev = autotune._state["cache_dir"]      # restore the PRIOR state —
    #        pinning the default here would disable a REPRO_AUTOTUNE_CACHE
    #        env override for the rest of the pytest process
    autotune.configure(cache_dir=str(tmp_path))
    try:
        assert check_against(REPO, tol=0.10, only={"kernels"}) == 0
    finally:
        autotune._state["cache_dir"] = prev
        autotune._state["legacy_checked"] = None
        autotune.reset_counters()


@pytest.mark.slow
def test_cluster_bench_bit_identical_with_empty_profile_store(tmp_path):
    """The profile store must not perturb the static simulated path AT
    ALL: with an empty store, a fresh cluster-bench run reproduces every
    committed derived metric string byte for byte (the simulated engines
    are deterministic per seed — any drift means the store leaked into
    the pricing or control path)."""
    import os
    os.environ["REPRO_PROFILE_STORE"] = str(tmp_path)
    try:
        from benchmarks.paper_benches import bench_cluster
        fresh = {name: derived for name, _, derived in bench_cluster()}
    finally:
        os.environ.pop("REPRO_PROFILE_STORE", None)
    committed = _committed("cluster")
    for row in committed["rows"]:
        assert fresh.get(row["name"]) == row["derived"], row["name"]


@pytest.mark.slow
def test_disagg_bench_matches_committed_baseline():
    """The disagg suite is pinned like the other baselines, and the
    committed BENCH_disagg.json itself must already show the PR's
    contracts: fleet >= 1.3x the best single-device mode with both SLO
    attainments >= 0.95 on the gated cells, chunked >= 1.1x co-tenant
    TTFT attainment at equal TPOT, and exact fabric accounting."""
    committed = _committed("disagg")
    rows = {r["name"]: _parse_metrics(r["derived"])
            for r in committed["rows"]}
    text = {r["name"]: r["derived"] for r in committed["rows"]}

    fleet = next(m for n, m in rows.items() if n.startswith("disagg/fleet/"))
    assert fleet["ttft_attain"] >= 0.95 and fleet["tpot_attain"] >= 0.95
    assert rows["disagg/fleet_vs_single"]["speedup"] >= 1.3
    chunk = next(m for n, m in rows.items()
                 if n.startswith("disagg/chunked/"))
    assert chunk["ttft_attain"] >= 0.95 and chunk["tpot_attain"] >= 0.95
    assert rows["disagg/chunked_vs_cotenant"]["speedup"] >= 1.1
    assert "tpot_equal=yes" in text["disagg/chunked_vs_cotenant"]
    assert rows["disagg/fabric/ici_exact"]["maxerr"] <= 1e-12
    for name, derived in text.items():
        if "conserved=" in derived:
            assert "conserved=yes" in derived, name
    # re-running the suite (with its in-process contract asserts) must
    # hold within the same gate CI applies
    assert check_against(REPO, tol=0.10, only={"disagg"}) == 0


@pytest.mark.slow
def test_tokens_bench_bit_identical_with_disagg_off(tmp_path):
    """The disaggregation/chunked-prefill additions must be EXACT no-ops
    on the PR 9 token paths: with disagg off (the defaults), a fresh
    tokens-bench run reproduces every committed BENCH_tokens.json derived
    metric string byte for byte — engines are deterministic per seed, so
    any drift means the new knobs leaked into co-tenant/static pricing."""
    import os
    os.environ["REPRO_PROFILE_STORE"] = str(tmp_path)
    try:
        from benchmarks.token_benches import bench_tokens
        fresh = {name: derived for name, _, derived in bench_tokens()}
    finally:
        os.environ.pop("REPRO_PROFILE_STORE", None)
    committed = _committed("tokens")
    for row in committed["rows"]:
        assert fresh.get(row["name"]) == row["derived"], row["name"]
