"""Per-kernel correctness: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32) * 0.5
    return x.astype(dtype)


FLASH_CASES = [
    # (B, Tq, Tk, H, KV, hd, causal, window, cap)
    (2, 256, 256, 8, 2, 64, True, None, None),
    (1, 128, 128, 4, 4, 32, True, 64, None),
    (2, 200, 200, 6, 2, 64, True, None, 50.0),     # padding path
    (1, 256, 256, 8, 1, 128, True, 100, 30.0),     # MQA + window + cap
    (1, 96, 96, 8, 8, 32, False, None, None),      # bidirectional (encoder)
    (3, 384, 384, 15, 5, 64, True, None, None),    # smollm-like heads
    (2, 200, 200, 6, 2, 64, False, None, None),    # non-causal k-padding
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Tq, Tk, H, KV, hd, causal, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Tq, H, hd), dtype)
    k = _rand(ks[1], (B, Tk, KV, hd), dtype)
    v = _rand(ks[2], (B, Tk, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, logit_cap=cap)
    ref = attention_ref(q, k, v, causal=causal, window=window, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


DECODE_CASES = [
    (2, 512, 8, 2, 64, 300, None, None),
    (1, 512, 4, 1, 128, 511, 128, None),
    (3, 300, 6, 6, 32, 150, None, 50.0),
    (2, 1024, 48, 1, 64, 700, None, None),        # granite-like MQA
    (1, 256, 32, 4, 128, 0, None, None),          # first token
]


@pytest.mark.parametrize("case", DECODE_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    B, S, H, KV, hd, pos, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    p = jnp.asarray(pos, jnp.int32)
    out = decode_attention(q, k, v, p, window=window, logit_cap=cap)
    ref = decode_attention_ref(q, k, v, p, window=window, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


SSD_CASES = [
    (2, 256, 4, 64, 32, 64),
    (1, 128, 8, 32, 16, 128),
    (2, 512, 2, 64, 64, 128),
    (1, 256, 64, 64, 128, 64),                    # mamba2-1.3b-like head count
]


@pytest.mark.parametrize("case", SSD_CASES, ids=str)
def test_ssd_scan_matches_naive_recurrence(case):
    B, T, H, P, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.5
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=2e-3, rtol=2e-3)


def test_ssd_scan_respects_initial_state():
    B, T, H, P, N = 1, 128, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.5
    # split the sequence: full pass == two half passes chaining state
    from repro.models.mamba import ssd_chunked
    y_full, s_full = ssd_chunked(x, dt, A, Bm, Cm, 64)
    h = T // 2
    y1, s1 = ssd_chunked(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], 64)
    y2, s2 = ssd_chunked(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:], 64,
                         init_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("blocks", [(32, 32), (64, 64), (128, 64)])
def test_flash_attention_noncausal_kpad_explicit_blocks(blocks):
    """Non-divisible Tk with causal=False: pad keys must be masked, not
    rejected (the wrapper used to raise ValueError on this path)."""
    bq, bk = blocks
    B, Tq, Tk, H, KV, hd = 1, 100, 100, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, Tq, H, hd))
    k = jax.random.normal(ks[1], (B, Tk, KV, hd))
    v = jax.random.normal(ks[2], (B, Tk, KV, hd))
    assert Tk % bk != 0                     # really exercises the pad path
    out = flash_attention(q, k, v, causal=False, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_gradients_match_ref():
    B, T, H, KV, hd = 2, 160, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (B, T, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, T, KV, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, T, KV, hd)) * 0.5
    w = jax.random.normal(ks[3], (B, T, H, hd))
    from repro.models.layers import flash_attention as model_flash

    def f1(q, k, v):
        return jnp.sum(model_flash(q, k, v, causal=True, window=48,
                                   logit_cap=30.0, block_q=64, block_k=64) * w)

    def f2(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True, window=48,
                                     logit_cap=30.0) * w)

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)
