"""Paged decode-attention vs the ragged-batch oracle, plus the kv-major
wrapper on the ragged-adjacent shapes the paged variant stresses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention_kvmajor,
                                                paged_decode_attention,
                                                resolve_page_size)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                decode_attention_ref_ragged)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32) * 0.5
    return x.astype(dtype)


def _paged_from_dense(k_cache, v_cache, page_size, *, shuffle_key=None):
    """Chop a dense (B, S, KV, hd) cache into a (P, psz, KV, hd) pool and a
    block table; optionally scatter the pages so the table indirection is
    actually exercised."""
    B, S, KV, hd = k_cache.shape
    ns = S // page_size
    P = B * ns
    kp = k_cache.reshape(B, ns, page_size, KV, hd).reshape(P, page_size, KV, hd)
    vp = v_cache.reshape(B, ns, page_size, KV, hd).reshape(P, page_size, KV, hd)
    tbl = jnp.arange(P, dtype=jnp.int32).reshape(B, ns)
    if shuffle_key is not None:
        perm = jax.random.permutation(shuffle_key, P)
        inv = jnp.argsort(perm)
        kp, vp = kp[perm], vp[perm]
        tbl = inv.reshape(B, ns)
    return kp, vp, tbl


PAGED_CASES = [
    # (B, S, H, KV, hd, psz, lens, window, cap)
    (4, 512, 8, 2, 64, 64, (512, 300, 37, 1), None, None),   # ragged
    (1, 256, 4, 1, 128, 64, (200,), None, None),             # single slot, MQA
    (3, 384, 6, 3, 64, 128, (384, 129, 64), None, None),     # non-pow2 heads
    (2, 512, 8, 2, 64, 64, (500, 90), 128, None),            # sliding window
    (2, 256, 4, 4, 32, 32, (250, 31), None, 50.0),           # logit cap
    (3, 256, 8, 2, 64, 64, (256, 0, 10), None, None),        # freed slot
]


@pytest.mark.parametrize("case", PAGED_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_matches_ragged_ref(case, dtype):
    B, S, H, KV, hd, psz, lens, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = _rand(ks[0], (B, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    kp, vp, tbl = _paged_from_dense(k, v, psz, shuffle_key=ks[3])
    lens = jnp.asarray(lens, jnp.int32)
    out = paged_decode_attention(q, kp, vp, lens, tbl,
                                 window=window, logit_cap=cap)
    ref = decode_attention_ref_ragged(q, k, v, lens,
                                      window=window, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_paged_matches_dense_ref_when_uniform():
    """With every slot at the same length, the ragged path must agree with
    the original positional oracle (cache valid on [0, pos])."""
    B, S, H, KV, hd, psz, pos = 2, 256, 8, 2, 64, 64, 199
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    q = _rand(ks[0], (B, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
    kp, vp, tbl = _paged_from_dense(k, v, psz)
    lens = jnp.full((B,), pos + 1, jnp.int32)
    out = paged_decode_attention(q, kp, vp, lens, tbl)
    ref = decode_attention_ref(q, k, v, jnp.asarray(pos, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_ignores_garbage_in_unused_pages_and_table_entries():
    """Pages past a slot's length must not leak into the output even when
    the pool holds garbage there and the table points out of range."""
    B, S, H, KV, hd, psz = 2, 256, 4, 2, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = _rand(ks[0], (B, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
    lens = jnp.asarray([70, 128], jnp.int32)
    ref = decode_attention_ref_ragged(q, k, v, lens)

    kp, vp, tbl = _paged_from_dense(k, v, psz)
    ns = S // psz
    # poison every page at-or-past each slot's length...
    used = (lens + psz - 1) // psz
    page_used = (jnp.arange(ns)[None, :] < used[:, None]).reshape(-1)
    kp = jnp.where(page_used[:, None, None, None], kp, 1e4)
    vp = jnp.where(page_used[:, None, None, None], vp, 1e4)
    # ...and point the unused table entries far out of the pool
    tbl = jnp.where(jnp.arange(ns)[None, :] < used[:, None], tbl, 10_000)
    out = paged_decode_attention(q, kp, vp, lens, tbl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_resolve_page_size_prefers_explicit_then_default():
    assert resolve_page_size(jnp.float32, B=4, H=8, KV=2, hd=64,
                             seq_budget=1024, page_size=32) == 32
    ps = resolve_page_size(jnp.float32, B=4, H=8, KV=2, hd=64,
                           seq_budget=1024)
    assert ps in (32, 64, 128, 256)


# --- satellite: kv-major wrapper on the shapes the paged variant stresses ---

KVMAJOR_CASES = [
    # (B, S, H, KV, hd, pos, window, cap) — ragged/odd kv_len, non-pow2
    # heads, single-slot batches
    (2, 300, 8, 2, 64, 299, None, None),      # odd S: padding path
    (3, 300, 6, 3, 64, 150, None, None),      # non-pow2 heads
    (1, 512, 4, 1, 128, 37, None, None),      # single slot, short kv_len
    (1, 640, 12, 3, 64, 633, 128, None),      # single slot + window
    (2, 384, 10, 5, 32, 65, None, 40.0),      # non-pow2 heads + cap
    (1, 256, 8, 2, 64, 0, None, None),        # single slot, first token
]


@pytest.mark.parametrize("case", KVMAJOR_CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kvmajor_matches_ref(case, dtype):
    B, S, H, KV, hd, pos, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(14), 3)
    q = _rand(ks[0], (B, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    p = jnp.asarray(pos, jnp.int32)
    # the kv-major entry point takes the model's (B, KV, S, hd) layout
    out = decode_attention_kvmajor(q, k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3), p,
                                   window=window, logit_cap=cap)
    ref = decode_attention_ref(q, k, v, p, window=window, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)
