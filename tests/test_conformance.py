"""Sim/real conformance: the same tiny model served through SimExecutor
and RealExecutor under DNNScalerController must agree.

The paper's Table-4 claim is that the Profiler's Batching-vs-Multi-Tenancy
DECISION and the Scaler's steady-state knob transfer from profiling to
serving.  Here the claim is tested end to end on the real path: a tiny
model runs under a wall-clock RealExecutor; an analytic JobProfile is
calibrated to the real executor's measured latencies (exactly how
`device_model._fit_profile` calibrates against the paper's Table 5, with
wall-clock measurements in place of the published throughputs); then the
controller runs over BOTH executors and must pick the same approach and
land its steady-state knob within one probe step.  The real path serves
per-point RUNNING-MEDIAN latencies with a live-re-anchored SLO (see
MedianRealExecutor/_AnchoredSlo) so the converged knob reflects the
measured latency curve rather than a shared host's second-scale load
swings.

One modeled quantity is intentionally NOT asserted: the absolute MT-point
latency.  The paper's model serializes GPU time across co-located
instances (real GPU contexts time-share SMs), while RealExecutor emulates
MT by stacking instance batches on one leading axis — its MT latency
amortizes like batching.  What must (and does) transfer is the eq. (3)-(5)
improvement ORDERING, not that point's absolute value."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import DNNScalerController
from repro.serving import device_model as dm
from repro.serving.engine import ServingEngine
from repro.serving.executor import RealExecutor, SimExecutor

WIDTH, DEPTH = 512, 2
M, N = 32, 8                    # the profiler's probe points (paper: m, n)
# both searches are confined to the calibrated batch range: above ~64 the
# host's multithreaded BLAS makes real batching nearly free (flat
# latency), a hardware behavior outside the paper's per-image cost model
# — conformance is claimed where the model's premises hold
MAX_BS = 64
# the controllers' tail window: small enough that ONE wall-clock spike
# (which fills the window with identical values for several steps) is
# flushed within a decision interval — otherwise a single OS spike spans
# two decisions and defeats the paper's §4.4 short-lived-spike filter
WINDOW = 64


class MedianRealExecutor:
    """RealExecutor view that serves each (bs, mtl) point's MEDIAN
    measured latency (measured on first visit, remembered after).

    The paper's methodology: every operating point is judged on "a
    certain number of batches", not on one sample.  On a shared CI host
    the raw per-step noise is non-stationary (sigma drifts 0.05-0.4
    within minutes), which would make the converged knob a property of
    the moment's load rather than of the latency curve this test is
    about.  Execution, compiles, and the latencies themselves stay
    real — only the per-point aggregation is applied up front."""

    def __init__(self, ex: RealExecutor, reps: int = 3, keep: int = 15,
                 anchor: tuple = None, anchor_every: int = 10):
        self.ex = ex
        self.reps = reps
        self.keep = keep
        self.anchor = anchor          # (bs, mtl) kept fresh for the SLO
        self.anchor_every = anchor_every
        self._steps = 0
        self._samples: dict = {}

    def _record(self, key: tuple, lat: float) -> list:
        samples = self._samples.setdefault(key, [])
        samples.append(lat)
        del samples[:-self.keep]
        return samples

    def point_median(self, bs: int, mtl: int) -> float:
        return float(np.median(self._samples[(bs, mtl)]))

    def run_step(self, bs: int, mtl: int) -> dict:
        res = self.ex.run_step(bs, mtl)
        key = (bs, mtl)
        samples = self._record(key, res["step_time"])
        while len(samples) < self.reps:
            self._record(key, self.ex.run_step(bs, mtl)["step_time"])
        # RUNNING median: a point first visited during a load burst heals
        # on revisit instead of staying poisoned for the whole search
        med = self.point_median(bs, mtl)
        self._steps += 1
        if (self.anchor is not None and key != self.anchor
                and self._steps % self.anchor_every == 0):
            # interleaved anchor probe: the SLO's reference point stays
            # measured under the SAME load the serving steps see
            self._record(self.anchor,
                         self.ex.run_step(*self.anchor)["step_time"])
        items = bs * mtl
        res.update(step_time=med,
                   request_latencies=np.full(min(items, 64), med),
                   throughput=items / med)
        return res


def make_real_executor() -> RealExecutor:
    ks = jax.random.split(jax.random.PRNGKey(0), DEPTH)
    params = [jax.random.normal(k, (WIDTH, WIDTH)) * 0.05 for k in ks]

    def fn(params, batch):
        x = batch["x"]
        for w in params:
            x = jnp.tanh(x @ w)
        return x.sum()

    def make_batch(n):
        return {"x": jnp.ones((n, WIDTH), jnp.float32)}

    # unit buckets: the conformance claim is about the latency CURVE, so
    # the real path must not quantize it through the serving bucket ladder
    return RealExecutor(fn, params, make_batch,
                        buckets=tuple(range(1, 129)))


def _measure(ex: RealExecutor, bs: int, mtl: int) -> float:
    """Median of repeated mean-latency measurements (seconds) — one OS
    spike must not skew the calibration."""
    return float(np.median([ex.mean_latency(bs, mtl, iters=3)
                            for _ in range(5)]))


def fit_profile(lat1_s: float, lat_m_s: float, lat_hi_s: float,
                hi: int) -> dm.JobProfile:
    """Calibrate (host, gpu1, amort) to measured batch latencies at
    bs in {1, M, hi} — the grid fit of `_fit_profile` driven by wall-clock
    measurements.  Fitting the top of the batch range matters: that is
    where the Batching scaler's steady state lives."""
    base_ms = lat1_s * 1e3
    host = base_ms * np.linspace(0.05, 0.95, 46)[:, None]     # (46, 1)
    gpu1 = base_ms - host
    amort = np.linspace(0.0, 0.95, 39)[None, :]               # (1, 39)
    lat_m = M * (host * float(dm.rho(M)) + gpu1 * M ** (-amort)) / 1e3
    lat_hi = hi * (host * float(dm.rho(hi)) + gpu1 * hi ** (-amort)) / 1e3
    err = (np.log(lat_m / lat_m_s) ** 2 + np.log(lat_hi / lat_hi_s) ** 2)
    i, j = np.unravel_index(np.argmin(err), err.shape)
    return dm.JobProfile(name="conformance-mlp", host_ms=float(host[i, 0]),
                         gpu1_ms=float(gpu1[i, 0]),
                         amort=float(amort[0, j]),
                         flops=DEPTH * WIDTH * WIDTH * 2.0,
                         param_bytes=DEPTH * WIDTH * WIDTH * 4.0)


ANCHOR_BS = 48      # the SLO sits at the top of the band over lat(48):
#                     steady state lands mid-range of the calibrated curve


class _AnchoredSlo:
    """SLO = lat(48)/0.9 from the serving-path's OWN running-median pool,
    re-anchored live (25% hysteresis) as the host's load drifts.

    Each path anchors its SLO to its own measured lat(48) (the sim to the
    model's).  A shared absolute SLO would make the steady knob a
    function of host-load DRIFT between calibration and serving — on a
    contended host the whole curve breathes 1.5x over seconds — while the
    Table-4 claim under test is about the latency curve's SHAPE.  The
    hysteresis keeps re-anchors rare (every change resets the scaler's
    search, exactly as a real capacity change would)."""

    def __init__(self, served: MedianRealExecutor):
        self.served = served
        for _ in range(3):
            served.run_step(ANCHOR_BS, 1)
        self.slo = served.point_median(ANCHOR_BS, 1) / 0.9

    def __call__(self, t: float) -> float:
        fresh = self.served.point_median(ANCHOR_BS, 1) / 0.9
        if abs(fresh - self.slo) > 0.25 * self.slo:
            self.slo = fresh
        return self.slo


def _anchored_slo_sim(prof: dm.JobProfile) -> float:
    return dm.batch_latency(dm.TESLA_P40, prof, ANCHOR_BS) / 0.9


@pytest.fixture(scope="module")
def calibrated():
    """(real executor, fitted profile, calibration-time measurements)
    shared by the suite — the measurements are the expensive part."""
    ex = make_real_executor()
    measured = {bs: _measure(ex, bs, 1) for bs in (1, M, 48, MAX_BS, 128)}
    prof = fit_profile(measured[1], measured[M], measured[128], hi=128)
    return ex, prof, measured


def test_fitted_profile_reproduces_batch_curve(calibrated):
    """The fit's residual against the CALIBRATION-TIME measurements
    (including bs=48/64, which the fit never saw) must be small enough
    that both searches walk the same terrain.  Judged against the
    measurements the fit was built from — re-measuring minutes later
    would test the shared host's load stationarity, not the model.

    The strict bound covers the range the searches actually visit
    (<= MAX_BS); at bs=128 the model's per-image host term with its
    rho(bs) copy-pressure factor structurally overestimates this
    workload's flat real curve (multithreaded BLAS), so that anchor only
    gets a sanity bound."""
    _, prof, measured = calibrated
    for bs, lat in measured.items():
        model = dm.batch_latency(dm.TESLA_P40, prof, bs)
        if bs <= MAX_BS:
            assert model == pytest.approx(lat, rel=0.5), bs
        else:
            assert model == pytest.approx(lat, rel=2.0), bs


def test_profiler_decision_agrees_sim_vs_real(calibrated):
    """The paper's eq. (3)-(5) decision must not depend on which executor
    (analytic or wall-clock) ran the probes."""
    ex, prof, _ = calibrated
    served = MedianRealExecutor(ex)
    real = DNNScalerController(served, _AnchoredSlo(served).slo,
                               mode="auto", m=M, n=N, max_bs=MAX_BS)
    sim = DNNScalerController(SimExecutor(prof, seed=0),
                              _anchored_slo_sim(prof),
                              mode="auto", m=M, n=N, max_bs=MAX_BS)
    assert real.profile.approach == sim.profile.approach
    # and the improvement ORDERING agrees, not just the argmax
    assert ((real.profile.ti_b > real.profile.ti_mt)
            == (sim.profile.ti_b > sim.profile.ti_mt))


def _steady(engine: ServingEngine, ctrl, steps: int) -> tuple:
    acc = engine.run(ctrl, max_steps=steps)
    last = [(bs, mtl) for _, bs, mtl, *_ in acc.trace[-steps // 3:]]
    vals, counts = np.unique(np.array(last), axis=0, return_counts=True)
    return tuple(int(v) for v in vals[int(np.argmax(counts))])


def test_steady_state_knobs_within_one_probe_step(calibrated):
    """Serve the same workload to steady state on both executors: the
    dominant knob must land within ONE probe step — a binary-search
    midpoint move, i.e. a factor of two, plus a small allowance for the
    real path's measurement granularity — and the tenancy knob within
    +-1."""
    ex, prof, _ = calibrated
    served = MedianRealExecutor(ex, anchor=(ANCHOR_BS, 1))
    anchored = _AnchoredSlo(served)
    real_ctrl = DNNScalerController(served, anchored.slo, mode="auto",
                                    m=M, n=N, max_bs=MAX_BS)
    real_steady = _steady(
        ServingEngine(served, anchored.slo, instance_launch_s=0.01,
                      window=WINDOW, slo_schedule=anchored),
        real_ctrl, steps=400)
    slo_s_ = _anchored_slo_sim(prof)
    sim_ctrl = DNNScalerController(SimExecutor(prof, seed=0), slo_s_,
                                   mode="auto", m=M, n=N, max_bs=MAX_BS)
    sim_steady = _steady(
        ServingEngine(SimExecutor(prof, seed=1), slo_s_, window=WINDOW),
        sim_ctrl, steps=400)

    assert real_ctrl.profile.approach == sim_ctrl.profile.approach
    bs_r, mtl_r = real_steady
    bs_s, mtl_s = sim_steady
    assert abs(math.log2(max(bs_s, 1) / max(bs_r, 1))) <= 1.2, \
        (real_steady, sim_steady)
    assert abs(mtl_s - mtl_r) <= 1, (real_steady, sim_steady)
