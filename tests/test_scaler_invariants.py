"""Property-based invariant tests for the three scalers (Algorithm 1 and the
2-D HybridScaler), plus the Table-4 decision regression test.

Invariants pinned here:
  * knobs always stay in [1, max] under arbitrary p95 feedback;
  * no movement while p95 sits inside the [alpha*SLO, SLO] band;
  * `infeasible` is only reachable at bs == 1 (and mtl == 1 for Hybrid);
  * known-bad damping never re-probes a pinned point before the amnesty
    window, and re-probes it after.

With hypothesis installed these run randomized; without it the conftest
shim degrades them to fixed boundary/midpoint examples.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.controller import DNNScalerController
from repro.core.scaler import ALPHA, BatchScaler, HybridScaler, MTScaler
from repro.serving.executor import SimExecutor
from repro.serving.workload import PAPER_JOBS

SLO = 0.1


class _FixedEst:
    """pick_mtl stub: seed the scaler at a chosen MTL."""

    def __init__(self, mtl=5):
        self.mtl = mtl

    def pick_mtl(self, observed, slo):
        return self.mtl, np.zeros(10)


def _scalers(seed_mtl=5):
    return [
        BatchScaler(SLO, decision_interval=1),
        MTScaler(SLO, _FixedEst(seed_mtl), {1: 0.01}, decision_interval=1),
        HybridScaler(SLO, _FixedEst(seed_mtl), {1: 0.01}, primary="MT",
                     decision_interval=1),
        HybridScaler(SLO, decision_interval=1),   # primary B, seed (1, 1)
    ]


# ---------------------------------------------------------------------------
# Bounds under arbitrary feedback
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.randoms(use_true_random=False))
def test_knobs_stay_in_bounds(rnd):
    for sc in _scalers():
        for _ in range(300):
            act = sc.action()
            assert 1 <= act.bs <= 128
            assert 1 <= act.mtl <= 10
            # p95 anywhere between deep slack and a 4x gross violation
            sc.observe(rnd.uniform(0.0, 4.0) * SLO)
        act = sc.action()
        assert 1 <= act.bs <= 128 and 1 <= act.mtl <= 10


# ---------------------------------------------------------------------------
# No movement inside the hysteresis band
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.floats(ALPHA * SLO + 1e-6, 0.98 * SLO - 1e-6),
       st.randoms(use_true_random=False))
def test_no_movement_inside_band(in_band_p95, rnd):
    # the 0.98*SLO upper edge keeps the fed values inside every scaler's
    # band even if HybridScaler's optional safety margin (its band is
    # [alpha*(1-safety)*SLO, (1-safety)*SLO]; safety defaults to 0) is
    # ever enabled with a small value
    for sc in _scalers():
        # arbitrary prefix to land the scaler in an arbitrary state
        for _ in range(50):
            sc.observe(rnd.uniform(0.0, 2.0) * SLO)
        sc.observe(in_band_p95)           # settle any pending probe check
        act0 = sc.action()
        for _ in range(40):
            sc.observe(in_band_p95)
            act = sc.action()
            assert (act.bs, act.mtl) == (act0.bs, act0.mtl), type(sc).__name__


# ---------------------------------------------------------------------------
# infeasible only reachable at the knob floor
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 10))
def test_infeasible_only_at_floor(seed_mtl):
    for sc in _scalers(seed_mtl):
        if not hasattr(sc, "infeasible"):
            continue
        for _ in range(400):
            sc.observe(2.0 * SLO)         # persistent violation
            act = sc.action()
            if sc.infeasible:
                assert act.bs == 1
                if isinstance(sc, HybridScaler):
                    assert act.mtl == 1
        assert sc.infeasible              # the floor violates too


# ---------------------------------------------------------------------------
# Known-bad damping + amnesty
# ---------------------------------------------------------------------------
def test_batch_scaler_known_bad_not_reprobed_before_amnesty():
    sc = BatchScaler(SLO, decision_interval=1)
    sc.observe(0.01)                      # deep slack: jump to the midpoint
    bad = sc.bs
    assert bad > 1
    sc.observe(2.0 * SLO)                 # spike filter eats the first one
    sc.observe(2.0 * SLO)                 # persistent: pin + descend
    assert sc._known_bad == bad
    assert sc.bs < bad
    # climb back up: the pinned point must not be re-probed until the
    # 12-converged-decision amnesty clears it
    seen_converged = 0
    while seen_converged < 12:
        before = sc.converged_steps
        sc.observe(0.01)
        assert sc.bs < bad
        seen_converged = max(seen_converged, sc.converged_steps)
        if sc.converged_steps == 0 and before == 0 and sc.bs == bad - 1:
            seen_converged = max(seen_converged, 1)
    # amnesty has cleared: the next slack decision may re-probe upward
    sc.observe(0.01)
    assert sc._known_bad is None or sc.bs <= bad


def test_mt_scaler_known_bad_not_reprobed_before_amnesty():
    sc = MTScaler(SLO, _FixedEst(5), {1: 0.01}, decision_interval=1)
    sc.observe(2.0 * SLO)
    sc.observe(2.0 * SLO)                 # pin mtl=5, drop to 4
    assert sc._known_bad == 5 and sc.mtl == 4
    for _ in range(11):                   # converged_steps accumulates
        sc.observe(0.01)                  # slack, but 5 is pinned
        assert sc.mtl == 4
    sc.observe(0.01)                      # 12th: amnesty clears the pin
    sc.observe(0.01)                      # now the re-probe is allowed
    assert sc.mtl == 5


def test_hybrid_known_bad_respects_amnesty_window():
    # max_mtl=1 freezes the orthogonal axis so the probe pattern is pure BS
    sc = HybridScaler(SLO, decision_interval=1, amnesty=20, max_mtl=1)
    sc.observe(0.2 * ALPHA * SLO)         # slack: grow bs 1 -> 2
    assert sc.action().bs == 2
    sc.observe(3.0 * SLO)                 # gross: undo the probe, pin (2, 1)
    assert sc.action().bs == 1
    assert sc.is_pinned(2, 1)
    pinned_at = sc._decisions
    # within the amnesty window the pinned point is never re-probed
    while sc._decisions - pinned_at < sc.amnesty - 1:
        sc.observe(0.2 * ALPHA * SLO)
        assert (sc.action().bs, sc.action().mtl) != (2, 1)
    # after the window the search may try it again (second strike makes it
    # permanent via the probe-target dominance rule)
    for _ in range(10):
        sc.observe(0.2 * ALPHA * SLO)
        if sc.action().bs == 2:
            break
    assert sc.action().bs == 2
    sc.observe(3.0 * SLO)                 # strike two: now permanent
    assert sc.action().bs == 1
    for _ in range(3 * sc.amnesty):
        sc.observe(0.2 * ALPHA * SLO)
        assert sc.action().bs == 1        # dominance blocks everything >= 2


def test_hybrid_secondary_axis_needs_two_slack_readings():
    """One band-edge wobble must not trigger an (expensive) MTL probe."""
    sc = HybridScaler(SLO, decision_interval=1, max_bs=1)   # bs frozen
    sc.observe(0.9 * SLO)                 # in band
    sc.observe(0.5 * ALPHA * SLO)         # first slack reading
    assert sc.action().mtl == 1           # gated
    sc.observe(0.5 * ALPHA * SLO)         # second consecutive slack
    assert sc.action().mtl == 2


# ---------------------------------------------------------------------------
# Table-4 regression: the controller reproduces the paper's decisions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("jid", [1, 3, 5, 11, 12, 19, 26, 29])
def test_controller_matches_paper_table4_decision(jid):
    """DNNScalerController under SimExecutor picks the method the paper's
    Table 4 records for this job — pinning the eq. 3-5 profiling behavior
    against refactors (job 23, the one structural disagreement, is
    documented in EXPERIMENTS.md and excluded)."""
    job = PAPER_JOBS[jid - 1]
    ctrl = DNNScalerController(SimExecutor(job.profile(), seed=jid),
                               job.slo_s)
    assert ctrl.approach == job.paper_method


def test_hybrid_mode_reports_h_and_acts_jointly():
    job = PAPER_JOBS[0]                   # inception_v1 — an MT job
    ctrl = DNNScalerController(SimExecutor(job.profile(), seed=1),
                               job.slo_s, mode="hybrid")
    assert ctrl.approach == "H"
    assert isinstance(ctrl.scaler, HybridScaler)
    assert ctrl.scaler.primary == "MT"    # profiler picked the seed axis
    act = ctrl.action()
    assert act.mtl >= 1 and act.bs == 1   # seeded at the MT estimate
