"""Integration tests: serving engine + controllers end-to-end (sim executor),
SLO attainment properties, tenancy planner, device-model sanity."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.controller import (ClipperController, DNNScalerController,
                                   StaticController)
from repro.core.matrix_completion import LatencyEstimator
from repro.serving import device_model as dm, tenancy
from repro.serving.engine import ServingEngine
from repro.serving.executor import SimExecutor
from repro.serving.workload import PAPER_JOBS


def _library(exclude_id=-1):
    est = LatencyEstimator(max_mtl=10)
    for j in PAPER_JOBS[:8]:
        if j.job_id != exclude_id:
            prof = j.profile()
            est.add_library_row({m: dm.mt_latency(dm.TESLA_P40, prof, 1, m)
                                 for m in range(1, 11)})
    return est


def run_job(job, controller_name, steps=4000, seed=0):
    prof = job.profile()
    if controller_name == "dnnscaler":
        ctrl = DNNScalerController(SimExecutor(prof, seed=seed), job.slo_s,
                                   estimator=_library(job.job_id))
    else:
        ctrl = ClipperController(job.slo_s)
    eng = ServingEngine(SimExecutor(prof, seed=seed + 1), job.slo_s)
    acc = eng.run(ctrl, max_steps=steps, sim_time_limit=240.0)
    return ctrl, acc.summary()


def test_dnnscaler_beats_clipper_on_mt_job():
    job = PAPER_JOBS[4]  # mobilenet_v1_025/imagenet — paper's 14x case
    _, s_d = run_job(job, "dnnscaler")
    _, s_c = run_job(job, "clipper")
    assert s_d["throughput"] > 1.5 * s_c["throughput"]


def test_dnnscaler_parity_with_clipper_on_b_job():
    job = PAPER_JOBS[2]  # inception_v4/imagenet — Batching either way
    ctrl, s_d = run_job(job, "dnnscaler")
    _, s_c = run_job(job, "clipper")
    assert ctrl.approach == "B"
    assert s_d["throughput"] > 0.8 * s_c["throughput"]


@pytest.mark.parametrize("jid", [1, 3, 5, 12, 19, 29])
def test_slo_attainment(jid):
    """Both controllers keep ~p95 <= SLO at steady state (paper Fig. 6)."""
    job = PAPER_JOBS[jid - 1]
    _, s = run_job(job, "dnnscaler")
    assert s["slo_attainment"] >= 0.85, (jid, s)
    # Clipper's AIMD probes past the SLO by design before backing off, so its
    # attainment is structurally lower (the paper's Fig. 7 shows the same
    # overshoot) — bound it loosely.
    _, s = run_job(job, "clipper")
    assert s["slo_attainment"] >= 0.45, (jid, s)


def test_slo_schedule_adaptation():
    """SLO drops mid-run -> DNNScaler sheds batch/instances (paper Figs 9-10)."""
    job = PAPER_JOBS[2]
    prof = job.profile()
    ctrl = DNNScalerController(SimExecutor(prof, seed=0), job.slo_s,
                               estimator=_library())
    slo_fn = lambda t: job.slo_s if t < 60.0 else job.slo_s * 0.4
    eng = ServingEngine(SimExecutor(prof, seed=1), job.slo_s,
                        slo_schedule=slo_fn)
    eng.run(ctrl, max_steps=1500, sim_time_limit=150.0)
    # after the tightening, knob must have been reduced
    early = [x for x in eng.acc.trace if x[0] < 55.0]
    late = [x for x in eng.acc.trace if x[0] > 100.0]
    assert late and early
    assert late[-1][1] < early[-1][1]  # batch size reduced
    assert late[-1][3] <= job.slo_s * 0.4 * 1.35  # p95 near new SLO


# ---------------------------------------------------------------------------
# Device-model and engine properties
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.sampled_from(list(dm.NET_SPECS)), st.integers(1, 128),
       st.integers(1, 10))
def test_latency_monotone_in_knobs(net, bs, mtl):
    prof = dm.paper_profile(net, "imagenet")
    l1 = dm.batch_latency(dm.TESLA_P40, prof, bs)
    l2 = dm.batch_latency(dm.TESLA_P40, prof, bs + 1)
    assert l2 >= l1 * 0.999                       # latency grows with BS
    m1 = dm.mt_latency(dm.TESLA_P40, prof, 1, mtl)
    m2 = dm.mt_latency(dm.TESLA_P40, prof, 1, mtl + 1)
    assert m2 >= m1 * 0.999                       # and with MTL


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(list(dm.NET_SPECS)))
def test_power_within_device_envelope(net):
    prof = dm.paper_profile(net, "imagenet")
    for mtl in (1, 4, 10):
        p = dm.power(dm.TESLA_P40, prof, 1, mtl)
        assert dm.TESLA_P40.idle_w <= p <= dm.TESLA_P40.peak_w


def test_engine_charges_instance_lifecycle():
    prof = dm.paper_profile("mobilenet_v1_05", "imagenet")
    eng = ServingEngine(SimExecutor(prof, seed=0), slo_s=0.2,
                        instance_launch_s=2.0)
    eng.run(StaticController(bs=1, mtl=4), max_steps=5)
    assert eng.reconfig_time == pytest.approx(2.0 * 3)  # 1 -> 4 instances


# ---------------------------------------------------------------------------
# TPU tenancy planner
# ---------------------------------------------------------------------------
def test_tenancy_plan_shapes():
    p = tenancy.plan((16, 16), 4)
    assert p.replicas == 4 and p.share == pytest.approx(0.25)
    assert p.replica_shape[0] * p.replica_shape[1] * 4 == 256
    assert tenancy.plan((16, 16), 3) is None      # non-divisor
    assert tenancy.plan((16, 16), 256).replica_shape == (1, 1)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128, 256]))
def test_tenancy_share_sums_to_one(mtl):
    p = tenancy.plan((16, 16), mtl)
    assert p is not None
    assert p.share * mtl == pytest.approx(1.0)


def test_open_loop_bursty_arrivals():
    """Open-loop engine: DNNScaler absorbs a 3x burst while keeping queue
    latency bounded; a static bs=1 server falls behind."""
    from repro.serving.engine import OpenLoopEngine
    job = PAPER_JOBS[2]  # inception_v4, SLO 419ms
    prof = job.profile()
    base_thr = 1.0 / dm.batch_latency(dm.TESLA_P40, prof, 1)
    rate = base_thr * 2.0  # needs batching to keep up

    ctrl = DNNScalerController(SimExecutor(prof, seed=0), job.slo_s,
                               estimator=LatencyEstimator())
    eng = OpenLoopEngine(SimExecutor(prof, seed=1), job.slo_s,
                         arrival_rate=rate, burst_factor=3.0, seed=2)
    acc = eng.run(ctrl, max_steps=3000, sim_time_limit=120.0)
    assert acc.total_items > rate * 60  # kept up with most of the load

    eng2 = OpenLoopEngine(SimExecutor(prof, seed=1), job.slo_s,
                          arrival_rate=rate, burst_factor=3.0, seed=2)
    acc2 = eng2.run(StaticController(bs=1, mtl=1), max_steps=3000,
                    sim_time_limit=120.0)
    assert acc.throughput > 1.5 * acc2.throughput  # static bs=1 falls behind
    assert len(eng.queue) < len(eng2.queue)        # bounded backlog
