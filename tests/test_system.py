"""End-to-end behaviour tests for the paper's system: the full
profile -> decide -> scale -> serve pipeline, and the real-executor path."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.controller import DNNScalerController, ClipperController
from repro.core.matrix_completion import LatencyEstimator
from repro.serving import device_model as dm
from repro.serving.engine import ServingEngine
from repro.serving.executor import RealExecutor, SimExecutor
from repro.serving.workload import PAPER_JOBS


def _estimator():
    est = LatencyEstimator(max_mtl=10)
    for j in PAPER_JOBS[:8]:
        prof = j.profile()
        est.add_library_row({m: dm.mt_latency(dm.TESLA_P40, prof, 1, m)
                             for m in range(1, 11)})
    return est


def test_full_pipeline_mt_job():
    """MT job: profile picks MT, matrix completion jumps near the right MTL,
    AIMD settles, SLO holds, throughput beats Clipper (paper's headline)."""
    job = PAPER_JOBS[18]  # mobilenet_v1_05 / caltech (paper: MT, MTL=10)
    prof = job.profile()
    ctrl = DNNScalerController(SimExecutor(prof, seed=3), job.slo_s,
                               estimator=_estimator())
    assert ctrl.approach == "MT"
    eng = ServingEngine(SimExecutor(prof, seed=4), job.slo_s)
    acc = eng.run(ctrl, max_steps=1500)
    s = acc.summary()
    eng_c = ServingEngine(SimExecutor(prof, seed=5), job.slo_s)
    acc_c = eng_c.run(ClipperController(job.slo_s), max_steps=1500)
    assert s["throughput"] > 1.5 * acc_c.summary()["throughput"]
    assert s["slo_attainment"] > 0.85
    assert ctrl.action().mtl >= 6


def test_full_pipeline_b_job_binary_search_fast():
    """B job: the pseudo-binary search reaches a stable batch size faster
    than Clipper's AIMD (paper Fig. 7)."""
    job = PAPER_JOBS[2]  # inception_v4, SLO 419ms
    prof = job.profile()
    ctrl = DNNScalerController(SimExecutor(prof, seed=0), job.slo_s,
                               estimator=_estimator())
    assert ctrl.approach == "B"
    eng = ServingEngine(SimExecutor(prof, seed=1), job.slo_s)
    eng.run(ctrl, max_steps=600)
    bs_trace = [t[1] for t in eng.acc.trace]

    eng2 = ServingEngine(SimExecutor(prof, seed=1), job.slo_s)
    clip = ClipperController(job.slo_s)
    eng2.run(clip, max_steps=600)
    clip_trace = [t[1] for t in eng2.acc.trace]

    def n_changes(trace):
        return sum(1 for a, b in zip(trace, trace[1:]) if a != b)

    # O(log) binary-search decisions vs O(n) additive probing
    assert n_changes(bs_trace) <= n_changes(clip_trace)
    assert bs_trace[-1] > 1
    # and the steady state is confined to a narrow band (the SLO noise keeps
    # the search alive, but it must not wander)
    tail = bs_trace[-50:]
    assert (max(tail) - min(tail)) <= 0.6 * max(tail)


def test_power_efficiency_improvement_on_mt_jobs():
    """Table 6: MT jobs show better throughput/W than Clipper despite higher
    absolute power."""
    job = PAPER_JOBS[3]  # mobilenet_v1_05 / imagenet
    prof = job.profile()
    ctrl = DNNScalerController(SimExecutor(prof, seed=0), job.slo_s,
                               estimator=_estimator())
    eng = ServingEngine(SimExecutor(prof, seed=1), job.slo_s)
    s = eng.run(ctrl, max_steps=1500).summary()
    eng2 = ServingEngine(SimExecutor(prof, seed=2), job.slo_s)
    s2 = eng2.run(ClipperController(job.slo_s), max_steps=1500).summary()
    assert s["power_efficiency"] > s2["power_efficiency"]


def test_real_executor_llm_serving():
    """Wall-clock path: serve a tiny real model, DNNScaler stays live."""
    cfg = get_config("smollm_360m", tiny=True)
    from repro.models import api

    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng, cfg)

    @jax.jit
    def fwd(params, batch):
        logits, _ = api.prefill(params, batch, cfg, capacity=40)
        return logits

    def make_batch(n):
        return {"tokens": jax.random.randint(rng, (n, 32), 0,
                                             cfg.vocab_size, jax.numpy.int32)}

    ex = RealExecutor(fwd, params, make_batch)
    base = ex.mean_latency(1, 1)
    slo = base * 6
    ctrl = DNNScalerController(ex, slo, m=8, n=4, max_bs=32, max_mtl=4)
    eng = ServingEngine(ex, slo, instance_launch_s=0.05)
    acc = eng.run(ctrl, max_steps=60)
    s = acc.summary()
    assert s["throughput"] > 0
    a = ctrl.action()
    assert a.bs >= 1 and a.mtl >= 1


def test_combination_study_fig12():
    """B+MT combination: some nets benefit, others only lose latency."""
    res152 = dm.paper_profile("resnet_v2_152", "imagenet")
    mob025 = dm.paper_profile("mobilenet_v1_025", "imagenet")
    # ResNet152 at BS=8: MTL 1->2 helps
    thr1 = dm.mt_throughput(dm.TESLA_P40, res152, 8, 1)
    thr2 = dm.mt_throughput(dm.TESLA_P40, res152, 8, 2)
    assert thr2 > thr1 * 1.05
    # latency always grows with the combination
    assert dm.mt_latency(dm.TESLA_P40, mob025, 4, 5) > \
        dm.mt_latency(dm.TESLA_P40, mob025, 1, 5)
