"""Online-churn ClusterEngine tests: request conservation under randomized
admit/drain sequences (the property test the tentpole demands — including
drains that land mid-stall), migration-cost accounting, drain semantics,
event-order monotonicity under churn, and the static-union baseline."""

import dataclasses

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.controller import StaticController
from repro.serving import device_model as dm
from repro.serving.cluster import (ClusterEngine, DeviceSpec, gpu_fleet,
                                   run_churn_cluster)
from repro.serving.workload import (ChurnJob, PAPER_JOBS, churn_trace,
                                    llm_serving_jobs)


def _static_factory(bs=1, mtl=1):
    return lambda job, executor: StaticController(bs=bs, mtl=mtl)


def _tenant(k, base, admit, depart, rate):
    return ChurnJob(job=dataclasses.replace(base, job_id=500 + k),
                    admit_s=admit, depart_s=depart, arrival_rate=rate)


def _assert_conserved(rep):
    for r in rep["per_job"]:
        assert r["submitted"] == (r["completed"] + r["rejected"]
                                  + r["backlog"]), r
    assert rep["aggregate"]["conserved"]


# ---------------------------------------------------------------------------
# Property: conservation holds under randomized admit/drain sequences.
# The mtl=3 static controller forces a 2 x launch stall on every job's very
# first step, so random departure times regularly land inside a stall —
# the exact mid-stall-drain case the tentpole calls out.
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 5), st.randoms(use_true_random=False))
def test_conservation_under_random_churn(seed, rnd):
    pool = PAPER_JOBS[:8]
    trace = []
    for k in range(3 + rnd.randrange(5)):
        admit = 0.0 if rnd.random() < 0.3 else rnd.random() * 12.0
        depart = (admit + 0.5 + rnd.random() * 12.0
                  if rnd.random() < 0.7 else None)
        rate = 20.0 + rnd.random() * 300.0
        trace.append(_tenant(k, pool[rnd.randrange(len(pool))],
                             admit, depart, rate))
    eng = ClusterEngine([], gpu_fleet(2), churn=trace,
                        controller_factory=_static_factory(mtl=3),
                        anticipate=True, seed=seed, max_queue=300)
    rep = eng.run(sim_time_limit=18.0)
    _assert_conserved(rep)
    # everything in the trace was admitted exactly once
    assert len(rep["per_job"]) == len(trace)


def test_conservation_with_drain_inside_initial_stall():
    """Departure inside the very first launch stall: the job never serves
    a single on-time step, yet every arrival is accounted."""
    job = PAPER_JOBS[0]
    # mtl=5 -> 4 launches x 2 s: the first step stalls until t=8; depart
    # at t=3 lands mid-stall
    trace = [_tenant(0, job, 0.0, 3.0, 200.0)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(mtl=5),
                        instance_launch_s=2.0, seed=0)
    rep = eng.run(sim_time_limit=15.0)
    _assert_conserved(rep)
    r = rep["per_job"][0]
    assert r["drained_at"] is not None
    # arrivals were clipped at the departure time, not the serving clock:
    # ~200/s over 3 s, never ~200/s over the 8 s stall
    assert r["submitted"] <= 200.0 * 3.0 * 1.6


def test_admission_charges_coresidents_migration():
    """A mid-run admission shrinks the resident's share: the resident pays
    one kill+relaunch stall, charged to its clock AND globally."""
    trace = [_tenant(0, PAPER_JOBS[2], 0.0, None, None),
             _tenant(1, PAPER_JOBS[2], 5.0, None, None)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(),
                        instance_launch_s=2.0, instance_kill_s=0.3, seed=0)
    rep = eng.run(sim_time_limit=20.0)
    resident = next(r for r in rep["per_job"] if r["job_id"] == 500)
    assert resident["migrations"] == 1
    assert resident["migration_stall_s"] == pytest.approx(2.3)
    assert eng.migration_stall_s == pytest.approx(2.3)
    assert eng.stall_time >= eng.migration_stall_s
    agg = rep["aggregate"]
    assert agg["admissions"] == 1 and agg["migrations"] == 1
    _assert_conserved(rep)


def test_tpu_submesh_migration_pays_checkpoint_transfer():
    """On a TPU pod slice the share change also streams every instance's
    params to the new submesh: the stall must exceed the kill+launch
    floor by the checkpoint-transfer term."""
    fleet = [DeviceSpec(device=dm.TPU_V5E, mesh_shape=(4, 4), name="pod0")]
    trace = [_tenant(0, PAPER_JOBS[2], 0.0, None, None),
             _tenant(1, PAPER_JOBS[2], 4.0, None, None)]
    ckpt_bps = 1e9
    eng = ClusterEngine([], fleet, churn=trace,
                        controller_factory=_static_factory(),
                        instance_launch_s=2.0, instance_kill_s=0.3,
                        ckpt_bps=ckpt_bps, seed=0)
    eng.run(sim_time_limit=20.0)
    expected = 2.3 + PAPER_JOBS[2].profile().param_bytes / ckpt_bps
    assert eng.migration_stall_s == pytest.approx(expected)


def test_drain_frees_share_and_deactivates():
    trace = [_tenant(0, PAPER_JOBS[2], 0.0, None, None),
             _tenant(1, PAPER_JOBS[2], 0.0, 6.0, None)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(), seed=0)
    rep = eng.run(sim_time_limit=20.0)
    drained = next(r for r in rep["per_job"] if r["job_id"] == 501)
    stayed = next(r for r in rep["per_job"] if r["job_id"] == 500)
    assert not drained["active"] and drained["drained_at"] >= 6.0
    assert stayed["active"]
    # the survivor owns the device again
    assert eng.residents[0] == [0]
    assert rep["aggregate"]["drains"] == 1


def test_event_order_stays_monotone_under_churn():
    trace = churn_trace(horizon_s=30.0, n_initial=3, n_churn=4,
                        mean_lifetime_s=10.0, include_llm=False, seed=3)
    eng = ClusterEngine([], gpu_fleet(2), churn=trace,
                        controller_factory=_static_factory(mtl=2),
                        anticipate=True, seed=3)
    rep = eng.run(sim_time_limit=30.0)
    times = [t for t, _ in eng.event_log]
    assert times == sorted(times)
    _assert_conserved(rep)
    # per-job clocks are monotone even across migration stalls
    for st_ in eng.states:
        trace_t = [t for t, *_ in st_.acc.trace]
        assert all(b > a for a, b in zip(trace_t, trace_t[1:]))


def test_static_union_never_migrates():
    trace = churn_trace(horizon_s=30.0, n_initial=3, n_churn=4,
                        mean_lifetime_s=10.0, include_llm=False, seed=5)
    eng = ClusterEngine([], gpu_fleet(2), churn=trace,
                        controller_factory=_static_factory(),
                        static_union=True, seed=5)
    rep = eng.run(sim_time_limit=30.0)
    assert rep["aggregate"]["migrations"] == 0
    assert rep["aggregate"]["migration_stall_s"] == 0.0
    # late arrivals still only serve inside their lifetime
    for r in rep["per_job"]:
        if r["admit_s"] > 0:
            first_step = next(
                t for t, *_ in
                eng.states[rep["per_job"].index(r)].acc.trace)
            assert first_step >= r["admit_s"]
    _assert_conserved(rep)


def test_predicted_steady_slices_library_surface_to_submesh_cap():
    """A SurfaceLibrary prediction on a TPU pod slice must be truncated
    to the submesh tenancy cap (regression: the full-width (8, 10)
    surface used to broadcast against the capped mtl grid and crash)."""
    from repro.core.matrix_completion import SurfaceLibrary

    lib = SurfaceLibrary()
    job = dataclasses.replace(PAPER_JOBS[2], job_id=500)

    def lat(b, m, base=5.0):
        return base * (1.0 + 0.2 * (b - 1)) * (1.0 + 0.5 * (m - 1)) / 1e3

    for b in lib.bs_values:
        for m in lib.mtl_values:
            lib.observe("historic", b, m, lat(b, m, 7.0))
    for b, m in ((1, 1), (32, 1), (1, 8)):
        lib.observe(500, b, m, lat(b, m))
    assert lib.predict(500) is not None
    fleet = [DeviceSpec(device=dm.TPU_V5E, mesh_shape=(2, 2), name="pod0")]
    eng = ClusterEngine([], fleet, churn=[ChurnJob(job=job)],
                        controller_factory=_static_factory(),
                        anticipate=True, surface_library=lib, seed=0)
    pred = eng._predicted_steady(job, 0, 1)   # cap = 4 < len(mtl grid)
    assert pred is not None
    assert pred[2] <= 4                       # mtl within the submesh cap


def test_llm_jobs_serve_in_churn_pool():
    jobs = llm_serving_jobs()
    assert all(j.profile().name.endswith("/decode") for j in jobs)
    trace = [_tenant(0, jobs[0], 0.0, None, 50.0)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(bs=4), seed=0)
    rep = eng.run(sim_time_limit=5.0)
    assert rep["per_job"][0]["completed"] > 0
    _assert_conserved(rep)


# ---------------------------------------------------------------------------
# Lockstep fairness: a wall-clock compile stall charged to one job's
# sub-millisecond simulated clock starves it in the lockstep loop until
# every peer catches up.  `stall_cap_s` bounds the per-event clock charge
# (the excess is recorded, never lost) and therefore the clock divergence.
# ---------------------------------------------------------------------------
class _StallingExecutor:
    """Sim-like executor whose FIRST step reports a huge compile stall
    (the real-executor AOT-compile regime at wall-clock magnitude)."""

    def __init__(self, lat=0.005, stall=50.0):
        self.lat = lat
        self.stall = stall
        self._first = True

    def run_step(self, bs, mtl):
        import numpy as np
        comp = self.stall if self._first else 0.0
        self._first = False
        items = bs * mtl
        return {"step_time": self.lat, "items": items,
                "compile_time": comp,
                "request_latencies": np.full(min(items, 64), self.lat),
                "power_w": 100.0, "throughput": items / self.lat}


def _stall_fleet_engine(stall_cap_s):
    built = []

    def factory(job, spec, share, mesh, seed):
        # only the FIRST tenancy's serving executor pays the giant stall
        ex = _StallingExecutor(stall=50.0 if not built else 0.0)
        built.append(ex)
        return ex

    trace = [_tenant(0, PAPER_JOBS[0], 0.0, None, 100.0),
             _tenant(1, PAPER_JOBS[0], 0.0, None, 100.0)]
    return ClusterEngine([], gpu_fleet(2), churn=trace,
                         controller_factory=_static_factory(),
                         executor_factory=factory, seed=0,
                         stall_cap_s=stall_cap_s, max_queue=2000)


def test_uncapped_compile_stall_starves_the_job():
    eng = _stall_fleet_engine(stall_cap_s=None)
    eng.run(sim_time_limit=2.0)
    stalled = eng.states[0]
    # the 50 s charge threw the clock past the horizon: one step, starved
    assert len(stalled.acc.trace) == 1
    assert eng.max_clock_skew_s >= 49.0
    assert eng.stall_capped_s == 0.0


def test_stall_cap_bounds_clock_divergence_and_restores_fairness():
    cap = 0.5
    eng = _stall_fleet_engine(stall_cap_s=cap)
    rep = eng.run(sim_time_limit=2.0)
    stalled, peer = eng.states[0], eng.states[1]
    # bounded divergence: no clock ever ran ahead of the slowest active
    # peer by more than the cap plus one serving step
    assert eng.max_clock_skew_s <= cap + 0.005 + 1e-9
    # the capped job serves the horizon instead of starving behind its
    # stall-inflated clock
    assert len(stalled.acc.trace) > 100
    assert len(peer.acc.trace) > 100
    # the excess was recorded, not lost
    assert eng.stall_capped_s == pytest.approx(50.0 - cap)
    _assert_conserved(rep)


# ---------------------------------------------------------------------------
# End-to-end policy comparison (kept small; the converged run lives in
# examples/cluster_churn.py and the churn bench suite)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_dynamic_replacement_beats_static_union_on_goodput():
    kw = dict(trace_kwargs=dict(n_initial=4, n_churn=8,
                                mean_lifetime_s=25.0),
              n_devices=4, horizon_s=90.0, seed=1)
    union = run_churn_cluster("union", **kw)
    surface = run_churn_cluster("surface", **kw)
    _assert_conserved(union)
    _assert_conserved(surface)
    assert (surface["aggregate"]["goodput"]
            > union["aggregate"]["goodput"])


# ---------------------------------------------------------------------------
# Real-executor churn smoke: 3 churn tenancies of the SAME architecture on
# one device, wall-clock executors rebuilt on every share change.  The
# profile store collects instrumented kill+relaunch measurements and, once
# enough samples exist, migrations are charged from the calibrated
# percentile instead of the modeling defaults — so the total charged
# migration stall must come in at or below the modeling-default total
# recorded in the same run.  Request conservation holds throughout.
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_real_executor_churn_calibrated_migrations(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.perf.profile_store import ProfileStore
    from repro.serving.executor import RealExecutor

    store = ProfileStore(str(tmp_path))
    built = []

    def factory(job, spec, share, mesh, seed):
        # a FRESH executor per (re)build — a migration really kills and
        # relaunches the serving process, including its AOT cache
        w = jax.random.normal(jax.random.PRNGKey(0), (16, 16))

        def fn(params, batch):
            return jnp.tanh(batch["x"] @ params).sum()

        def make_batch(n):
            return {"x": jnp.ones((n, 16), jnp.float32)}

        ex = RealExecutor(fn, w, make_batch)
        built.append(ex)
        return ex

    base = PAPER_JOBS[0]                      # one architecture: all
    #                                           measurements share one
    #                                           calibration key
    # the departing tenant is the LAST admitted: an admission reshare
    # stalls the co-residents, not the newcomer, so its clock stays with
    # the pack and the drain fires within the step budget — a tenant
    # whose own clock was stall-inflated would starve in the lockstep
    # loop until every other ~0.2 ms/step job caught up to it
    trace = [_tenant(0, base, 0.0, None, 25.0),
             _tenant(1, base, 0.0, None, 25.0),
             _tenant(2, base, 0.05, None, 25.0),
             _tenant(3, base, 0.10, None, 25.0),
             _tenant(4, base, 0.15, 0.2, 25.0)]
    eng = ClusterEngine([], gpu_fleet(1), churn=trace,
                        controller_factory=_static_factory(bs=2),
                        executor_factory=factory, profile_store=store,
                        instance_launch_s=0.5, instance_kill_s=0.1,
                        seed=0, max_queue=500)
    # the budget must cover the pre-admission serving (hundreds of
    # ~0.2 ms lockstep steps per simulated 50 ms, MORE on a faster
    # host — a warm process can dispatch in tens of microseconds, so
    # leave generous headroom; sim_time_limit still bounds the run)
    rep = eng.run(sim_time_limit=6.0, max_steps=60000)

    _assert_conserved(rep)
    agg = rep["aggregate"]
    assert agg["admissions"] == 3 and agg["drains"] >= 1
    # enough share changes that the calibration kicked in mid-run
    assert agg["migrations"] >= 2 * 3
    key = f"{base.dnn}/{base.dataset}|{gpu_fleet(1)[0].device.name}"
    assert store.migration_cost(key) is not None
    # the headline: calibrated stalls never exceed the modeling defaults
    # recorded in the same run, and at least one migration was charged
    # from measurements (tiny models relaunch far faster than 0.6 s)
    assert agg["migration_stall_s"] <= \
        agg["migration_modeled_stall_s"] + 1e-9
    assert agg["migration_stall_s"] < 0.99 * agg["migration_modeled_stall_s"]
    # instrumented executors: stale hits never happen, and every rebuild
    # produced a fresh executor
    for ex in built:
        assert ex.cache_stats.stale_hits == 0
    assert len(built) > len(trace) * 2        # rebuilds really happened
    # measurements persisted for the NEXT process
    store2 = ProfileStore(str(tmp_path))
    assert store2.migration_cost(key) is not None


# ---------------------------------------------------------------------------
# Online cost-model retraining: surface rows persisted at drain time accrue
# per device class, and once `retrain_every_rows` fresh ones land the class
# model refits from the store AT DRAIN — never with fewer usable rows than
# a cold `train_cost_model` fit would accept, and always on strictly more
# rows than the previous fit (the store only grows within a run).
# ---------------------------------------------------------------------------
def test_online_retrain_grows_rows_and_respects_min_floor(tmp_path,
                                                          monkeypatch):
    from repro.core.matrix_completion import SurfaceLibrary
    from repro.perf import cost_model as cm
    from repro.perf.profile_store import ProfileStore
    from repro.serving.cluster import paper_controller_factory
    from repro.serving.workload import churn_trace

    store = ProfileStore(str(tmp_path))
    lib = SurfaceLibrary()
    calls = []
    real = cm.train_cost_model

    def recording(st, dc, **kw):
        model = real(st, dc, **kw)
        calls.append((dc, None if model is None else model.n_rows))
        return model

    monkeypatch.setattr(
        "repro.serving.cluster.cost_model_mod.train_cost_model", recording)
    trace = churn_trace(horizon_s=60.0, n_initial=4, n_churn=8,
                        mean_lifetime_s=15.0, include_llm=False, seed=2)
    eng = ClusterEngine([], gpu_fleet(3), churn=trace,
                        controller_factory=paper_controller_factory(
                            "hybrid", surface=lib),
                        surface_library=lib, profile_store=store,
                        retrain_every_rows=2, seed=0)
    rep = eng.run(sim_time_limit=60.0)
    _assert_conserved(rep)

    fits = [n for _, n in calls if n is not None]
    assert fits, "no online retrain ever fired"
    assert rep["aggregate"]["cost_model_retrains"] == {"tesla-p40": len(fits)}
    # the minimum-row floor held on every fit, thin attempts came back None
    assert all(n >= 4 for n in fits)
    # each successive refit saw strictly more training rows
    assert all(b > a for a, b in zip(fits, fits[1:]))
    # the refit landed: the engine serves the new model and persisted it
    assert "tesla-p40" in eng.cost_models
    assert cm.load_cost_model(store, "tesla-p40") is not None
