"""Micro-benchmarks: Pallas kernels (interpret mode) vs pure-jnp oracle wall
time on CPU, autotuned vs hard-coded tilings on the same shapes, plus the
real tiny-model serving step."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _maxerr(a, b) -> float:
    """Max abs deviation pallas vs reference — the deterministic metric
    ``--check`` gates the kernels suite on (wall clocks are too noisy)."""
    return float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                 - jnp.asarray(b, jnp.float32))))


def bench_kernels():
    # baseline rows pin the HARD-CODED tile defaults explicitly, so their
    # numbers stay comparable across runs whether or not the autotune cache
    # (which this suite fills below) is already warm
    from repro.perf import autotune
    rows = []
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, T, H, KV, hd = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, hd), jnp.float32)
    t_pl = _time(lambda a, b, c: flash_attention(
        a, b, c, causal=True, **autotune.DEFAULTS["flash_attention"]), q, k, v)
    t_ref = _time(jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True)),
                  q, k, v)
    err = _maxerr(flash_attention(q, k, v, causal=True,
                                  **autotune.DEFAULTS["flash_attention"]),
                  attention_ref(q, k, v, causal=True))
    rows.append(("kernel/flash_attention/1k", t_pl * 1e6,
                 f"interpret_vs_ref=x{t_pl / t_ref:.2f}(CPU-interpret),"
                 f"maxerr={err:.3e}"))

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    S = 4096
    q1 = jax.random.normal(ks[0], (4, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (4, S, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (4, S, KV, hd), jnp.float32)
    pos = jnp.asarray(S - 1, jnp.int32)
    t_pl = _time(lambda a, b, c: decode_attention(
        a, b, c, pos, **autotune.DEFAULTS["decode_attention"]), q1, kc, vc)
    t_ref = _time(jax.jit(lambda a, b, c: decode_attention_ref(a, b, c, pos)),
                  q1, kc, vc)
    err = _maxerr(decode_attention(q1, kc, vc, pos,
                                   **autotune.DEFAULTS["decode_attention"]),
                  decode_attention_ref(q1, kc, vc, pos))
    rows.append(("kernel/decode_attention/4k", t_pl * 1e6,
                 f"interpret_vs_ref=x{t_pl / t_ref:.2f}(CPU-interpret),"
                 f"maxerr={err:.3e}"))

    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_ref
    B2, T2, Hh, P, N = 1, 512, 8, 64, 64
    kk = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(kk[0], (B2, T2, Hh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(kk[1], (B2, T2, Hh)))
    A = -jnp.exp(jax.random.normal(kk[2], (Hh,)) * 0.5)
    Bm = jax.random.normal(kk[3], (B2, T2, N)) * 0.5
    Cm = jax.random.normal(kk[4], (B2, T2, N)) * 0.5
    t_pl = _time(lambda *a: ssd_scan(*a, chunk=128), x, dt, A, Bm, Cm)
    t_ref = _time(jax.jit(ssd_ref), x, dt, A, Bm, Cm)
    err = _maxerr(ssd_scan(x, dt, A, Bm, Cm, chunk=128)[0],
                  ssd_ref(x, dt, A, Bm, Cm)[0])
    rows.append(("kernel/ssd_scan/512", t_pl * 1e6,
                 f"interpret_vs_ref=x{t_pl / t_ref:.2f}(CPU-interpret),"
                 f"maxerr={err:.3e}"))

    # -- autotuned vs hard-coded tilings on the exact bench tensors ---------
    # (tune() fills the persistent cache for these shape classes; the timed
    # comparison below runs on the REAL bench inputs, not the tuner's
    # synthetic ones, so the recorded speedup is what a caller would see)
    tuned = autotune.tune("flash_attention", "float32", BKV=B * KV,
                          G=H // KV, hd=hd, Tq=T, Tk=T,
                          causal=True)["config"]
    t_def = _time(lambda a, b, c: flash_attention(
        a, b, c, causal=True, **autotune.DEFAULTS["flash_attention"]), q, k, v)
    t_tun = _time(lambda a, b, c: flash_attention(
        a, b, c, causal=True, **tuned), q, k, v)
    rows.append(("kernel/flash_attention/1k/autotuned", t_tun * 1e6,
                 f"default={t_def * 1e6:.0f}us,x{t_def / t_tun:.2f},"
                 f"cfg={tuned}"))

    tuned = autotune.tune("decode_attention", "float32", BKV=4 * KV,
                          G=H // KV, hd=hd, S=S)["config"]
    t_def = _time(lambda a, b, c: decode_attention(
        a, b, c, pos, **autotune.DEFAULTS["decode_attention"]), q1, kc, vc)
    t_tun = _time(lambda a, b, c: decode_attention(a, b, c, pos, **tuned),
                  q1, kc, vc)
    rows.append(("kernel/decode_attention/4k/autotuned", t_tun * 1e6,
                 f"default={t_def * 1e6:.0f}us,x{t_def / t_tun:.2f},"
                 f"cfg={tuned}"))

    tuned = autotune.tune("ssd_scan", "float32", H=Hh, P=P, N=N,
                          T=T2)["config"]
    t_def = _time(lambda *a: ssd_scan(
        *a, **autotune.DEFAULTS["ssd_scan"]), x, dt, A, Bm, Cm)
    t_tun = _time(lambda *a: ssd_scan(*a, **tuned), x, dt, A, Bm, Cm)
    rows.append(("kernel/ssd_scan/512/autotuned", t_tun * 1e6,
                 f"default={t_def * 1e6:.0f}us,x{t_def / t_tun:.2f},"
                 f"cfg={tuned}"))
    return rows


def bench_real_decode():
    """Wall-clock decode step of a tiny real model on this host."""
    from repro.configs.base import get_config
    from repro.models import api
    rows = []
    for arch in ("smollm_360m", "mamba2_1p3b", "gemma2_2b"):
        cfg = get_config(arch, tiny=True)
        rng = jax.random.PRNGKey(0)
        params = api.init_params(rng, cfg)
        tokens = jax.random.randint(rng, (4, 32), 0, cfg.vocab_size, jnp.int32)
        _, cache = jax.jit(lambda p, t: api.prefill(p, {"tokens": t}, cfg, 64)
                           )(params, tokens)
        step = jax.jit(lambda p, c, t, q: api.decode_step(p, c, t, q, cfg))
        tok = jnp.zeros((4,), jnp.int32)
        pos = jnp.asarray(32, jnp.int32)
        t = _time(lambda p, c: step(p, c, tok, pos)[0], params, cache, iters=5)
        rows.append((f"real_decode/{arch}-tiny", t * 1e6,
                     f"tok_s={4 / t:.0f}"))
    return rows
