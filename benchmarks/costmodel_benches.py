"""Learned HLO cost model: prediction quality + zero-probe warm start.

Three rows:

* ``costmodel/train`` (non-gated) — wall time of one ridge fit over the
  full 29-row Table-4 profile store.  Training is cheap enough (~60 ms)
  that the cluster engine can afford to retrain at every boot.

* ``costmodel/loo`` — FULL leave-one-job-out over the 29 unique Table-4
  (dnn, dataset) pairs: each fold persists the other 28 dense probed
  surfaces, trains, and prices the held-out job's whole (bs, mtl) grid
  from its HLO-derived features alone.  The gated metric is the
  median-of-fold-medians relative error (``medrelerr``, lower-is-better:
  fresh must stay under ratio x base + floor).  The paper-table jobs
  split into architecture families (inception, mobilenet, resnet, nasnet,
  ...); singleton families (textclassif, deepspeech2) predict worst and
  are reported via ``jobs_ok`` (folds with median error <= 0.30).

* ``costmodel/warmstart/<job>`` — the acceptance scenario: a COLD process
  (empty surface library, so the similarity tier refuses) with a trained
  cost model reaches the HybridScaler steady point for a held-out job in
  strictly fewer distinct probes than the refusal path.  The invariants —
  support mask all-False, analytic pins bit-identical between the two
  paths, strict probe reduction, same steady point — are asserted
  in-process; the row only reports the counts.
"""

from __future__ import annotations

import collections
import tempfile
import time

import numpy as np

BS_GRID = (1, 2, 4, 8, 16, 32, 64, 128)
MAX_MTL = 10
DEVICE_CLASS = "tesla-p40"
# held-out job for the warm-start scenario: mobilenet_v2_1/imagenet
# (paper job 6) — low LOO error and a paper steady point of MTL=10, the
# longest climb from (1, 1), so the start-point hint has room to help
HELD = ("mobilenet_v2_1", "imagenet")
HELD_SLO_S = 0.081
DRIVE_STEPS = 400


def _paper_pairs():
    from repro.serving.workload import PAPER_JOBS
    seen = []
    for job in PAPER_JOBS:
        pair = (job.dnn, job.dataset)
        if pair not in seen:
            seen.append(pair)
    return seen


def _truth_grid(dnn, ds):
    from repro.serving import device_model as dm
    prof = dm.paper_profile(dnn, ds)
    return dm.mt_latency_grid(dm.TESLA_P40, prof, BS_GRID,
                              tuple(range(1, MAX_MTL + 1)))


def _dense_records(pairs):
    """Persist one dense probed surface per pair; return the raw records."""
    from repro.core.matrix_completion import SurfaceLibrary
    from repro.perf.profile_store import ProfileStore
    with tempfile.TemporaryDirectory() as tmp:
        st = ProfileStore(tmp)
        lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
        for dnn, ds in pairs:
            lat = _truth_grid(dnn, ds)
            key = ("bench", dnn, ds)
            for i, b in enumerate(BS_GRID):
                for j in range(MAX_MTL):
                    lib.observe(key, b, j + 1, float(lat[i, j]))
            st.persist_surface(lib, key, signature=f"{dnn}/{ds}",
                               device_class=DEVICE_CLASS,
                               tile_dependent=False)
        return dict(st.section("surfaces"))


def _store_excluding(records, exclude_sig):
    """Fresh in-memory store holding every record but the held-out one."""
    from repro.perf.profile_store import ProfileStore
    st = ProfileStore("/nonexistent-costmodel-bench")  # never saved
    held_key = ProfileStore.surface_key(exclude_sig, DEVICE_CLASS)
    for sk, rec in records.items():
        if sk != held_key:
            st.put("surfaces", sk, rec)
    return st


def loo_errors(pairs=None, records=None):
    """Per-fold median relative error of the held-out surface prediction."""
    from repro.perf import cost_model as cm
    pairs = pairs or _paper_pairs()
    records = records or _dense_records(pairs)
    errs = {}
    for dnn, ds in pairs:
        sig = f"{dnn}/{ds}"
        st = _store_excluding(records, sig)
        model = cm.train_cost_model(st, DEVICE_CLASS)
        feat = cm.features_for_signature(sig)
        if model is None or feat is None:
            errs[sig] = float("inf")
            continue
        est = model.predict_surface(feat, BS_GRID,
                                    tuple(range(1, MAX_MTL + 1)))
        truth = _truth_grid(dnn, ds)
        rel = np.abs(np.asarray(est) - truth) / truth
        errs[sig] = float(np.median(rel))
    return errs


def _drive(ctrl, ex, steps=DRIVE_STEPS):
    acts = []
    for _ in range(steps):
        act = ctrl.action()
        res = ex.run_step(act.bs, act.mtl)
        ctrl.observe(res["step_time"], res)
        acts.append((act.bs, act.mtl))
    return collections.Counter(acts[-100:]).most_common(1)[0][0]


class _ColdExecutor:
    """SimExecutor minus the analytic ``price_surface`` oracle.

    In simulation the pricing oracle IS the ground truth, so a scaler
    seeded from it converges near-optimally with or without a prior.  A
    cold real deployment has no such oracle — the scaler discovers the
    frontier by probing, which is exactly the cost the zero-probe prior
    amortizes.  Hiding the oracle puts both paths in that regime."""

    def __init__(self, inner):
        self._inner = inner

    def __getattr__(self, name):
        if name == "price_surface":
            raise AttributeError(name)
        return getattr(self._inner, name)


def warmstart_scenario(records=None, *, seed=7):
    """Cold-process warm start vs library refusal on the held-out job.

    Returns (probes_model, probes_refusal, steady_model, steady_refusal)
    after asserting the tier invariants in-process."""
    from repro.core.controller import DNNScalerController
    from repro.core.matrix_completion import SurfaceLibrary
    from repro.perf import cost_model as cm
    from repro.serving import device_model as dm
    from repro.serving.executor import SimExecutor

    pairs = _paper_pairs()
    records = records or _dense_records(pairs)
    sig = f"{HELD[0]}/{HELD[1]}"
    model = cm.train_cost_model(_store_excluding(records, sig), DEVICE_CLASS)
    assert model is not None, "training refused with 28 dense rows"
    assert sig not in model.train_signatures, "held-out job leaked into fit"
    feat = cm.features_for_signature(sig)
    prof = dm.paper_profile(*HELD)

    def spawn(with_model):
        lib = SurfaceLibrary(bs_values=BS_GRID, max_mtl=MAX_MTL)
        if with_model:
            lib.set_cost_model(model)
            lib.register_features("held", feat)
            pred = lib.predict("held")
            assert pred is not None and lib.last_tier == "model"
            est, support = pred
            # the prior is a hint, never history: nothing is "supported"
            assert not support.any(), "cost-model tier claimed support"
            assert np.isfinite(est).all() and (est > 0).all()
        ex = _ColdExecutor(SimExecutor(prof, dm.TESLA_P40, seed=seed))
        ctrl = DNNScalerController(ex, HELD_SLO_S, mode="hybrid",
                                   surface_library=lib, surface_key="held")
        return ctrl, ex

    ctrl_m, ex_m = spawn(True)
    ctrl_r, ex_r = spawn(False)
    # no pricing oracle and an all-False support mask: NEITHER path may
    # have pinned a frontier — the prior is a start hint, never history
    assert ctrl_m._surface is None and ctrl_r._surface is None, \
        "cost-model tier pinned a frontier"
    steady_m = _drive(ctrl_m, ex_m)
    steady_r = _drive(ctrl_r, ex_r)
    # latency noise near the SLO boundary can flip the steady point one
    # bs rung either way on any given seed; the same MTL plateau and an
    # adjacent bs rung is the same operating regime
    assert steady_m[1] == steady_r[1] and \
        max(steady_m[0], steady_r[0]) <= 2 * min(steady_m[0], steady_r[0]), \
        f"warm start converged elsewhere: {steady_m} != {steady_r}"
    assert ctrl_m.probe_count < ctrl_r.probe_count, \
        (f"no probe saving: model={ctrl_m.probe_count} "
         f"refusal={ctrl_r.probe_count}")
    return ctrl_m.probe_count, ctrl_r.probe_count, steady_m, steady_r


def bench_costmodel():
    from repro.perf import cost_model as cm

    rows = []
    pairs = _paper_pairs()
    records = _dense_records(pairs)

    st = _store_excluding(records, "")        # full store: nothing excluded
    t0 = time.perf_counter()
    model = cm.train_cost_model(st, DEVICE_CLASS)
    t_train = time.perf_counter() - t0
    assert model is not None
    rows.append(("costmodel/train", t_train * 1e6,
                 f"rows={model.n_rows},dim={len(model.mu)}"))

    t0 = time.perf_counter()
    errs = loo_errors(pairs, records)
    t_loo = time.perf_counter() - t0
    med = float(np.median(list(errs.values())))
    ok = sum(1 for e in errs.values() if e <= 0.30)
    rows.append(("costmodel/loo", t_loo * 1e6 / len(errs),
                 f"medrelerr={med:.4f},jobs_ok={ok},folds={len(errs)}"))

    t0 = time.perf_counter()
    pm, pr, steady, _ = warmstart_scenario(records)
    t_ws = time.perf_counter() - t0
    rows.append((f"costmodel/warmstart/{HELD[0]}", t_ws * 1e6,
                 f"probes_model={pm},probes_refusal={pr},saved={pr - pm},"
                 f"steady_bs={steady[0]},steady_mtl={steady[1]}"))
    return rows
