"""Scenario-matrix benches: {steady, diurnal, flash-crowd} traffic x
{fixed, spot} capacity x {power-packed, spread} placement, served by the
MPS partition planner with the HybridScaler's share axis active.

Each cell reports goodput, minimum per-job SLO attainment, and
joules-per-good-request (the packing objective's currency: `pack`
consolidates tenants onto few devices so idle floors are paid on 2 of 4
devices; `spread` pays all 4).  The suite gates itself in-process:

  * every cell holds >= ATTAIN_FLOOR minimum per-job SLO attainment;
  * request conservation (submitted == completed + rejected + backlog)
    holds per job in every cell — including under spot revocation, where
    a force-killed tenant's stranded backlog moves to `rejected`;
  * pack beats spread on joules-per-good-request at equal goodput for
    every (traffic, capacity) pair;
  * k uniform slices of 1/k sum to the whole-device MTL-k power draw
    (the per-slice power model's calibration invariant);
  * one spot+flash cell is bit-identical between the exact and
    vectorized engines.

`--check` gates the goodput rows (higher-is-better, 10%) and the jpg
rows (lower-is-better envelope) against the committed baseline; the
in-process asserts re-fire on every check run because check_against
re-executes the suite function.
"""

from __future__ import annotations

SEED = 3
HORIZON_S = 240.0
ATTAIN_FLOOR = 0.95
# pack must beat spread on joules-per-good-request while goodput stays
# within this relative band — "measurably fewer joules at EQUAL goodput"
GOODPUT_BAND = 0.02


def _cell_name(traffic: str, spot: bool, policy: str) -> str:
    return f"scenarios/{traffic}/{'spot' if spot else 'fixed'}/{policy}"


def bench_scenarios():
    import numpy as np

    from repro.serving import device_model as dm
    from repro.serving.cluster import (SCENARIO_TRAFFICS,
                                       run_scenario_cluster)

    rows = []

    # calibration row: k uniform tenants at share 1/k, mtl=1 sum to the
    # whole-device MTL-k draw — spatial multiplexing at equal aggregate
    # share burns what the paper's MTL curves burn
    dev = dm.TESLA_P40
    prof = dm.paper_profile("inception_v1")
    worst = 0.0
    for bs in (1, 4, 16, 64):
        for k in range(1, 9):
            total = k * dm.slice_power(dev, prof, bs, 1, share=1.0 / k,
                                       inv_share=float(k), tenants=k)
            whole = dm.power(dev, prof, bs, k)
            worst = max(worst, abs(total - whole) / whole)
    assert worst <= 1e-9, \
        f"uniform k-slice power sum drifted from MTL-k draw: rel {worst:.2e}"
    rows.append(("scenarios/uniform_power_sum", 0.0,
                 f"max_rel_err={worst:.1e}"))

    cells = {}
    flash_spot_spread = None
    for traffic in SCENARIO_TRAFFICS:
        for spot in (False, True):
            for policy in ("pack", "spread"):
                rep = run_scenario_cluster(
                    traffic, spot=spot, power_policy=policy,
                    seed=SEED, horizon_s=HORIZON_S)
                a = rep["aggregate"]
                name = _cell_name(traffic, spot, policy)
                assert a["conserved"], f"{name}: conservation broken"
                for j in rep["per_job"]:
                    assert j["submitted"] == (j["completed"] + j["rejected"]
                                              + j["backlog"]), \
                        f"{name}: job {j['job_id']} leaked requests"
                assert not a["truncated"], f"{name}: truncated run"
                assert a["min_attainment"] >= ATTAIN_FLOOR, \
                    (f"{name}: min attainment {a['min_attainment']:.3f} "
                     f"< {ATTAIN_FLOOR}")
                jpg = a["joules_per_good_request"]
                assert jpg is not None and np.isfinite(jpg) and jpg > 0.0
                cells[(traffic, spot, policy)] = a
                if (traffic, spot, policy) == ("flash", True, "spread"):
                    flash_spot_spread = rep
                rows.append((name, 0.0,
                             f"goodput={a['goodput']:.1f}/s,"
                             f"attain={a['min_attainment']:.3f},"
                             f"jpg={jpg:.4f}J,"
                             f"energy={a['energy_j']:.0f}J,"
                             f"devs_powered={a['devices_powered']},"
                             f"evac={a['preempt_evacuated']},"
                             f"killed={a['preempt_killed']},"
                             f"conserved={'yes' if a['conserved'] else 'NO'}"
                             + (",truncated=1" if a.get("truncated")
                                else "")))

    # pack vs spread: fewer joules per good request at equal goodput,
    # for every traffic shape and capacity mix
    for traffic in SCENARIO_TRAFFICS:
        for spot in (False, True):
            pack = cells[(traffic, spot, "pack")]
            spread = cells[(traffic, spot, "spread")]
            gp, gs = pack["goodput"], spread["goodput"]
            assert abs(gp - gs) <= GOODPUT_BAND * max(gp, gs), \
                (f"{traffic}/spot={spot}: pack and spread goodput differ "
                 f"{gp:.1f} vs {gs:.1f} — jpg comparison not apples-to-"
                 f"apples")
            jp = pack["joules_per_good_request"]
            js = spread["joules_per_good_request"]
            assert jp < js, \
                (f"{traffic}/spot={spot}: pack jpg {jp:.4f} not below "
                 f"spread jpg {js:.4f}")
            cap = "spot" if spot else "fixed"
            rows.append((f"scenarios/{traffic}/{cap}/pack_vs_spread", 0.0,
                         f"jpg_ratio={jp / js:.3f},"
                         f"joules_saved_frac={1.0 - jp / js:.3f}"))

    # spot cells must actually exercise the preemption machinery
    assert any(cells[(t, True, p)]["preemptions"] > 0
               for t in SCENARIO_TRAFFICS for p in ("pack", "spread")), \
        "no spot cell fired a revocation"
    assert any(cells[(t, True, "spread")]["preempt_evacuated"] > 0
               for t in SCENARIO_TRAFFICS), \
        "no spread spot cell evacuated a tenant"

    # exact-vs-vector conformance on the hardest cell (spot revocation
    # mid-flash-crowd): the full report must be bit-identical
    vec = run_scenario_cluster("flash", spot=True, power_policy="spread",
                               seed=SEED, horizon_s=HORIZON_S,
                               vectorized=True)
    identical = vec == flash_spot_spread
    assert identical, "vectorized scenario engine diverged from exact"
    rows.append(("scenarios/exact_vs_vector", 0.0,
                 f"bit_identical={identical}"))
    return rows
