"""Roofline benchmark: reads the dry-run JSON artifacts (launch/dryrun.py)
and emits the per-(arch x shape x mesh) roofline terms as CSV rows."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def bench_roofline():
    rows = []
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        return [("roofline/missing", 0.0,
                 "run: PYTHONPATH=src python -m repro.launch.dryrun")]
    n_ok = n_skip = 0
    for f in files:
        rec = json.load(open(f))
        key = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "SKIP":
            n_skip += 1
            rows.append((key, 0.0, "SKIP:" + rec["reason"][:60]))
            continue
        if rec["status"] != "OK":
            rows.append((key, 0.0, "FAIL:" + rec.get("error", "?")[:60]))
            continue
        n_ok += 1
        rl = rec["roofline"]
        bound = max(rl["t_compute"], rl["t_memory"], rl["t_collective"])
        rows.append((key, bound * 1e6,
                     f"dom={rl['dominant']},"
                     f"tc={rl['t_compute'] * 1e3:.2f}ms,"
                     f"tm={rl['t_memory'] * 1e3:.2f}ms,"
                     f"tx={rl['t_collective'] * 1e3:.2f}ms,"
                     f"useful={rl['useful_flops_ratio']:.3f},"
                     f"mem_chip={rl['memory_per_chip'] / 1e9:.2f}GB"))
    rows.append(("roofline/summary", 0.0, f"ok={n_ok},skip={n_skip}"))
    return rows
