"""Token-level continuous batching: slot engine vs static bucketed baseline.

One deterministic ragged-length decode trace (seed 0, lognormal output
lengths) served twice on the same analytic device — once by the slot-based
continuous engine (admit-on-free-slot / evict-on-EOS), once by the classic
fixed-shape bucketed baseline where a finished sequence holds its slot
until the batch's LONGEST member drains.  Gated metrics (deterministic per
seed, simulated time):

  * ``goodput=``  — decode tokens/s of requests meeting BOTH per-token
    SLOs (TTFT = queue + prefill; TPOT = mean seconds per output token);
  * ``speedup=``  — the continuous/static goodput ratio, CAPPED at 4x
    before pinning: the PR's contract is ">= 1.5x", and the cap keeps the
    --check floor meaningful (0.9 x 4 = 3.6 >= 1.5) while the static
    baseline sits far past its saturation cliff (the uncapped
    ``raw_speedup`` rides along in the row);
  * ``maxerr=``   — the paged-KV Pallas kernel vs the ragged-length
    oracle on a continuous-batch-shaped ragged batch (lower-is-better
    envelope, like the kernels suite).

The contract is ALSO asserted in-process: raw speedup >= 1.5 and the
continuous engine meeting its SLOs (attainment >= 0.95) raise, turning a
qualitative regression into a suite ERROR rather than a quieter metric
drift.
"""

from __future__ import annotations

import time

# the committed operating point: 16 slots, arrivals at 12 req/s (inside
# continuous capacity, past the static engine's saturation cliff)
N_REQUESTS = 300
RATE_RPS = 12.0
SLOTS = 16
TTFT_SLO_S = 1.0
TPOT_SLO_S = 0.05
SPEEDUP_CAP = 4.0


def _paged_kernel_row():
    import jax
    import jax.numpy as jnp
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref_ragged

    B, S, H, KV, hd, psz = 8, 1024, 8, 2, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32) * 0.5
    # ragged per-slot lengths — the live-batch shape mid-trace
    lens = jnp.asarray([1024, 700, 512, 301, 128, 37, 1, 0], jnp.int32)
    ns = S // psz
    kp = k.reshape(B, ns, psz, KV, hd).reshape(B * ns, psz, KV, hd)
    vp = v.reshape(B, ns, psz, KV, hd).reshape(B * ns, psz, KV, hd)
    tbl = jnp.arange(B * ns, dtype=jnp.int32).reshape(B, ns)

    out = paged_decode_attention(q, kp, vp, lens, tbl)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3):
        out = paged_decode_attention(q, kp, vp, lens, tbl)
    jax.block_until_ready(out)
    wall = (time.perf_counter() - t0) / 3
    ref = decode_attention_ref_ragged(q, k, v, lens)
    err = float(jnp.max(jnp.abs(out - ref)))
    return (f"tokens/paged_kernel/ragged_{B}x{S}", wall * 1e6,
            f"maxerr={err:.3e}")


def bench_tokens():
    from repro.configs.base import get_config
    from repro.serving.device_model import llm_profile
    from repro.serving.token_engine import (ragged_decode_trace,
                                            run_token_serving)

    rows = [_paged_kernel_row()]
    prof = llm_profile(get_config("gemma2-2b"), mode="decode",
                       kv_seq_budget=1024)
    trace = ragged_decode_trace(N_REQUESTS, 0, rate_rps=RATE_RPS)
    reports = {}
    for pol in ("continuous", "static"):
        t0 = time.perf_counter()
        rep = run_token_serving(prof, policy=pol, seed=0, trace=trace,
                                max_slots=SLOTS, static_bs=SLOTS,
                                ttft_slo_s=TTFT_SLO_S,
                                tpot_slo_s=TPOT_SLO_S)
        wall = time.perf_counter() - t0
        assert rep["conserved"], f"{pol}: request conservation violated"
        reports[pol] = rep
        rows.append((f"tokens/{pol}/{SLOTS}slots", wall * 1e6,
                     f"goodput={rep['goodput_tokens_s']:.1f}tok/s,"
                     f"ttft_attain={rep['ttft_attainment']:.3f},"
                     f"tpot_attain={rep['tpot_attainment']:.3f},"
                     f"ttft_p95={rep['ttft_p95_s'] * 1e3:.1f}ms,"
                     f"tpot_p95={rep['tpot_p95_s'] * 1e3:.2f}ms,"
                     f"conserved={'yes' if rep['conserved'] else 'NO'}"
                     + (",truncated=1" if rep["truncated"] else "")))

    # the same engine under a HybridScaler driving live slots (bs axis)
    t0 = time.perf_counter()
    rep_c = run_token_serving(prof, policy="continuous", seed=0, trace=trace,
                              max_slots=SLOTS, ttft_slo_s=TTFT_SLO_S,
                              tpot_slo_s=TPOT_SLO_S, use_controller=True)
    wall = time.perf_counter() - t0
    assert rep_c["conserved"], "hybrid: request conservation violated"
    rows.append((f"tokens/continuous_hybrid/{SLOTS}slots", wall * 1e6,
                 f"goodput={rep_c['goodput_tokens_s']:.1f}tok/s,"
                 f"ttft_attain={rep_c['ttft_attainment']:.3f},"
                 f"tpot_attain={rep_c['tpot_attainment']:.3f},"
                 f"mean_slots={rep_c['mean_live_slots']:.1f}"))

    cont, stat = reports["continuous"], reports["static"]
    raw = cont["goodput_tokens_s"] / max(stat["goodput_tokens_s"], 1e-9)
    # the PR contract, asserted so a regression is a loud suite ERROR
    assert raw >= 1.5, f"continuous/static goodput {raw:.2f}x < 1.5x"
    assert cont["ttft_attainment"] >= 0.95, \
        f"continuous TTFT attainment {cont['ttft_attainment']:.3f} < 0.95"
    assert cont["tpot_attainment"] >= 0.95, \
        f"continuous TPOT attainment {cont['tpot_attainment']:.3f} < 0.95"
    rows.append(("tokens/continuous_vs_static", 0.0,
                 f"speedup={min(raw, SPEEDUP_CAP):.2f}x,"
                 f"raw_speedup={raw:.2f}x,"
                 f"slo_ok={'yes' if cont['slo_attainment'] >= 0.95 else 'NO'}"))
    return rows
