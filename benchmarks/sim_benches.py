"""Fleet-scale simulator benchmark: the vectorized lockstep engine vs the
object-based reference at 1000 jobs x 1000 devices.

One static job per device (the regime where per-event Python overhead
dominates the reference engine), a 20 s simulated horizon, and a
2M-step budget nobody hits.  The gated metric is the vector/object
sim-steps-per-second speedup, CAPPED at 25x before pinning: the contract
is ">= 20x", and capping keeps machine-to-machine variance above the
floor from flapping the --check gate (0.9 x 25 = 22.5 >= 20) while the
uncapped `raw_speedup` stays in the row for the curious.  `agree` is the
vector/object aggregate-throughput ratio — the bulk path is statistically
equivalent, not bit-identical, so it should sit within a percent of 1.

The `bulk_vectorized_delta` row (non-gated) isolates the bulk path's own
internals: fleet-vectorized per-round draws (`_bulk_vector`) vs the legacy
per-job chunk loop (`_bulk_jobloop`), same scenario.
"""

from __future__ import annotations

import dataclasses
import time

N_JOBS = 1000
N_DEVICES = 1000
HORIZON_S = 20.0
MAX_STEPS = 2_000_000
SPEEDUP_CAP = 25.0


def _scenario():
    from repro.core.controller import StaticController
    from repro.serving.cluster import gpu_fleet
    from repro.serving.workload import PAPER_JOBS
    jobs = [dataclasses.replace(PAPER_JOBS[0], job_id=10_000 + i)
            for i in range(N_JOBS)]
    fleet = gpu_fleet(N_DEVICES)
    return jobs, fleet, (lambda job, ex: StaticController(bs=8, mtl=1))


def _timed_run(cls):
    jobs, fleet, cf = _scenario()
    eng = cls(jobs, fleet, controller_factory=cf, seed=0)
    # time only the event loop: engine construction (placement over 1000
    # devices) is identical for both classes and not what the PR speeds up
    t0 = time.perf_counter()
    rep = eng.run(sim_time_limit=HORIZON_S, max_steps=MAX_STEPS)
    wall = time.perf_counter() - t0
    return eng, rep, wall


def bench_sim():
    from repro.serving.cluster import ClusterEngine, VectorClusterEngine

    rows = []
    ev, rv, tv = _timed_run(VectorClusterEngine)
    eo, ro, to = _timed_run(ClusterEngine)
    for label, eng, rep, wall in (("object", eo, ro, to),
                                  ("vector", ev, rv, tv)):
        a = rep["aggregate"]
        rows.append((f"sim/{N_JOBS}x{N_DEVICES}/{label}", wall * 1e6,
                     f"steps={eng.steps_run},"
                     f"steps_per_s={eng.steps_run / wall:.0f},"
                     f"conserved={'yes' if a['conserved'] else 'NO'}"
                     + (",truncated=1" if a.get("truncated") else "")))
    raw = (ev.steps_run / tv) / (eo.steps_run / to)
    agree = (rv["aggregate"]["aggregate_throughput"]
             / max(ro["aggregate"]["aggregate_throughput"], 1e-9))
    rows.append((f"sim/{N_JOBS}x{N_DEVICES}/speedup", 0.0,
                 f"speedup={min(raw, SPEEDUP_CAP):.2f}x,"
                 f"raw_speedup={raw:.2f}x,agree={agree:.4f}"))

    # bulk-mode internals: fleet-vectorized round draws vs the legacy
    # per-job chunk loop (the >10k-device follow-up).  Non-gated — the
    # metric key is deliberately NOT thr/goodput/speedup, it is a
    # wall-clock ratio on one machine; the statistical-agreement ratio
    # rides along for the curious.
    class _LoopEngine(VectorClusterEngine):
        bulk_use_loop = True

    el, rl, tl = _timed_run(_LoopEngine)
    vec_ratio = (ev.steps_run / tv) / (el.steps_run / tl)
    bulk_agree = (rv["aggregate"]["aggregate_throughput"]
                  / max(rl["aggregate"]["aggregate_throughput"], 1e-9))
    rows.append((f"sim/{N_JOBS}x{N_DEVICES}/bulk_vectorized_delta", tl * 1e6,
                 f"bulk_vec_speedup={vec_ratio:.2f}x,"
                 f"bulk_agree={bulk_agree:.4f}"))
    return rows
