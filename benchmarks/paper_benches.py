"""One benchmark per paper table/figure (DESIGN.md §8).

Each function returns a list of (name, value_seconds_or_metric, derived) rows
that benchmarks/run.py prints as ``name,us_per_call,derived`` CSV.  All runs
use the calibrated SimExecutor (see serving/device_model.py); real-execution
paths are exercised by tests/ and examples/.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.clipper import ClipperController
from repro.core.controller import DNNScalerController, StaticController
from repro.core.matrix_completion import LatencyEstimator
from repro.core.profiler import Profiler
from repro.serving import device_model as dm
from repro.serving.engine import ServingEngine
from repro.serving.executor import SimExecutor
from repro.serving.workload import PAPER_JOBS

DEV = dm.TESLA_P40


def _estimator(exclude_id=-1):
    est = LatencyEstimator(max_mtl=10)
    mtls = list(range(1, 11))
    for j in PAPER_JOBS[:8]:
        if j.job_id != exclude_id:
            curve = dm.mt_latency_curve(DEV, j.profile(), 1, mtls)
            est.add_library_row(dict(zip(mtls, curve)))
    return est


def _run(job, controller_name, steps=2500, seed=0):
    prof = job.profile()
    if controller_name == "dnnscaler":
        ctrl = DNNScalerController(SimExecutor(prof, seed=seed), job.slo_s,
                                   estimator=_estimator(job.job_id))
    else:
        ctrl = ClipperController(job.slo_s)
    eng = ServingEngine(SimExecutor(prof, seed=seed + 1), job.slo_s)
    acc = eng.run(ctrl, max_steps=steps, sim_time_limit=300.0)
    return ctrl, acc


# ---------------------------------------------------------------------------
def bench_fig1_sweeps():
    """Fig 1: BS / MTL sweeps for the 4 preliminary DNNs."""
    rows = []
    nets = ["inception_v1", "inception_v4", "mobilenet_v1_1", "resnet_v2_152"]
    for net in nets:
        prof = dm.paper_profile(net, "imagenet")
        for bs in (1, 8, 32, 128):
            thr = bs / dm.batch_latency(DEV, prof, bs)
            lat = dm.batch_latency(DEV, prof, bs)
            rows.append((f"fig1/{net}/batching/bs{bs}", lat * 1e6,
                         f"thr={thr:.1f}img/s"))
        for mtl in (1, 2, 4, 8):
            lat = dm.mt_latency(DEV, prof, 1, mtl)
            thr = dm.mt_throughput(DEV, prof, 1, mtl)
            rows.append((f"fig1/{net}/tenancy/mtl{mtl}", lat * 1e6,
                         f"thr={thr:.1f}img/s"))
    return rows


def bench_table5_profiler():
    """Table 5: Profiler TI_B / TI_MT and the decision for every job."""
    rows = []
    agree = 0
    for j in PAPER_JOBS:
        prof = j.profile()
        res = Profiler(SimExecutor(prof, seed=j.job_id), probe_steps=5).probe()
        ok = res.approach == (j.paper_method or res.approach)
        agree += ok
        rows.append((f"table5/job{j.job_id}/{j.dnn}-{j.dataset}",
                     res.probe_time_s * 1e6,
                     f"TI_B={res.ti_b:.1f}%,TI_MT={res.ti_mt:.1f}%,"
                     f"pick={res.approach},paper={j.paper_method},"
                     f"agree={ok}"))
    rows.append(("table5/decision_agreement", 0.0, f"{agree}/30"))
    return rows


def bench_fig5_throughput():
    """Fig 5: DNNScaler vs Clipper throughput on all 30 jobs."""
    rows = []
    ratios = []
    for j in PAPER_JOBS:
        ctrl, acc_d = _run(j, "dnnscaler", seed=10 + j.job_id)
        _, acc_c = _run(j, "clipper", seed=50 + j.job_id)
        td, tc = acc_d.throughput, acc_c.throughput
        ratios.append(td / max(tc, 1e-9))
        act = ctrl.action()
        rows.append((f"fig5/job{j.job_id}/{j.dnn}-{j.dataset}",
                     1e6 / max(td, 1e-9),
                     f"dnnscaler={td:.1f}/s,clipper={tc:.1f}/s,"
                     f"x{td / max(tc, 1e-9):.2f},approach={ctrl.approach},"
                     f"steady_bs={act.bs},steady_mtl={act.mtl}"))
    ratios = np.array(ratios)
    rows.append(("fig5/geomean_speedup", 0.0,
                 f"x{np.exp(np.log(ratios).mean()):.2f}"))
    rows.append(("fig5/max_speedup", 0.0, f"x{ratios.max():.2f}"))
    rows.append(("fig5/avg_improvement", 0.0,
                 f"{(ratios.mean() - 1) * 100:.0f}%"))
    return rows


def bench_table6_power():
    """Table 6: power efficiency on the paper's MT jobs."""
    rows = []
    mt_ids = [1, 2, 4, 5, 6, 8, 9, 10, 14, 18, 19, 20, 21, 29, 30]
    for jid in mt_ids:
        j = PAPER_JOBS[jid - 1]
        _, acc_d = _run(j, "dnnscaler", seed=100 + jid)
        _, acc_c = _run(j, "clipper", seed=150 + jid)
        pe_d = acc_d.power_efficiency
        pe_c = acc_c.power_efficiency
        rows.append((f"table6/job{jid}", 0.0,
                     f"dnnscaler={pe_d:.2f}/W,clipper={pe_c:.2f}/W,"
                     f"x{pe_d / max(pe_c, 1e-9):.2f},"
                     f"P_d={acc_d.avg_power:.0f}W,P_c={acc_c.avg_power:.0f}W"))
    return rows


def bench_fig7_traces():
    """Figs 7-8: dynamic adaptation traces (convergence speed)."""
    rows = []
    for jid, nm in ((3, "batching"), (2, "tenancy")):
        j = PAPER_JOBS[jid - 1]
        ctrl, acc = _run(j, "dnnscaler", steps=800, seed=7)
        knob = [t[1] if nm == "batching" else t[2] for t in acc.trace]
        changes = sum(1 for a, b in zip(knob, knob[1:]) if a != b)
        _, acc_c = _run(j, "clipper", steps=800, seed=7)
        knob_c = [t[1] for t in acc_c.trace]
        changes_c = sum(1 for a, b in zip(knob_c, knob_c[1:]) if a != b)
        rows.append((f"fig7/job{jid}/{nm}", 0.0,
                     f"knob_changes_dnnscaler={changes},"
                     f"knob_changes_clipper={changes_c},"
                     f"steady={knob[-1]}"))
    return rows


def bench_fig9_sensitivity():
    """Figs 9-10: SLO changes mid-run (B: inception_v4; MT: inception_v1)."""
    rows = []
    cases = [("inception_v4", 3, "B"), ("inception_v1", 1, "MT")]
    for net, jid, kind in cases:
        j = PAPER_JOBS[jid - 1]
        for direction in ("tighten", "relax"):
            prof = j.profile()
            if direction == "tighten":
                slo_fn = lambda t: j.slo_s if t < 60 else j.slo_s * 0.5
            else:
                slo_fn = lambda t: j.slo_s * 0.5 if t < 60 else j.slo_s
            ctrl = DNNScalerController(SimExecutor(prof, seed=0),
                                       slo_fn(0.0), estimator=_estimator())
            eng = ServingEngine(SimExecutor(prof, seed=1), slo_fn(0.0),
                                slo_schedule=slo_fn)
            acc = eng.run(ctrl, max_steps=12000, sim_time_limit=140.0)
            knob_i = 1 if kind == "B" else 2
            early = [t[knob_i] for t in acc.trace if t[0] < 55]
            late = [t[knob_i] for t in acc.trace if t[0] > 90]
            p95_late = [t[3] for t in acc.trace if t[0] > 90]
            adapted = (late and early and
                       ((direction == "tighten" and late[-1] < early[-1]) or
                        (direction == "relax" and late[-1] > early[-1])))
            rows.append((f"fig9/{net}/{direction}", 0.0,
                         f"knob {early[-1] if early else '?'}->"
                         f"{late[-1] if late else '?'},adapted={bool(adapted)},"
                         f"final_p95={np.mean(p95_late) * 1e3:.0f}ms,"
                         f"final_slo={slo_fn(139) * 1e3:.0f}ms"))
    return rows


def bench_fig11_sole_mt():
    """Fig 11: B-selected jobs would have been worse under pure MT."""
    rows = []
    for jid in (3, 7, 11, 15, 22, 25):
        j = PAPER_JOBS[jid - 1]
        prof = j.profile()
        thr_b, thr_mt = [], []
        for bs in (8, 16, 32, 64, 128):
            lat = dm.batch_latency(DEV, prof, bs)
            if lat <= j.slo_s:
                thr_b.append(bs / lat)
        mtls = np.arange(1, 11)
        lats = dm.mt_latency_curve(DEV, prof, 1, mtls)
        thr_mt = [m / lat for m, lat in zip(mtls, lats) if lat <= j.slo_s]
        best_b = max(thr_b, default=1 / dm.batch_latency(DEV, prof, 1))
        best_mt = max(thr_mt, default=0.0)
        rows.append((f"fig11/job{jid}", 0.0,
                     f"best_B={best_b:.1f}/s,best_MT={best_mt:.1f}/s,"
                     f"B_wins={best_b > best_mt}"))
    return rows


def bench_fig12_combination():
    """Fig 12: combining B+MT helps some nets, not others."""
    rows = []
    for net, bs, sweep_mtl in (("resnet_v2_152", 8, True),
                               ("pnasnet_large", 8, True)):
        prof = dm.paper_profile(net, "imagenet")
        thr = [dm.mt_throughput(DEV, prof, bs, m) for m in (1, 2, 3, 4)]
        gain = thr[1] / thr[0]
        rows.append((f"fig12/{net}/bs8_mtl1-4", 0.0,
                     f"thr={','.join(f'{t:.0f}' for t in thr)},"
                     f"mtl2_gain=x{gain:.2f}"))
    for net in ("mobilenet_v1_1", "mobilenet_v1_025"):
        prof = dm.paper_profile(net, "imagenet")
        thr = [dm.mt_throughput(DEV, prof, b, 5) for b in (1, 2, 4, 8)]
        rows.append((f"fig12/{net}/mtl5_bs1-8", 0.0,
                     f"thr={','.join(f'{t:.0f}' for t in thr)},"
                     f"bs_gain=x{thr[-1] / thr[0]:.2f}"))
    return rows


def bench_llm_serving():
    """Beyond-paper: DNNScaler on the assigned architectures (TPU v5e,
    submesh tenancy; decode-mode profiles)."""
    from repro.configs.base import ARCH_IDS, get_config
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        prof = dm.llm_profile(cfg, mode="decode")
        base = dm.batch_latency(dm.TPU_V5E, prof, 1)
        slo = base * 4
        ex = SimExecutor(prof, device=dm.TPU_V5E, seed=0)
        ctrl = DNNScalerController(ex, slo, estimator=LatencyEstimator())
        eng = ServingEngine(SimExecutor(prof, device=dm.TPU_V5E, seed=1), slo)
        acc = eng.run(ctrl, max_steps=1200, sim_time_limit=120.0)
        act = ctrl.action()
        rows.append((f"llm/{arch}", base * 1e6,
                     f"approach={ctrl.approach},bs={act.bs},mtl={act.mtl},"
                     f"thr={acc.throughput:.0f}tok/s,"
                     f"attain={acc.slo_attainment:.2f}"))
    return rows


def bench_churn():
    """Beyond-paper: online job churn — admissions/drains mid-run under
    {static-union, dynamic re-placement, dynamic + shared surface}
    placement policies on one shared trace.  Goodput (SLO-attainment-
    weighted completions per second) is the headline; request
    conservation is checked on every row."""
    from repro.serving.cluster import CHURN_POLICIES, run_churn_cluster
    from repro.serving.workload import churn_trace

    rows = []
    horizon, seed = 120.0, 1
    trace = churn_trace(horizon_s=horizon, n_initial=4, n_churn=10,
                        mean_lifetime_s=30.0, seed=seed)
    goodput = {}
    for policy in CHURN_POLICIES:
        rep = run_churn_cluster(policy, trace=list(trace), n_devices=5,
                                horizon_s=horizon, seed=seed)
        a = rep["aggregate"]
        conserved = a["conserved"] and all(
            r["submitted"] == r["completed"] + r["rejected"] + r["backlog"]
            for r in rep["per_job"])
        goodput[policy] = a["goodput"]
        rows.append((f"churn/{policy}", 0.0,
                     f"goodput={a['goodput']:.1f}/s,"
                     f"thr={a['aggregate_throughput']:.1f}/s,"
                     f"migs={a['migrations']},"
                     f"mig_stall={a['migration_stall_s']:.1f}s,"
                     f"conserved={'yes' if conserved else 'NO'}"
                     + (",truncated=1" if a.get("truncated") else "")))
    rows.append(("churn/dynamic_vs_union", 0.0,
                 f"x{goodput['dynamic'] / max(goodput['union'], 1e-9):.2f}"))
    rows.append(("churn/surface_vs_union", 0.0,
                 f"x{goodput['surface'] / max(goodput['union'], 1e-9):.2f}"))
    return rows


def bench_partition():
    """Beyond-paper: spatial partition sharing (the third knob).  The
    mixed small/large-DNN churn trace served under {uniform 1/k
    time-share baseline, heterogeneous MPS shares, MIG-grid shares} —
    all three priced by the SAME calibrated spatial model, so the rows
    isolate what heterogeneous shares + cheap resizes buy.  Also pins the
    pricing calibration itself: uniform partitions must reproduce the
    MTL curves bit-identically."""
    import numpy as _np
    from repro.serving.cluster import (PARTITION_POLICIES,
                                       run_partition_cluster)
    from repro.serving.workload import mixed_partition_trace

    rows = []
    # calibration row: uniform 1/m spatial shares == the paper's MTL curve
    prof = dm.paper_profile("inception_v1")
    bs = _np.array([1, 2, 4, 8, 16, 32, 64, 128])
    ident = all(
        _np.array_equal(
            dm.part_latency_grid(DEV, prof, bs, [1],
                                 inv_share=float(m), tenants=m),
            dm.mt_latency_grid(DEV, prof, bs, [m]))
        for m in range(1, 11))
    rows.append(("partition/uniform_equals_mtl_pricing", 0.0,
                 f"bit_identical={ident}"))

    horizon, seed = 120.0, 1
    trace = mixed_partition_trace(horizon_s=horizon, n_light=5, seed=seed)
    goodput = {}
    for policy in PARTITION_POLICIES:
        rep = run_partition_cluster(policy, trace=list(trace), n_devices=2,
                                    horizon_s=horizon, seed=seed)
        a = rep["aggregate"]
        goodput[policy] = a["goodput"]
        rows.append((f"partition/{policy}", 0.0,
                     f"goodput={a['goodput']:.1f}/s,"
                     f"thr={a['aggregate_throughput']:.1f}/s,"
                     f"resizes={a['resizes']},"
                     f"resize_stall={a['resize_stall_s']:.2f}s,"
                     f"migs={a['migrations']},"
                     f"mig_stall={a['migration_stall_s']:.1f}s,"
                     f"conserved={'yes' if a['conserved'] else 'NO'}"
                     + (",truncated=1" if a.get("truncated") else "")))
    rows.append(("partition/het_vs_uniform", 0.0,
                 f"x{goodput['het'] / max(goodput['uniform'], 1e-9):.2f}"))
    return rows


def bench_burst():
    """Beyond-paper: open-loop bursty arrivals (paper §3.2 mentions bursty
    workloads) — DNNScaler vs static bs=1 under a 3x burst."""
    from repro.serving.engine import OpenLoopEngine
    rows = []
    for jid in (3, 12):
        j = PAPER_JOBS[jid - 1]
        prof = j.profile()
        rate = 2.0 / dm.batch_latency(DEV, prof, 1)
        for name, mk in (
            ("dnnscaler", lambda: DNNScalerController(
                SimExecutor(prof, seed=0), j.slo_s, estimator=_estimator())),
            ("static_bs1", lambda: StaticController(bs=1, mtl=1)),
        ):
            eng = OpenLoopEngine(SimExecutor(prof, seed=1), j.slo_s,
                                 arrival_rate=rate, burst_factor=3.0, seed=2)
            acc = eng.run(mk(), max_steps=4000, sim_time_limit=120.0)
            rows.append((f"burst/job{jid}/{name}", 0.0,
                         f"served={acc.total_items},thr={acc.throughput:.1f}/s,"
                         f"e2e_p95={acc.p95*1e3:.0f}ms,"
                         f"backlog={len(eng.queue)}"))
    return rows


def bench_alpha_ablation():
    """Ablation: the paper sets alpha=0.85 'empirically' — sweep it and
    report the throughput/violation trade-off it balances."""
    from repro.core.scaler import BatchScaler
    rows = []
    j = PAPER_JOBS[2]
    prof = j.profile()
    for alpha in (0.70, 0.80, 0.85, 0.90, 0.95):
        class _Ctl:
            def __init__(self):
                self.sc = BatchScaler(j.slo_s, alpha=alpha)
            def set_slo(self, s):
                self.sc.set_slo(s)
            def action(self):
                return self.sc.action()
            def observe(self, p95, res=None):
                self.sc.observe(p95, res)
        eng = ServingEngine(SimExecutor(prof, seed=5), j.slo_s)
        acc = eng.run(_Ctl(), max_steps=2500, sim_time_limit=240.0)
        knob_changes = sum(1 for a, b in zip(acc.trace, acc.trace[1:])
                           if a[1] != b[1])
        rows.append((f"alpha/{alpha:.2f}", 0.0,
                     f"thr={acc.throughput:.1f}/s,"
                     f"attain={acc.slo_attainment:.3f},"
                     f"knob_changes={knob_changes}"))
    return rows


def bench_matrix_completion_ablation():
    """Ablation: matrix completion (library) vs naive 2-point interpolation
    for the MTL jump accuracy (paper's Fig 4 mechanism)."""
    from repro.core.matrix_completion import LatencyEstimator
    rows = []
    est_lib = _estimator()
    est_naive = LatencyEstimator(max_mtl=10)   # empty library -> interpolation
    for name, est in (("library", est_lib), ("interp", est_naive)):
        errs, jump_err = [], []
        for j in PAPER_JOBS[10:]:
            prof = j.profile()
            truth = np.array([dm.mt_latency(DEV, prof, 1, m)
                              for m in range(1, 11)])
            pred = est.estimate({1: truth[0], 8: truth[7]})
            errs.append(np.mean(np.abs(pred - truth) / truth))
            best_true = max([m for m in range(1, 11)
                             if truth[m - 1] < j.slo_s], default=1)
            mtl, _ = est.pick_mtl({1: truth[0], 8: truth[7]}, j.slo_s)
            jump_err.append(abs(mtl - best_true))
        rows.append((f"matcomp/{name}", 0.0,
                     f"rel_err={np.mean(errs)*100:.1f}%,"
                     f"mean_jump_error={np.mean(jump_err):.2f}_instances"))
    return rows


def bench_cluster():
    """Beyond-paper: the multi-job cluster scenario — a 12-job slice of the
    Table-4 trace on a 5-device fleet under every controller policy, plus
    the full 30-job/12-device aggregate for {paper, hybrid} (short horizon;
    examples/cluster_serve.py runs the converged 300 s version)."""
    from repro.serving.cluster import gpu_fleet, run_paper_cluster
    rows = []
    jobs = PAPER_JOBS[:12]
    fleet = gpu_fleet(5)
    thr = {}
    for mode in ("auto", "hybrid", "B", "MT", "clipper"):
        rep = run_paper_cluster(mode, jobs=jobs, fleet=fleet,
                                sim_time_limit=90.0)
        a = rep["aggregate"]
        thr[mode] = a["aggregate_throughput"]
        rows.append((f"cluster/slice12/{mode}", 0.0,
                     f"thr={a['aggregate_throughput']:.1f}/s,"
                     f"meet_slo={a['jobs_meeting_slo']}/{a['feasible_jobs']},"
                     f"stall={a['total_stall_s']:.1f}s"
                     + (",truncated=1" if a.get("truncated") else "")))
    best_pure = max(thr["auto"], thr["B"], thr["MT"])
    rows.append(("cluster/slice12/hybrid_vs_best_pure", 0.0,
                 f"x{thr['hybrid'] / max(best_pure, 1e-9):.2f}"))
    full = {}
    for mode in ("auto", "hybrid"):
        rep = run_paper_cluster(mode, n_devices=12, sim_time_limit=90.0,
                                seed=2)
        a = rep["aggregate"]
        full[mode] = a["aggregate_throughput"]
        rows.append((f"cluster/full30/{mode}", 0.0,
                     f"thr={a['aggregate_throughput']:.1f}/s,"
                     f"meet_slo={a['jobs_meeting_slo']}/{a['feasible_jobs']},"
                     f"stall={a['total_stall_s']:.1f}s"
                     + (",truncated=1" if a.get("truncated") else "")))
    rows.append(("cluster/full30/hybrid_vs_paper", 0.0,
                 f"x{full['hybrid'] / max(full['auto'], 1e-9):.2f}"))
    return rows


def bench_matcomp_nonlinear():
    """Where matrix completion beats interpolation: latency curves with a
    saturation knee (the regime of real GPU co-location — latency is flat
    until the accelerator saturates, then grows steeply).  Two observations
    at MTL={1,8} straddle the knee; linear interpolation misplaces it, a
    library of same-shaped curves recovers it."""
    from repro.core.matrix_completion import LatencyEstimator
    import numpy as _np

    def knee_curve(base, knee, steep):
        return _np.array([base * (1.0 + max(0, m - knee) * steep)
                          for m in range(1, 11)])

    rng = _np.random.default_rng(0)
    rows = []
    lib = LatencyEstimator(max_mtl=10)
    for _ in range(12):
        c = knee_curve(rng.uniform(5, 50), rng.integers(3, 7),
                       rng.uniform(0.4, 0.9))
        lib.add_library_row({m: c[m - 1] for m in range(1, 11)})
    naive = LatencyEstimator(max_mtl=10)

    for name, est in (("library", lib), ("interp", naive)):
        errs, jump = [], []
        for i in range(20):
            c = knee_curve(rng.uniform(5, 50), rng.integers(3, 7),
                           rng.uniform(0.4, 0.9))
            pred = est.estimate({1: c[0], 8: c[7]})
            errs.append(_np.mean(_np.abs(pred - c) / c))
            slo = c[0] * 1.8
            best = max([m for m in range(1, 11) if c[m - 1] < slo], default=1)
            mtl, _ = est.pick_mtl({1: c[0], 8: c[7]}, slo)
            jump.append(abs(mtl - best))
        rows.append((f"matcomp_nonlinear/{name}", 0.0,
                     f"rel_err={_np.mean(errs)*100:.1f}%,"
                     f"mean_jump_error={_np.mean(jump):.2f}_instances"))
    return rows
