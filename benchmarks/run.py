# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run [--only X]
[--json DIR] [--check DIR]``.

``--json DIR`` additionally writes one ``BENCH_<suite>.json`` per suite
(rows + wall time + autotune-cache stats) — the persisted perf trajectory:
each PR's recorded baselines live next to the previous ones, so a
regression shows up as a diff, not a memory.

``--check DIR`` re-runs every suite that has a committed
``BENCH_<suite>.json`` in DIR and compares the fresh throughput/goodput
metrics row by row against the baseline, exiting nonzero on any >10%
regression (``--check-tol`` to change).  Wall-clock rows (us_per_call)
are NOT gated — they are too noisy across machines; the gated metrics
come from the simulated-time engines and are deterministic per seed.
The kernels suite is gated on its ``maxerr=`` rows (pallas vs reference
max abs error — a lower-is-better envelope; see ``_LOWER_METRICS``) plus
row presence, not on its wall-clock timings.

Suites (one per paper table/figure — DESIGN.md §8):
  fig1          BS / MTL sweeps (preliminary study)
  table5        Profiler TI_B / TI_MT + decisions vs paper Table 4
  fig5          DNNScaler vs Clipper throughput, 30 jobs
  table6        power efficiency on MT jobs
  fig7          adaptation-speed traces
  fig9          SLO-change sensitivity
  fig11         sole-MT check on B jobs
  fig12         B+MT combination
  llm           DNNScaler on the assigned architectures (TPU model)
  cluster       multi-job cluster serving: paper vs hybrid vs pure knobs
  churn         online admit/drain churn: union vs dynamic vs shared surface
  partition     spatial partition sharing: uniform vs heterogeneous shares
  burst         open-loop bursty arrivals: DNNScaler vs static (beyond paper)
  sim           fleet-scale simulator: vectorized engine vs object reference
                at 1000 jobs x 1000 devices (gated on the speedup ratio)
  scenarios     scenario matrix: {steady,diurnal,flash} traffic x
                {fixed,spot} capacity x {pack,spread} power packing —
                gated on goodput and joules-per-good-request, with
                attainment/conservation/power-sum asserts in-process
  tokens        token-level continuous batching: slot engine vs the static
                bucketed baseline on one ragged decode trace (gated on
                goodput and the capped continuous/static ratio), plus the
                paged-KV kernel vs the ragged oracle (maxerr)
  disagg        disaggregated prefill/decode: prefill pool + KV-transfer
                fabric vs the best single-device mode on a long-prefill
                trace (gated on goodput and the fleet/single ratio),
                chunked vs co-tenant prefill TTFT attainment, and the
                fabric's transfer accounting vs the analytic model (maxerr)
  alpha         ablation: hysteresis coefficient alpha (paper: 0.85 empirical)
  matcomp       ablation: matrix completion vs naive interpolation
  kernels       Pallas kernel micro-benches (interpret mode)
  real_decode   wall-clock tiny-model decode
  roofline      per-(arch x shape x mesh) terms from the dry-run JSON
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
import time


def suites():
    from benchmarks import (costmodel_benches, disagg_benches, kernel_benches,
                            paper_benches, roofline_bench, scenario_benches,
                            sim_benches, token_benches)
    return {
        "fig1": paper_benches.bench_fig1_sweeps,
        "table5": paper_benches.bench_table5_profiler,
        "fig5": paper_benches.bench_fig5_throughput,
        "table6": paper_benches.bench_table6_power,
        "fig7": paper_benches.bench_fig7_traces,
        "fig9": paper_benches.bench_fig9_sensitivity,
        "fig11": paper_benches.bench_fig11_sole_mt,
        "fig12": paper_benches.bench_fig12_combination,
        "llm": paper_benches.bench_llm_serving,
        "cluster": paper_benches.bench_cluster,
        "churn": paper_benches.bench_churn,
        "partition": paper_benches.bench_partition,
        "burst": paper_benches.bench_burst,
        "alpha": paper_benches.bench_alpha_ablation,
        "matcomp": paper_benches.bench_matrix_completion_ablation,
        "matcomp_nl": paper_benches.bench_matcomp_nonlinear,
        "sim": sim_benches.bench_sim,
        "scenarios": scenario_benches.bench_scenarios,
        "tokens": token_benches.bench_tokens,
        "disagg": disagg_benches.bench_disagg,
        "kernels": kernel_benches.bench_kernels,
        "real_decode": kernel_benches.bench_real_decode,
        "roofline": roofline_bench.bench_roofline,
        "costmodel": costmodel_benches.bench_costmodel,
    }


_COUNTER_KEYS = ("hits", "misses", "timings", "tunes")


def _autotune_stats() -> dict:
    try:
        from repro.perf import autotune
        return autotune.cache_stats()
    except Exception:  # noqa: BLE001 — stats must never fail a bench run
        return {}


def _autotune_delta(before: dict, after: dict) -> dict:
    """Per-suite view: counters as deltas (one process runs many suites;
    cumulative numbers would credit earlier suites' tuning to later ones),
    cache size/location as absolutes."""
    out = dict(after)
    for k in _COUNTER_KEYS:
        if k in after and k in before:
            out[k] = after[k] - before[k]
    return out


# metrics gated by --check: simulated-time results, deterministic per seed
# (wall-clock us_per_call rows are informational only — too noisy to gate).
# "speedup" is the sim suite's vector/object steps-per-second ratio, pinned
# capped (see sim_benches) so the gate floor stays above the 20x contract.
_CHECKED_METRICS = ("thr", "goodput", "speedup")

# lower-is-better gated metrics: numeric-accuracy rows (the kernels suite's
# pallas-vs-reference max abs error).  These are deterministic per seed on
# one machine but float arithmetic differs slightly across CPUs/XLA
# versions, so the gate is a generous (ratio, absolute-floor) envelope:
# regression iff fresh > ratio * baseline + floor — catching a kernel that
# went numerically wrong, not a last-ulp wobble.
_LOWER_METRICS = {"maxerr": (4.0, 1e-6),
                  # joules per good request (scenarios suite): energy is
                  # simulated-deterministic per seed, so the envelope only
                  # absorbs small goodput wobble, not machine noise
                  "jpg": (1.25, 1e-9),
                  # held-out HLO cost-model prediction error (costmodel
                  # suite's leave-one-job-out median relative error): fully
                  # deterministic — analytic truth surfaces, fixed fold
                  # order — so the envelope only absorbs BLAS/solver
                  # last-ulp drift across platforms, not model regressions
                  "medrelerr": (1.5, 0.02)}


def _parse_metrics(derived) -> dict:
    """``k=<float><unit>`` pairs out of a derived string."""
    out = {}
    for part in str(derived).split(","):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        m = re.match(r"[-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?", v.strip())
        if m:
            out[k.strip()] = float(m.group(0))
    return out


def check_against(base_dir: str, *, tol: float = 0.10,
                  only=None) -> int:
    """Re-run every suite with a committed BENCH_<suite>.json in
    `base_dir` and compare fresh throughput/goodput metrics row by row.
    Returns the number of regressions (fresh < (1 - tol) * baseline)."""
    table = suites()
    regressions = 0
    checked = 0
    for path in sorted(glob.glob(os.path.join(base_dir, "BENCH_*.json"))):
        committed = json.load(open(path))
        suite = committed.get("suite")
        if only and suite not in only:
            continue
        if suite not in table:
            # a committed baseline whose suite the harness no longer knows
            # is a broken gate, not a skip: the silent pass used to hide a
            # renamed/deleted suite until its regressions shipped
            print(f"CHECK {suite or path}: UNKNOWN suite for baseline "
                  f"{os.path.basename(path)} — not registered in suites()")
            regressions += 1
            continue
        gated = _CHECKED_METRICS + tuple(_LOWER_METRICS)
        if not any(m in _parse_metrics(r.get("derived", ""))
                   for r in committed.get("rows", [])
                   for m in gated):
            continue    # nothing gated in this baseline (wall-clock-only
            #             suites): don't burn time re-running
        try:
            fresh_rows = table[suite]()
        except Exception as e:  # noqa: BLE001
            print(f"CHECK {suite}: ERROR {type(e).__name__}: {e}")
            regressions += 1
            continue
        if not fresh_rows:
            # a suite that exists in the baseline dir but produced nothing
            # fresh would previously sail through the row loop untested
            print(f"CHECK {suite}: NO FRESH ROWS (baseline has "
                  f"{len(committed.get('rows', []))})")
            regressions += 1
            continue
        fresh = {name: _parse_metrics(derived)
                 for name, _, derived in fresh_rows}
        for name, metrics in fresh.items():
            # a truncated engine run means the row's metrics cover a
            # partial horizon — never comparable, always a failure
            if metrics.get("truncated"):
                print(f"CHECK {suite}: TRUNCATED row {name} "
                      f"(hit max_steps before the simulated horizon)")
                regressions += 1
        for row in committed.get("rows", []):
            base = _parse_metrics(row.get("derived", ""))
            got = fresh.get(row["name"])
            if got is None:
                print(f"CHECK {suite}: MISSING row {row['name']}")
                regressions += 1
                continue
            for metric in _CHECKED_METRICS:
                if metric not in base:
                    continue
                checked += 1
                if metric not in got:
                    print(f"CHECK {suite}: {row['name']} lost "
                          f"metric {metric}")
                    regressions += 1
                elif got[metric] < (1.0 - tol) * base[metric]:
                    print(f"CHECK {suite}: REGRESSION {row['name']} "
                          f"{metric} {base[metric]:.1f} -> "
                          f"{got[metric]:.1f} "
                          f"({got[metric] / base[metric] - 1.0:+.1%})")
                    regressions += 1
            for metric, (ratio, floor) in _LOWER_METRICS.items():
                if metric not in base:
                    continue
                checked += 1
                if metric not in got:
                    print(f"CHECK {suite}: {row['name']} lost "
                          f"metric {metric}")
                    regressions += 1
                elif got[metric] > ratio * base[metric] + floor:
                    print(f"CHECK {suite}: REGRESSION {row['name']} "
                          f"{metric} {base[metric]:.2e} -> "
                          f"{got[metric]:.2e} (limit "
                          f"{ratio * base[metric] + floor:.2e})")
                    regressions += 1
    print(f"CHECK: {checked} metrics compared, {regressions} regressions "
          f"(tolerance {tol:.0%})")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="also write BENCH_<suite>.json files into DIR "
                         "(default: current directory)")
    ap.add_argument("--check", default=None, metavar="DIR",
                    help="compare a fresh run against the committed "
                         "BENCH_*.json baselines in DIR; exit nonzero on "
                         "any >tol regression")
    ap.add_argument("--check-tol", type=float, default=0.10,
                    help="relative regression tolerance for --check "
                         "(default 0.10)")
    args = ap.parse_args()
    if args.check:
        only = set(args.only.split(",")) if args.only else None
        if check_against(args.check, tol=args.check_tol, only=only):
            raise SystemExit(1)
        return
    table = suites()
    names = args.only.split(",") if args.only else list(table)
    if args.json:
        os.makedirs(args.json, exist_ok=True)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        fn = table[name]
        at_before = _autotune_stats()
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            failures += 1
            continue
        wall = time.time() - t0
        for rname, us, derived in rows:
            print(f"{rname},{us:.2f},{derived}")
        print(f"{name}/_suite_wall,{wall * 1e6:.0f},ok", file=sys.stderr)
        if args.json:
            path = os.path.join(args.json, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({
                    "suite": name,
                    "suite_wall_s": wall,
                    "rows": [{"name": r, "us_per_call": u, "derived": d}
                             for r, u, d in rows],
                    "autotune": _autotune_delta(at_before, _autotune_stats()),
                }, f, indent=2)
            print(f"{name} -> {path}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
