"""Disaggregated prefill/decode serving: prefill pool + KV-transfer
fabric vs every single-device prefill mode, plus chunked prefill vs the
co-tenant baseline.  One long-prefill ragged trace per cell (prefill_mean
= 2048 tokens, the ISSUE's floor), all simulated-time and deterministic
per seed.  Gated metrics:

  * ``disagg/fleet/*``     — goodput of the disaggregated fleet (3
    prefill-specialized devices feeding 1 decode device over the ICI
    fabric) at 20 req/s, where every single-device mode is past its
    saturation cliff;
  * ``disagg/single_best`` — the best single-device prefill mode
    (co-tenant / time-slice / chunked / static) on the SAME trace;
  * ``disagg/fleet_vs_single`` — ``speedup=`` fleet/single goodput
    ratio.  The PR contract (>= 1.3x with both SLO attainments >= 0.95
    on the fleet) is asserted in-process, so a qualitative regression is
    a loud suite ERROR, while the 0.9x --check floor guards drift;
  * ``disagg/chunked_vs_cotenant`` — ``speedup=`` here is the
    chunked/co-tenant TTFT-attainment ratio at a 222 ms TTFT budget on a
    4096-token serving context: co-tenant pays the monolithic padded
    prefill (217.8 ms at the full kv budget) for every prompt, chunked
    pays per actual prompt token, so the 2048-token-mean prompts leave
    co-tenant ~4 ms of queueing slack and chunked ~150 ms.  Contract
    (>= 1.1x at equal TPOT attainment) asserted in-process;
  * ``disagg/fabric/ici_exact`` — ``maxerr=`` relative error of the
    fabric's transfer-time/bytes accounting vs the analytic interconnect
    model (latency floor + bytes/bandwidth summed over the trace).  The
    engine charges each transfer through the same ``Interconnect``, in
    arrival order, so the sums must agree to float associativity
    (asserted <= 1e-12 relative in-process).

Request conservation (``submitted == completed + rejected + backlog``,
in-transfer KV folded into backlog) is asserted at every cell's exit.
"""

from __future__ import annotations

import time

N_REQUESTS = 200
PREFILL_MEAN = 2048

# fleet cell: 20 req/s is ~1.55x a single device's prefill+decode
# capacity; 3 pool members keep the prefill stage ahead of decode
FLEET_RPS = 20.0
FLEET_SLOTS = 16
FLEET_POOL = 3
FLEET_TTFT_S = 1.2
FLEET_TPOT_S = 0.05
FLEET_KV_BUDGET = 2048

# chunked cell: a 4096-token serving context over 2048-token-mean prompts
# at a rate well inside decode capacity — isolates prefill pricing
CHUNK_RPS = 6.0
CHUNK_TOKENS = 512
CHUNK_TTFT_S = 0.222
CHUNK_KV_BUDGET = 4096


def _fmt(rep: dict) -> str:
    return (f"goodput={rep['goodput_tokens_s']:.1f}tok/s,"
            f"ttft_attain={rep['ttft_attainment']:.3f},"
            f"tpot_attain={rep['tpot_attainment']:.3f},"
            f"ttft_p95={rep['ttft_p95_s'] * 1e3:.1f}ms,"
            f"tpot_p95={rep['tpot_p95_s'] * 1e3:.2f}ms,"
            f"conserved={'yes' if rep['conserved'] else 'NO'}"
            + (",truncated=1" if rep.get("truncated") else ""))


def bench_disagg():
    from repro.configs.base import get_config
    from repro.serving import device_model as dm
    from repro.serving.disagg import fabric_for, run_disagg_serving
    from repro.serving.token_engine import run_token_serving
    from repro.serving.workload import long_prefill_trace

    cfg = get_config("gemma2-2b")
    rows = []

    # --- fleet cell: disaggregated pool vs every single-device mode ----
    prof = dm.llm_profile(cfg, mode="decode", kv_seq_budget=FLEET_KV_BUDGET)
    trace = long_prefill_trace(N_REQUESTS, 0, rate_rps=FLEET_RPS,
                               prefill_mean=PREFILL_MEAN)
    t0 = time.perf_counter()
    fleet = run_disagg_serving(prof, seed=0, trace=trace,
                               n_prefill=FLEET_POOL, n_decode=1,
                               kv_seq_budget=FLEET_KV_BUDGET,
                               max_slots=FLEET_SLOTS,
                               ttft_slo_s=FLEET_TTFT_S,
                               tpot_slo_s=FLEET_TPOT_S)
    wall = time.perf_counter() - t0
    assert fleet["conserved"], "fleet: request conservation violated"
    rows.append((f"disagg/fleet/{FLEET_POOL}p1d_{FLEET_RPS:.0f}rps",
                 wall * 1e6, _fmt(fleet)))

    best = None
    for mode in ("cotenant", "timeslice", "chunked", "static"):
        t0 = time.perf_counter()
        if mode == "static":
            rep = run_token_serving(prof, policy="static", seed=0,
                                    trace=trace, max_slots=FLEET_SLOTS,
                                    static_bs=FLEET_SLOTS,
                                    ttft_slo_s=FLEET_TTFT_S,
                                    tpot_slo_s=FLEET_TPOT_S)
        else:
            rep = run_token_serving(prof, policy="continuous", seed=0,
                                    trace=trace, max_slots=FLEET_SLOTS,
                                    ttft_slo_s=FLEET_TTFT_S,
                                    tpot_slo_s=FLEET_TPOT_S,
                                    prefill_mode=mode,
                                    chunk_tokens=CHUNK_TOKENS)
        assert rep["conserved"], f"{mode}: request conservation violated"
        if best is None or rep["goodput_tokens_s"] > best[1]:
            best = (mode, rep["goodput_tokens_s"])
    rows.append((f"disagg/single_best/{FLEET_RPS:.0f}rps", 0.0,
                 f"goodput={best[1]:.1f}tok/s,mode={best[0]}"))

    ratio = fleet["goodput_tokens_s"] / max(best[1], 1e-9)
    assert ratio >= 1.3, \
        f"disagg/single goodput {ratio:.2f}x < 1.3x (best={best[0]})"
    assert fleet["ttft_attainment"] >= 0.95, \
        f"fleet TTFT attainment {fleet['ttft_attainment']:.3f} < 0.95"
    assert fleet["tpot_attainment"] >= 0.95, \
        f"fleet TPOT attainment {fleet['tpot_attainment']:.3f} < 0.95"
    rows.append(("disagg/fleet_vs_single", 0.0,
                 f"speedup={ratio:.2f}x,best_single={best[0]},"
                 f"slo_ok={'yes' if fleet['slo_attainment'] >= 0.95 else 'NO'}"))

    # --- fabric accounting vs the analytic interconnect model ----------
    fab = fabric_for(prof, kv_seq_budget=FLEET_KV_BUDGET)
    exp_busy = sum(fab.interconnect.transfer_s(
        fab.kv_bytes_per_token * r.prefill_tokens) for r in trace)
    exp_bytes = sum(fab.kv_bytes_per_token * r.prefill_tokens
                    for r in trace)
    got = fleet["fabric"]
    err = max(abs(got["busy_s"] - exp_busy) / exp_busy,
              abs(got["bytes_moved"] - exp_bytes) / exp_bytes)
    assert got["transfers"] == N_REQUESTS, \
        f"fabric charged {got['transfers']} != {N_REQUESTS} transfers"
    assert err <= 1e-12, f"fabric accounting off by {err:.3e} relative"
    rows.append(("disagg/fabric/ici_exact", 0.0,
                 f"maxerr={err:.3e},transfers={got['transfers']},"
                 f"kv_gb={got['bytes_moved'] / 1e9:.1f}"))

    # --- chunked prefill vs the co-tenant baseline ---------------------
    prof4k = dm.llm_profile(cfg, mode="decode",
                            kv_seq_budget=CHUNK_KV_BUDGET)
    trace4k = long_prefill_trace(N_REQUESTS, 0, rate_rps=CHUNK_RPS,
                                 prefill_mean=PREFILL_MEAN)
    reps = {}
    for mode in ("chunked", "cotenant"):
        t0 = time.perf_counter()
        rep = run_token_serving(prof4k, policy="continuous", seed=0,
                                trace=trace4k, max_slots=FLEET_SLOTS,
                                ttft_slo_s=CHUNK_TTFT_S, tpot_slo_s=0.05,
                                prefill_mode=mode,
                                chunk_tokens=CHUNK_TOKENS)
        wall = time.perf_counter() - t0
        assert rep["conserved"], f"{mode}: request conservation violated"
        reps[mode] = rep
        rows.append((f"disagg/{mode}/{CHUNK_RPS:.0f}rps", wall * 1e6,
                     _fmt(rep)))
    ch, co = reps["chunked"], reps["cotenant"]
    tratio = ch["ttft_attainment"] / max(co["ttft_attainment"], 1e-9)
    assert tratio >= 1.1, \
        f"chunked/cotenant TTFT attainment {tratio:.2f}x < 1.1x"
    assert ch["ttft_attainment"] >= 0.95, \
        f"chunked TTFT attainment {ch['ttft_attainment']:.3f} < 0.95"
    # "at equal TPOT": both modes keep the pure-decode SLO
    assert ch["tpot_attainment"] >= 0.95 and co["tpot_attainment"] >= 0.95, \
        "TPOT attainment not held on both sides of the chunked comparison"
    assert abs(ch["tpot_attainment"] - co["tpot_attainment"]) <= 0.02, \
        "chunked comparison is not at equal TPOT attainment"
    rows.append(("disagg/chunked_vs_cotenant", 0.0,
                 f"speedup={tratio:.2f}x,"
                 f"chunked_ttft={ch['ttft_attainment']:.3f},"
                 f"cotenant_ttft={co['ttft_attainment']:.3f},"
                 f"tpot_equal=yes"))

    # the pool-ratio controller axis on the fleet cell (ride-along: only
    # conservation is asserted — the ladder's demand-following is covered
    # by tests/test_disagg.py)
    t0 = time.perf_counter()
    hyb = run_disagg_serving(prof, seed=0, trace=trace,
                             n_prefill=FLEET_POOL, n_decode=1,
                             kv_seq_budget=FLEET_KV_BUDGET,
                             max_slots=FLEET_SLOTS,
                             ttft_slo_s=FLEET_TTFT_S,
                             tpot_slo_s=FLEET_TPOT_S,
                             use_controller=True,
                             pool_ladder=(1, 2, 3))
    wall = time.perf_counter() - t0
    assert hyb["conserved"], "hybrid fleet: request conservation violated"
    rows.append((f"disagg/fleet_hybrid/{FLEET_POOL}p1d", wall * 1e6,
                 f"goodput={hyb['goodput_tokens_s']:.1f}tok/s,"
                 f"ttft_attain={hyb['ttft_attainment']:.3f},"
                 f"pool_active={hyb['pool']['active']},"
                 f"conserved={'yes' if hyb['conserved'] else 'NO'}"))
    return rows
