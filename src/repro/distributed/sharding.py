"""Divisibility-aware sharding rules for params, inputs and caches.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Strategy (baseline — see EXPERIMENTS.md §Perf for the
beyond-baseline variants):

Params
  * TP over 'model':
      - attention: head axis, only when the KV-head count divides the model
        axis (whisper, zamba2) or KV==1 with Q-heads divisible (granite MQA).
        Otherwise attention weights are replicated over 'model' (the GQA
        reshape would not propagate under GSPMD) — a recorded baseline cost.
      - MLP: d_ff axis (always divisible for the assigned archs).
      - MoE: expert axis when divisible (qwen3: 128/16), else per-expert d_ff
        (mixtral: 8 experts, 16384 d_ff).
      - embeddings / lm_head: vocab axis when divisible, else d_model axis.
      - Mamba blocks: replicated over 'model' (TP for SSD needs grouped B/C —
        beyond baseline), sharded over 'data' in train mode.
  * FSDP over 'data' (train mode, and inference when the TP-sharded params
    exceed the per-chip HBM budget): largest remaining divisible axis.
  * 'pod' replicates params (DP across pods, FSDP within a pod).

Inputs / caches
  * batch axes over ('pod','data') when divisible, else ('data',), else
    replicated.
  * decode KV caches: batch over 'data', *sequence over 'model'* (context-
    parallel decode — reductions over the cache length become all-reduces).
    long_500k (batch=1) shards the sequence over every available axis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

# Per-chip HBM budget (bytes) above which inference params get FSDP too.
HBM_PARAM_BUDGET = 8 * 1024 ** 3


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh

    @property
    def axis_sizes(self) -> dict:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    @property
    def model(self) -> int:
        return self.axis_sizes.get("model", 1)

    @property
    def data(self) -> int:
        return self.axis_sizes.get("data", 1)

    @property
    def has_pod(self) -> bool:
        return "pod" in self.axis_sizes

    @property
    def batch_axes(self) -> tuple:
        return ("pod", "data") if self.has_pod else ("data",)

    @property
    def batch_size(self) -> int:
        return int(np.prod([self.axis_sizes[a] for a in self.batch_axes]))


def attn_head_tp(cfg: ModelConfig, model: int) -> bool:
    """Can attention shard its head axes over the model axis?"""
    if cfg.num_kv_heads and _div(cfg.num_kv_heads, model):
        return True
    if cfg.num_kv_heads == 1 and _div(cfg.num_heads, model):
        return True  # MQA: H -> (1, G) reshape keeps shards aligned
    return False


def batch_spec_axes(minfo: MeshInfo, batch: int):
    """Largest prefix of batch axes that divides `batch`."""
    axes = []
    prod = 1
    for a in minfo.batch_axes:
        if _div(batch, prod * minfo.axis_sizes[a]):
            axes.append(a)
            prod *= minfo.axis_sizes[a]
    return tuple(axes) if axes else None


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------
def _fsdp_axis(shape: tuple, taken: dict, data: int) -> Optional[int]:
    """Largest dim divisible by `data` not already sharded."""
    best, best_dim = None, 0
    for i, s in enumerate(shape):
        if i in taken:
            continue
        if _div(s, data) and s > best_dim:
            best, best_dim = i, s
    return best


def _leaf_spec(path_names: list, shape: tuple, cfg: ModelConfig,
               minfo: MeshInfo, fsdp: bool, q_tp: bool = False) -> P:
    model, data = minfo.model, minfo.data
    name = path_names[-1] if path_names else ""
    parents = set(path_names)
    nd = len(shape)
    tp: dict[int, str] = {}

    def last_dims(k):  # index of k-th dim from the end
        return nd - k

    in_moe = "moe" in parents
    in_attn = ("attn" in parents) or ("cross" in parents)
    in_mlp = "mlp" in parents

    if name in ("wq", "wk", "wv", "wo", "bq", "bk", "bv") and in_attn:
        head_tp = attn_head_tp(cfg, model)
        # q_tp (§Perf beyond-baseline): shard Q/O projections on the Q-head
        # axis whenever H divides the model axis, even if the KV heads don't
        # (K/V weights stay replicated — they are G times smaller).
        q_only = q_tp and not head_tp and _div(cfg.num_heads, model)
        if head_tp or q_only:
            if name in ("wq", "bq"):
                tp[last_dims(2)] = "model"      # (…, d, H, hd) -> H
            elif name in ("wk", "wv", "bk", "bv"):
                # MQA (KV=1) / q-only: K/V stay replicated
                if _div(cfg.num_kv_heads, model):
                    tp[last_dims(2)] = "model"
            else:  # wo: (…, H, hd, d)
                tp[last_dims(3)] = "model"
    elif name in ("wi", "wg") and in_moe:
        # MoE expert weights (…, E, d, f): EP when divisible, else TP on f
        if _div(cfg.num_experts, model):
            tp[last_dims(3)] = "model"
        elif _div(shape[-1], model):
            tp[last_dims(1)] = "model"
    elif name == "wo" and in_moe:
        # (…, E, f, d)
        if _div(cfg.num_experts, model):
            tp[last_dims(3)] = "model"
        elif _div(shape[last_dims(2)], model):
            tp[last_dims(2)] = "model"
    elif name in ("wi", "wg") and in_mlp:
        if _div(shape[-1], model):
            tp[last_dims(1)] = "model"          # dense MLP (…, d, f) -> f
    elif name == "wo" and in_mlp:
        # dense MLP down-proj (…, f, d)
        if _div(shape[last_dims(2)], model):
            tp[last_dims(2)] = "model"
    elif name == "router":
        pass                                     # (…, d, E) small, replicate
    elif name == "embed":
        # Only vocab-axis TP: sharding d_model here propagates a d-sharded
        # layout into every block (and trips XLA SPMD resharding bugs inside
        # scan bodies for odd-vocab archs).  Non-divisible vocab -> replicate
        # over 'model' (FSDP over 'data' still applies in train mode).
        if _div(cfg.vocab_size, model):
            tp[last_dims(2)] = "model"
    elif name == "lm_head":
        if _div(cfg.vocab_size, model):
            tp[last_dims(1)] = "model"
    elif name == "vis_proj":
        if _div(shape[-1], model):
            tp[last_dims(1)] = "model"

    spec = [None] * nd
    for i, ax in tp.items():
        spec[i] = ax
    if fsdp:
        fi = _fsdp_axis(shape, tp, data)
        if fi is not None:
            spec[fi] = "data"
    return P(*spec)


def _path_names(path) -> list:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(f"[{k.idx}]")
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(str(k.name))
    return names


def param_specs(abstract_params, cfg: ModelConfig, minfo: MeshInfo,
                mode: str) -> dict:
    """PartitionSpec pytree for the params.
    mode: 'train' (FSDP+TP) | 'infer' (TP, +FSDP if over HBM budget) |
    'tp' (TP only — no per-layer all-gathers).

    q-TP (shard Q/O projections on the head axis even when KV heads don't
    divide the model axis) measured strictly better on every pair it applies
    to (EXPERIMENTS.md §Perf A1/C2) — default ON; a '_noqtp' suffix
    reproduces the paper-faithful baseline sharding."""
    q_tp = not mode.endswith("_noqtp")
    base = mode.replace("_qtp", "").replace("_noqtp", "")
    fsdp = base == "train"
    if base == "infer":
        tp_bytes = cfg.param_count() * 2 / minfo.model
        fsdp = tp_bytes > HBM_PARAM_BUDGET
    elif base == "tp":
        fsdp = False
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(_path_names(path), leaf.shape, cfg,
                                      minfo, fsdp, q_tp=q_tp),
        abstract_params)


def param_shardings(abstract_params, cfg, minfo: MeshInfo, mode: str):
    specs = param_specs(abstract_params, cfg, minfo, mode)
    return jax.tree.map(lambda s: NamedSharding(minfo.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Input / cache rules
# ---------------------------------------------------------------------------
def batch_input_specs(abstract_batch: dict, minfo: MeshInfo) -> dict:
    out = {}
    for name, leaf in abstract_batch.items():
        b = leaf.shape[0]
        axes = batch_spec_axes(minfo, b)
        spec = [axes] + [None] * (leaf.ndim - 1)
        out[name] = P(*spec)
    return out


def _cache_leaf_spec(path_names: list, shape: tuple, cfg: ModelConfig,
                     minfo: MeshInfo, batch: int, capacity: int) -> P:
    """KV caches: (count, B, S, KV, hd) [+ local/global/cross variants];
    mamba states: ssm (count[, inner], B, H, P, N), conv (…, B, W-1, C)."""
    name = path_names[-1] if path_names else ""
    nd = len(shape)
    b_axes = batch_spec_axes(minfo, batch)
    seq_axes: Optional[tuple]
    if batch == 1:
        # long-context: spend every axis on the sequence
        all_axes = (*minfo.batch_axes, "model")
        total = int(np.prod([minfo.axis_sizes[a] for a in all_axes]))
        if _div(capacity, total):
            seq_axes = all_axes
        else:
            seq_axes = ("model",) if _div(capacity, minfo.model) else None
        b_axes = None
    else:
        seq_axes = ("model",) if _div(capacity, minfo.model) else None

    spec = [None] * nd
    if name in ("k", "v"):
        # (count, B, KV, S, hd)
        spec[nd - 4] = b_axes
        spec[nd - 2] = seq_axes
    elif name in ("ck", "cv"):
        # cross K/V (count, B, S_enc, KV, hd): encoder length small — batch only
        spec[nd - 4] = b_axes
    elif name == "ssm":
        # (count[, inner], B, H, P, N)
        spec[nd - 4] = b_axes
    elif name == "conv":
        spec[nd - 3] = b_axes
    return P(*spec)


def cache_specs_tree(abstract_cache, cfg: ModelConfig, minfo: MeshInfo,
                     batch: int, capacity: int):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(_path_names(path), leaf.shape,
                                            cfg, minfo, batch, capacity),
        abstract_cache)


def to_shardings(spec_tree, minfo: MeshInfo):
    return jax.tree.map(lambda s: NamedSharding(minfo.mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
