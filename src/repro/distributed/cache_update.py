"""Sharded KV-cache append (§Perf, append-outside-scan decode).

A dynamic-update-slice at a traced position into a *model-sharded* sequence
axis makes GSPMD all-gather the whole cache (measured: +790 ms collective on
qwen2 decode_32k).  This helper performs the append under ``shard_map``: each
device checks whether the global slot lands in its local shard and writes the
one-token slice locally — O(token) traffic, zero collectives.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

# jax.shard_map only became a top-level alias in newer releases; fall back
# to the experimental home on the versions that predate it
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                                  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _axes_tuple(ax):
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def _append_local(c_loc, d_loc, pos, *, seq_axes, mesh_axis_sizes, axis=3):
    """Per-device body: write d (…,1,…) into c at global slot pos (mod cap)."""
    s_loc = c_loc.shape[axis]
    shard_idx = jnp.zeros((), jnp.int32)
    total = 1
    for a in seq_axes:
        shard_idx = shard_idx * mesh_axis_sizes[a] + lax.axis_index(a)
        total *= mesh_axis_sizes[a]
    cap = s_loc * total
    slot = pos % cap
    start = shard_idx * s_loc
    local = jnp.clip(slot - start, 0, s_loc - 1)
    in_range = (slot >= start) & (slot < start + s_loc)
    cur = lax.dynamic_slice_in_dim(c_loc, local, 1, axis=axis)
    newv = jnp.where(in_range, d_loc.astype(c_loc.dtype), cur)
    return lax.dynamic_update_slice_in_dim(c_loc, newv, local, axis=axis)


def append_kv(cache_leaf, delta_leaf, pos, spec: P, minfo, axis: int = 3):
    """cache (count,B,KV,S,hd) with PartitionSpec `spec`; delta (…,1,…)."""
    seq_axes = _axes_tuple(spec[axis]) if axis < len(spec) else ()
    if not seq_axes:
        cap = cache_leaf.shape[axis]
        return lax.dynamic_update_slice_in_dim(
            cache_leaf, delta_leaf.astype(cache_leaf.dtype), pos % cap,
            axis=axis)

    delta_spec = list(spec)
    delta_spec[axis] = None
    fn = functools.partial(_append_local, seq_axes=seq_axes,
                           mesh_axis_sizes=minfo.axis_sizes, axis=axis)
    return _shard_map(
        fn, mesh=minfo.mesh,
        in_specs=(spec, P(*delta_spec), P()),
        out_specs=spec,
    )(cache_leaf, delta_leaf, pos)


def apply_cache_deltas(cache, deltas, pos, cache_specs, minfo):
    """Walk the cache pytree: K/V leaves (S axis = -2) get the sharded append;
    state leaves (matching shapes) are replaced wholesale."""
    def go(c, d, spec):
        if c.shape == d.shape:
            return d.astype(c.dtype)
        return append_kv(c, d, pos, spec, minfo, axis=c.ndim - 2)

    return jax.tree.map(go, cache, deltas, cache_specs)
