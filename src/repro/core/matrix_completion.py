"""Matrix completion for MTL->latency estimation (paper §3.3.2).

The paper profiles latency at MTL=1 and MTL=8 only, then recovers the full
latency curve over MTL in [1, N] with SVD-based matrix completion (they use
TFOCS convex optimization; we solve the same nuclear-norm relaxation with
soft-impute — iterative singular-value thresholding, Mazumder et al. 2010).

The matrix M has one row per *job* (a library of previously profiled jobs
plus the current one) and one column per MTL in 1..N.  Rows are normalized by
their MTL=1 latency so the low-rank structure captures scaling-curve shapes.
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def soft_impute(M: np.ndarray, mask: np.ndarray, *, lam: float = 0.05,
                rank: Optional[int] = None, iters: int = 300,
                tol: float = 1e-6) -> np.ndarray:
    """Fill missing entries (mask==False) of M via iterative SVD thresholding.

    lam is the singular-value shrinkage (relative to the largest sv);
    rank optionally hard-truncates.
    """
    M = np.asarray(M, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    X = np.where(mask, M, 0.0)
    col_mean = np.where(mask.any(0), (M * mask).sum(0) / np.maximum(mask.sum(0), 1), 0.0)
    X = np.where(mask, M, np.broadcast_to(col_mean, M.shape))

    prev = X.copy()
    for _ in range(iters):
        U, s, Vt = np.linalg.svd(X, full_matrices=False)
        thr = lam * s[0] if s.size else 0.0
        s_shrunk = np.maximum(s - thr, 0.0)
        if rank is not None:
            s_shrunk[rank:] = 0.0
        Xlr = (U * s_shrunk) @ Vt
        X = np.where(mask, M, Xlr)
        delta = np.linalg.norm(X - prev) / max(np.linalg.norm(prev), 1e-12)
        prev = X.copy()
        if delta < tol:
            break
    return X


class SurfaceLibrary:
    """Cross-job shared (bs, mtl) latency surface (2-D analogue of §3.3.2).

    Every job's probed (bs, mtl) step-latency points land in one jobs x
    knobs matrix (rows = serving tenancies, columns = the flattened
    (bs, mtl) grid).  Rows are normalized by the job's (bs=1, mtl=1)
    latency — the paper's §3.3.2 scheme — so the low-rank structure
    captures scaling-curve *shapes* across architecturally similar jobs
    rather than absolute speeds (which also makes rows comparable across
    device shares).  `soft_impute` completes the matrix; `predict` returns
    a newly admitted job's full de-normalized surface so its HybridScaler
    can seed dominance pins from history instead of the analytic floor,
    and so re-placement can anticipate its hybrid steady state."""

    def __init__(self, bs_values: tuple = (1, 2, 4, 8, 16, 32, 64, 128),
                 max_mtl: int = 10, *, min_rows: int = 1,
                 min_points: int = 2, rank: int = 3, loo_tol: float = 0.3,
                 sim_tol: float = 0.25, max_sim_rows: int = 6,
                 share_values: tuple = (1.0,)):
        self.bs_values = tuple(int(b) for b in bs_values)
        self.mtl_values = tuple(range(1, max_mtl + 1))
        # spatial-partition knob grid (serving/partition.py share ladder),
        # stored DESCENDING so latency is monotone non-decreasing along
        # all three axes (bs up, mtl up, share DOWN) — the monotone prior
        # and the dominance support mask then treat every axis alike.
        # The default single-rung grid keeps the library exactly 2-D:
        # arrays, persistence, and predictions are bit-identical to the
        # pre-partition library.
        self.share_values = tuple(sorted((float(s) for s in share_values),
                                         reverse=True))
        self.min_rows = min_rows          # similar rows needed to predict
        self.min_points = min_points      # observed points the target needs
        self.rank = rank
        self.loo_tol = loo_tol            # leave-one-out relative error gate
        self.sim_tol = sim_tol            # shared-support similarity gate
        self.max_sim_rows = max_sim_rows  # completion uses the k best rows
        self._bs_idx = {b: i for i, b in enumerate(self.bs_values)}
        self._sum: dict = {}              # key -> self.shape latency sums
        self._cnt: dict = {}              # key -> self.shape sample counts
        self._version: dict = {}          # key -> bumped on every change
        self._pred_cache: dict = {}       # key -> (versions-fingerprint, est)
        self.observations = 0             # on-grid points recorded (total)
        self.last_reject = None           # why the library tier said None:
        #                                   "points" | "base" | "rows" |
        #                                   "loo" | "share" (drives load-time
        #                                   eviction in the cross-run store)
        self.last_tier = None             # which tier served the last
        #                                   predict(): "library" | "model"
        self._cost_model = None           # perf.cost_model.CostModel prior
        self._features = {}               # key -> ModelFeatures (or None)

    # -- zero-probe prior (perf/cost_model.py third tier) -------------------
    def set_cost_model(self, model) -> None:
        """Attach the learned HLO cost model; `predict` then falls back to
        its zero-probe surface when similarity refuses."""
        self._cost_model = model

    def register_features(self, key, feat) -> None:
        """Remember a tenancy's architecture features (None is remembered
        too, so a featureless job is not re-derived every predict)."""
        self._features[key] = feat

    def has_features(self, key) -> bool:
        return key in self._features

    @property
    def shape(self) -> tuple:
        if len(self.share_values) == 1:
            return len(self.bs_values), len(self.mtl_values)
        return (len(self.bs_values), len(self.mtl_values),
                len(self.share_values))

    def share_index(self, share) -> Optional[int]:
        """Grid index of a share rung (None = the largest rung / off-grid
        values are rejected, mirroring the bs grid)."""
        if share is None:
            return 0
        for s, v in enumerate(self.share_values):
            if abs(v - float(share)) <= 1e-9:
                return s
        return None

    def observe(self, key, bs: int, mtl: int, latency_s: float,
                share=None) -> None:
        """Record one probed step latency.  Off-grid (bs, mtl, share)
        points are dropped — the scalers' doubling/AIMD/ladder moves keep
        probes on the power-of-two x small-integer x rung grid, so
        coverage stays dense."""
        i = self._bs_idx.get(int(bs))
        j = int(mtl) - 1
        s = self.share_index(share)
        if i is None or s is None or not 0 <= j < len(self.mtl_values):
            return
        if not np.isfinite(latency_s) or latency_s <= 0.0:
            return
        if key not in self._sum:
            self._sum[key] = np.zeros(self.shape)
            self._cnt[key] = np.zeros(self.shape, dtype=np.int64)
        ix = (i, j) if len(self.share_values) == 1 else (i, j, s)
        self._sum[key][ix] += float(latency_s)
        self._cnt[key][ix] += 1
        self._version[key] = self._version.get(key, 0) + 1
        self.observations += 1

    def n_points(self, key) -> int:
        cnt = self._cnt.get(key)
        return int((cnt > 0).sum()) if cnt is not None else 0

    def reset_row(self, key) -> None:
        """Drop a tenancy's accumulated points.  Called when its device
        share changes: latencies probed on the old share would otherwise
        be averaged with the new share's and poison the row."""
        self._sum.pop(key, None)
        self._cnt.pop(key, None)
        self._version[key] = self._version.get(key, 0) + 1

    def row(self, key) -> tuple:
        """(mean-latency grid, observed mask) for one tenancy."""
        cnt = self._cnt[key]
        mask = cnt > 0
        mean = np.where(mask, self._sum[key] / np.maximum(cnt, 1), 0.0)
        return mean, mask

    def export_row(self, key) -> Optional[tuple]:
        """(latency-sum grid, sample-count grid) copies for persistence,
        or None for an unknown key."""
        if key not in self._sum:
            return None
        return self._sum[key].copy(), self._cnt[key].copy()

    def import_row(self, key, sum_, cnt) -> bool:
        """Install a persisted row (e.g. a prior run's tenancy reloaded
        from the profile store).  Grid-shape and sanity checked; merges
        into an existing row of the same key.  Returns False (and imports
        nothing) on malformed input."""
        try:
            sum_ = np.asarray(sum_, np.float64)
            cnt = np.asarray(cnt, np.int64)
        except (TypeError, ValueError):
            return False
        if sum_.shape != self.shape or cnt.shape != self.shape:
            return False
        if (cnt < 0).any() or not np.isfinite(sum_).all():
            return False
        mask = cnt > 0
        if (sum_[mask] <= 0).any():
            return False
        if key not in self._sum:
            self._sum[key] = np.zeros(self.shape)
            self._cnt[key] = np.zeros(self.shape, dtype=np.int64)
        self._sum[key] += np.where(mask, sum_, 0.0)
        self._cnt[key] += cnt
        self._version[key] = self._version.get(key, 0) + 1
        self.observations += int(mask.sum())
        return True

    def _base_flat(self, mask_flat) -> Optional[int]:
        """Flat index of the row's normalizer: the (bs=1, mtl=1) point at
        the LARGEST observed share rung (rung 0 is the largest because the
        share grid is stored descending; with the default single-rung grid
        this is exactly the old (1, 1) requirement)."""
        for s in range(len(self.share_values)):
            if mask_flat[s]:
                return s
        return None

    def predict(self, key, share=None, allow_model=True) -> Optional[tuple]:
        """(mean-latency surface, support mask) for `key`, served by the
        first tier that can answer:

          1. similarity fold-in (`_predict_library`) — completed from
             architecturally similar probed history, support = dominance;
          2. the learned HLO cost model (``set_cost_model``) — a
             ZERO-PROBE prior priced from architecture features alone,
             with an all-False support mask: downstream dominance pins,
             surface jumps, and capacity promises all key on support, so
             the prior can seed but never promise.  ``allow_model=False``
             restricts to tier 1 (the profile store's load-time LOO
             validation must judge the library, not the prior).

        `last_tier` records which tier answered ("library" | "model");
        `last_reject` always reports the LIBRARY tier's refusal reason.
        """
        result = self._predict_library(key)
        if result is not None:
            self.last_tier = "library"
            return self._slice_result(result, share)
        self.last_tier = None
        if not allow_model or self._cost_model is None:
            return None
        feat = self._features.get(key)
        if feat is None:
            return None
        est = np.asarray(self._cost_model.predict_surface(
            feat, self.bs_values, self.mtl_values, self.share_values),
            np.float64).reshape(self.shape)
        if not np.isfinite(est).all() or (est <= 0).any():
            return None
        self.last_tier = "model"
        return self._slice_result(
            (est, np.zeros(self.shape, dtype=bool)), share)

    def _predict_library(self, key) -> Optional[tuple]:
        """The similarity tier: (completed mean-latency surface, support
        mask) for `key`, the surface de-normalized by the job's own
        observed (1, 1) point.
        None until the target has its (1, 1) normalizer plus `min_points`
        observations and the library holds `min_rows` similar tenancies
        (too little history would let one noisy row poison permanent
        dominance pins downstream).  With a multi-rung share grid the
        completed object is the full (bs, mtl, share) tensor; the caller
        (`predict`) slices 2-D (bs, mtl) views per share rung.

        The §3.3.2 premise is SIMILARITY, so the completion does not pool
        every tenancy: library rows are first ranked by agreement with the
        target on the shared support of their observed (normalized) points
        and only rows within `sim_tol` median relative error join the
        matrix — a recurring architecture's earlier tenancy matches almost
        exactly; an unrelated job's row does not.  The result is then
        leave-one-out validated: each of the target's observed off-base
        points is held out in turn and must be recovered within `loo_tol`
        relative error.  A job with no architecturally similar history
        gets None instead of a fabricated surface."""
        self.last_reject = "points"
        if self.n_points(key) < max(self.min_points, 1):
            return None
        mean, mask = self.row(key)
        t_mask = np.ravel(mask)
        base = self._base_flat(t_mask)
        if base is None:
            self.last_reject = "base"
            return None                   # need the normalizer
        t_norm = np.ravel(mean) / np.ravel(mean)[base]
        others = []
        for k in self._sum:
            if k == key or self.n_points(k) < 2:
                continue
            m, obs = self.row(k)
            r_mask = np.ravel(obs)
            rbase = self._base_flat(r_mask)
            if rbase is None:
                continue
            r_norm = np.ravel(m) / np.ravel(m)[rbase]
            shared = np.nonzero(t_mask & r_mask)[0]
            # base points are 1.0 by construction — no information
            shared = shared[(shared != base) & (shared != rbase)]
            if len(shared) < 2:
                continue                  # not enough overlap to judge
            err = float(np.median(np.abs(r_norm[shared] - t_norm[shared])
                                  / np.maximum(np.abs(t_norm[shared]),
                                               1e-12)))
            if err <= self.sim_tol:
                others.append((err, k, r_norm, r_mask))
        if len(others) < self.min_rows:
            self.last_reject = "rows"
            return None
        others.sort(key=lambda e: e[0])
        others = others[:self.max_sim_rows]
        fingerprint = (tuple(k for _, k, _, _ in others),
                       self._version.get(key, 0),
                       sum(self._version.get(k, 0) for _, k, _, _ in others))
        cached = self._pred_cache.get(key)
        if cached is not None and cached[0] == fingerprint:
            self.last_reject = cached[2] if len(cached) > 2 else None
            return cached[1]
        # complete in LOG space: latency surfaces are near-multiplicative
        # families (host x batch x tenancy factors), so their logs are
        # genuinely low-rank — and the 3-orders-of-magnitude dynamic range
        # of the linear surface would otherwise let the singular-value
        # shrinkage crush the few small observed anchors of a sparse row.
        # The LIBRARY matrix (dense-ish rows) is completed by soft_impute;
        # the target row is then FOLDED IN by ridge-regressing its few
        # observed anchors onto the library's principal components —
        # running the sparse target row through the iterative thresholding
        # itself would let the shrinkage compound on its ~95% free entries
        # and collapse them toward zero.
        lib_rows = np.vstack([np.log(np.maximum(r, 1e-12))
                              for _, _, r, _ in others])
        lib_mask = np.vstack([m for _, _, _, m in others])
        if not lib_mask.all():
            lib_rows = soft_impute(lib_rows, lib_mask,
                                   rank=min(self.rank, lib_rows.shape[0]))
        r_basis = min(self.rank, lib_rows.shape[0])
        _, _, Vt = np.linalg.svd(lib_rows, full_matrices=False)
        basis = Vt[:r_basis]                  # (r, knobs), uncentered
        t_log = np.log(np.maximum(t_norm, 1e-12))

        def complete(target_mask) -> np.ndarray:
            obs = np.nonzero(target_mask)[0]
            A = basis[:, obs].T               # (n_obs, r)
            b = t_log[obs]
            ridge = 1e-6 * np.eye(r_basis)
            coef = np.linalg.solve(A.T @ A + ridge, A.T @ b)
            return np.exp(coef @ basis)

        # leave-one-out gate on the target's off-base observations
        holdouts = [ix for ix in np.nonzero(t_mask)[0] if ix != base]
        for ix in holdouts:
            loo = t_mask.copy()
            loo[ix] = False
            pred = complete(loo)[ix]
            actual = t_norm[ix]
            if abs(pred - actual) > self.loo_tol * abs(actual):
                self.last_reject = "loo"
                self._pred_cache[key] = (fingerprint, None, "loo")
                return None

        est = complete(t_mask).reshape(self.shape)
        est = np.maximum(est, 1e-9)
        # physical prior: latency is monotone along every knob axis (the
        # share axis is stored descending, so it points the same way)
        for ax in range(est.ndim):
            est = np.maximum.accumulate(est, axis=ax)
        est = est * np.ravel(mean)[base]
        # support: a grid point is trustworthy only if SOME pooled
        # observation dominates it (component-wise >=) — latency
        # monotonicity then upper-bounds it by a measured value.  Corners
        # beyond every observation are pure extrapolation; callers must
        # not jump to, pin, or promise capacity at unsupported points.
        pooled = t_mask.reshape(self.shape).copy()
        for m in lib_mask:
            pooled |= m.reshape(self.shape)
        support = pooled
        for ax in range(support.ndim):
            support = np.flip(np.maximum.accumulate(
                np.flip(support, ax), axis=ax), ax)
        result = (est, support)
        self.last_reject = None
        self._pred_cache[key] = (fingerprint, result, None)
        return result

    def _slice_result(self, result, share):
        """The (bs, mtl) view of a prediction at one share rung (the full
        object — 2-D, or the whole tensor — when `share` is None).  An
        unknown/off-grid rung returns None with `last_reject = "share"` —
        distinct from the no-history rejections, so callers can tell a
        bad rung apart from a cold library."""
        if result is None or share is None or len(self.share_values) == 1:
            return result
        s = self.share_index(share)
        if s is None:
            self.last_reject = "share"
            self.last_tier = None
            return None
        est, support = result
        return est[:, :, s], support[:, :, s]


class LatencyEstimator:
    """Estimates latency(MTL) for a new job from two profiled points plus a
    library of fully-profiled historical jobs."""

    def __init__(self, max_mtl: int = 10):
        self.max_mtl = max_mtl
        self.library: list[np.ndarray] = []   # normalized rows, len max_mtl

    def add_library_row(self, latencies_by_mtl: dict) -> None:
        row = np.array([latencies_by_mtl[m] for m in range(1, self.max_mtl + 1)],
                       dtype=np.float64)
        self.library.append(row / row[0])

    def estimate(self, observed: dict) -> np.ndarray:
        """observed: {mtl: latency_s} (the paper uses {1: ..., 8: ...}).

        Returns estimated latency for MTL = 1..max_mtl (seconds)."""
        assert 1 in observed, "need the MTL=1 point for normalization"
        base = observed[1]
        row = np.zeros(self.max_mtl)
        mask_row = np.zeros(self.max_mtl, dtype=bool)
        for m, lat in observed.items():
            if 1 <= m <= self.max_mtl:
                row[m - 1] = lat / base
                mask_row[m - 1] = True

        if self.library:
            M = np.vstack(self.library + [row])
            mask = np.vstack([np.ones_like(r, dtype=bool) for r in self.library]
                             + [mask_row])
            filled = soft_impute(M, mask, rank=min(3, M.shape[0]))
            est = filled[-1]
        else:
            # no library: fall back to linear interpolation/extrapolation in MTL
            ms = np.array(sorted(observed))
            vals = np.array([observed[m] / base for m in ms])
            est = np.interp(np.arange(1, self.max_mtl + 1), ms, vals)
            if len(ms) >= 2:  # extrapolate past the last observation
                slope = (vals[-1] - vals[0]) / (ms[-1] - ms[0])
                for i in range(self.max_mtl):
                    m = i + 1
                    if m > ms[-1]:
                        est[i] = vals[-1] + slope * (m - ms[-1])
        est = np.maximum(est, 1e-9)
        # physical prior: co-locating more instances never reduces latency
        est = np.maximum.accumulate(est)
        return est * base

    def pick_mtl(self, observed: dict, slo_s: float) -> tuple[int, np.ndarray]:
        """Largest MTL whose estimated latency is below the SLO (Alg. 1 l.32)."""
        est = self.estimate(observed)
        ok = [m for m in range(1, self.max_mtl + 1) if est[m - 1] < slo_s]
        return (max(ok) if ok else 1), est
