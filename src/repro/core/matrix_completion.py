"""Matrix completion for MTL->latency estimation (paper §3.3.2).

The paper profiles latency at MTL=1 and MTL=8 only, then recovers the full
latency curve over MTL in [1, N] with SVD-based matrix completion (they use
TFOCS convex optimization; we solve the same nuclear-norm relaxation with
soft-impute — iterative singular-value thresholding, Mazumder et al. 2010).

The matrix M has one row per *job* (a library of previously profiled jobs
plus the current one) and one column per MTL in 1..N.  Rows are normalized by
their MTL=1 latency so the low-rank structure captures scaling-curve shapes.
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def soft_impute(M: np.ndarray, mask: np.ndarray, *, lam: float = 0.05,
                rank: Optional[int] = None, iters: int = 300,
                tol: float = 1e-6) -> np.ndarray:
    """Fill missing entries (mask==False) of M via iterative SVD thresholding.

    lam is the singular-value shrinkage (relative to the largest sv);
    rank optionally hard-truncates.
    """
    M = np.asarray(M, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    X = np.where(mask, M, 0.0)
    col_mean = np.where(mask.any(0), (M * mask).sum(0) / np.maximum(mask.sum(0), 1), 0.0)
    X = np.where(mask, M, np.broadcast_to(col_mean, M.shape))

    prev = X.copy()
    for _ in range(iters):
        U, s, Vt = np.linalg.svd(X, full_matrices=False)
        thr = lam * s[0] if s.size else 0.0
        s_shrunk = np.maximum(s - thr, 0.0)
        if rank is not None:
            s_shrunk[rank:] = 0.0
        Xlr = (U * s_shrunk) @ Vt
        X = np.where(mask, M, Xlr)
        delta = np.linalg.norm(X - prev) / max(np.linalg.norm(prev), 1e-12)
        prev = X.copy()
        if delta < tol:
            break
    return X


class LatencyEstimator:
    """Estimates latency(MTL) for a new job from two profiled points plus a
    library of fully-profiled historical jobs."""

    def __init__(self, max_mtl: int = 10):
        self.max_mtl = max_mtl
        self.library: list[np.ndarray] = []   # normalized rows, len max_mtl

    def add_library_row(self, latencies_by_mtl: dict) -> None:
        row = np.array([latencies_by_mtl[m] for m in range(1, self.max_mtl + 1)],
                       dtype=np.float64)
        self.library.append(row / row[0])

    def estimate(self, observed: dict) -> np.ndarray:
        """observed: {mtl: latency_s} (the paper uses {1: ..., 8: ...}).

        Returns estimated latency for MTL = 1..max_mtl (seconds)."""
        assert 1 in observed, "need the MTL=1 point for normalization"
        base = observed[1]
        row = np.zeros(self.max_mtl)
        mask_row = np.zeros(self.max_mtl, dtype=bool)
        for m, lat in observed.items():
            if 1 <= m <= self.max_mtl:
                row[m - 1] = lat / base
                mask_row[m - 1] = True

        if self.library:
            M = np.vstack(self.library + [row])
            mask = np.vstack([np.ones_like(r, dtype=bool) for r in self.library]
                             + [mask_row])
            filled = soft_impute(M, mask, rank=min(3, M.shape[0]))
            est = filled[-1]
        else:
            # no library: fall back to linear interpolation/extrapolation in MTL
            ms = np.array(sorted(observed))
            vals = np.array([observed[m] / base for m in ms])
            est = np.interp(np.arange(1, self.max_mtl + 1), ms, vals)
            if len(ms) >= 2:  # extrapolate past the last observation
                slope = (vals[-1] - vals[0]) / (ms[-1] - ms[0])
                for i in range(self.max_mtl):
                    m = i + 1
                    if m > ms[-1]:
                        est[i] = vals[-1] + slope * (m - ms[-1])
        est = np.maximum(est, 1e-9)
        # physical prior: co-locating more instances never reduces latency
        est = np.maximum.accumulate(est)
        return est * base

    def pick_mtl(self, observed: dict, slo_s: float) -> tuple[int, np.ndarray]:
        """Largest MTL whose estimated latency is below the SLO (Alg. 1 l.32)."""
        est = self.estimate(observed)
        ok = [m for m in range(1, self.max_mtl + 1) if est[m - 1] < slo_s]
        return (max(ok) if ok else 1), est
