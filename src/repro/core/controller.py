"""DNNScaler controller (paper §3.2): Profiler -> Scaler, plus baselines.

DNNScalerController drives the serving engine for one job:
  1. Profiler probes BS in {1,m} / MTL in {1,n}, picks Batching or
     Multi-Tenancy (eq. 3-5).
  2. The matching Scaler maintains p95 <= SLO while maximizing throughput
     (binary search on BS, or matrix-completion + AIMD on MTL).

`mode` selects the approach policy:
  "auto"   — the paper's Algorithm 1: profile, then commit to B or MT;
  "hybrid" — beyond the paper: a HybridScaler jointly tunes (BS, MTL) by
             coordinate descent, seeded by the matrix-completion estimate;
  "B"/"MT" — force one pure strategy (the Fig. 11 sole-knob ablations).

StaticController fixes (bs, mtl) — used for the Fig. 1 sweeps and the
Fig. 11/12 combination studies.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.clipper import ClipperController
from repro.core.matrix_completion import LatencyEstimator
from repro.core.profiler import Profiler, ProfileResult
from repro.core.scaler import ALPHA, BatchScaler, HybridScaler, MTScaler
from repro.serving.engine import Action


class DNNScalerController:
    name = "dnnscaler"

    def __init__(self, executor, slo_s: float, *,
                 estimator: Optional[LatencyEstimator] = None,
                 max_bs: int = 128, max_mtl: int = 10,
                 m: int = 32, n: int = 8, decision_interval: int = 5,
                 mode: str = "auto"):
        if mode not in ("auto", "hybrid", "B", "MT"):
            raise ValueError(f"unknown mode {mode!r}")
        self.slo = slo_s
        self.mode = mode
        self.max_mtl = max_mtl
        self.estimator = estimator or LatencyEstimator(max_mtl=max_mtl)
        self.profiler = Profiler(executor, m=m, n=n)
        self.profile: ProfileResult = self.profiler.probe()

        picked = self.profile.approach if mode == "auto" else mode
        if picked == "hybrid":
            # the profiler's winner is the primary knob; the secondary knob
            # is grown opportunistically once the primary saturates
            observed = self.profiler.mt_observations(self.profile)
            self.scaler = HybridScaler(slo_s, self.estimator, observed,
                                       primary=self.profile.approach,
                                       max_bs=max_bs, max_mtl=max_mtl,
                                       decision_interval=decision_interval)
            self._surface = None
            if hasattr(executor, "price_surface"):
                # 2-D analogue of the matrix-completion seed: price the
                # whole knob grid in ONE vectorized call and pin the
                # model-infeasible frontier before the first probe
                bs_vals = np.arange(1, max_bs + 1)
                mtl_vals = np.arange(1, max_mtl + 1)
                lat = executor.price_surface(bs_vals, mtl_vals)
                self._surface = (bs_vals, mtl_vals, lat)
                self.scaler.seed_surface(bs_vals, mtl_vals, lat)
        elif picked == "B":
            self.scaler = BatchScaler(slo_s, max_bs=max_bs,
                                      decision_interval=decision_interval)
        else:
            observed = self.profiler.mt_observations(self.profile)
            self.scaler = MTScaler(slo_s, self.estimator, observed,
                                   max_mtl=max_mtl,
                                   decision_interval=decision_interval)

    @property
    def approach(self) -> str:
        if self.mode == "auto":
            return self.profile.approach
        return "H" if self.mode == "hybrid" else self.mode

    def set_slo(self, slo_s: float) -> None:
        changed = slo_s != self.slo
        self.slo = slo_s
        self.scaler.set_slo(slo_s)
        if changed and getattr(self, "_surface", None) is not None:
            # set_slo cleared all pins; re-derive the infeasible frontier
            # for the new SLO from the already-priced surface (no re-pricing)
            self.scaler.seed_surface(*self._surface)

    def action(self) -> Action:
        return self.scaler.action()

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        self.scaler.observe(p95, result)


class StaticController:
    name = "static"

    def __init__(self, bs: int = 1, mtl: int = 1):
        self.bs = bs
        self.mtl = mtl

    def set_slo(self, slo_s: float) -> None:
        pass

    def action(self) -> Action:
        return Action(bs=self.bs, mtl=self.mtl)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        pass


__all__ = ["DNNScalerController", "ClipperController", "StaticController",
           "HybridScaler", "ALPHA"]
