"""DNNScaler controller (paper §3.2): Profiler -> Scaler, plus baselines.

DNNScalerController drives the serving engine for one job:
  1. Profiler probes BS in {1,m} / MTL in {1,n}, picks Batching or
     Multi-Tenancy (eq. 3-5).
  2. The matching Scaler maintains p95 <= SLO while maximizing throughput
     (binary search on BS, or matrix-completion + AIMD on MTL).

`mode` selects the approach policy:
  "auto"   — the paper's Algorithm 1: profile, then commit to B or MT;
  "hybrid" — beyond the paper: a HybridScaler jointly tunes (BS, MTL) by
             coordinate descent, seeded by the matrix-completion estimate;
  "B"/"MT" — force one pure strategy (the Fig. 11 sole-knob ablations).

StaticController fixes (bs, mtl) — used for the Fig. 1 sweeps and the
Fig. 11/12 combination studies.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.clipper import ClipperController
from repro.core.matrix_completion import LatencyEstimator
from repro.core.profiler import Profiler, ProfileResult
from repro.core.scaler import ALPHA, BatchScaler, HybridScaler, MTScaler
from repro.serving.engine import Action


class DNNScalerController:
    name = "dnnscaler"

    def __init__(self, executor, slo_s: float, *,
                 estimator: Optional[LatencyEstimator] = None,
                 max_bs: int = 128, max_mtl: int = 10,
                 m: int = 32, n: int = 8, decision_interval: int = 5,
                 mode: str = "auto", surface_library=None,
                 surface_key=None, share_ladder=None):
        if mode not in ("auto", "hybrid", "B", "MT"):
            raise ValueError(f"unknown mode {mode!r}")
        self.slo = slo_s
        self.mode = mode
        # spatial-partition third knob (serving/partition.py): only the
        # HybridScaler searches it; the 1-D paper scalers ignore it
        self.share_ladder = share_ladder
        self.max_bs = max_bs
        self.max_mtl = max_mtl
        self.estimator = estimator or LatencyEstimator(max_mtl=max_mtl)
        # cross-job shared surface (core.matrix_completion.SurfaceLibrary):
        # every probed (bs, mtl) point this controller serves is pooled
        # into the jobs x knobs matrix, and a new job seeds its scaler
        # from the soft-impute completion of similar jobs' rows
        self.surface_library = surface_library
        self.surface_key = surface_key
        self.profiler = Profiler(executor, m=m, n=n)
        self.profile: ProfileResult = self.profiler.probe()
        # distinct (bs, mtl) operating points this controller has tried —
        # the probing cost the cross-run profile store amortizes away; a
        # warm-started controller must reach steady state with fewer
        self.probed_points = {(1, 1), (m, 1), (1, n)}
        if surface_library is not None:
            # the profiler's three points — (1,1), (m,1), (1,n) — are free
            # observations for the shared surface (paper: profiling points
            # come for free for matrix completion)
            p = self.profile
            for (bs, mtl), lat in (((1, 1), p.lat_base), ((m, 1), p.lat_bs_m),
                                   ((1, n), p.lat_mtl_n)):
                surface_library.observe(surface_key, bs, mtl, lat)

        picked = self.profile.approach if mode == "auto" else mode
        if picked == "hybrid":
            # the profiler's winner is the primary knob; the secondary knob
            # is grown opportunistically once the primary saturates
            observed = self.profiler.mt_observations(self.profile)
            self.scaler = HybridScaler(slo_s, self.estimator, observed,
                                       primary=self.profile.approach,
                                       max_bs=max_bs, max_mtl=max_mtl,
                                       decision_interval=decision_interval,
                                       share_ladder=share_ladder)
            self._seed_scaler_surface(executor)
        elif picked == "B":
            self.scaler = BatchScaler(slo_s, max_bs=max_bs,
                                      decision_interval=decision_interval)
        else:
            observed = self.profiler.mt_observations(self.profile)
            self.scaler = MTScaler(slo_s, self.estimator, observed,
                                   max_mtl=max_mtl,
                                   decision_interval=decision_interval)

    def _seed_scaler_surface(self, executor) -> None:
        """Pin the HybridScaler's infeasible frontier before the first
        probe.  Preference order: the cross-job SurfaceLibrary completion
        (history of architecturally similar jobs, de-normalized by this
        job's own base point) when it has enough data; otherwise the
        executor's analytic `price_surface` floor."""
        self._surface = None
        self._surface_margin = 1.0
        model_start = None
        lib = self.surface_library
        if lib is not None:
            # a partitioned scaler seeds from the tensor slice at ITS rung
            share = getattr(self.scaler, "share", None)
            pred = (lib.predict(self.surface_key, share=share)
                    if share is not None else lib.predict(self.surface_key))
            if pred is not None and getattr(lib, "last_tier",
                                            "library") == "model":
                # zero-probe cost-model prior: its support mask is
                # all-False by construction, so it must NEVER pin the
                # frontier or jump like probed history — it only nominates
                # a START point for the climb, at a conservative 0.6*SLO
                # target (prediction error budget on top of the library
                # path's 0.75 mean-to-p95 slack).  Pins still come from
                # the analytic price_surface floor below, exactly as if
                # the library had refused outright.
                from repro.serving.device_model import best_feasible_point
                est = pred[0]
                if est.ndim == 3:
                    est = est[:, :, 0]       # largest rung (full share)
                bs_vals = np.asarray(lib.bs_values)
                mtl_vals = np.asarray(lib.mtl_values)
                keep = bs_vals <= self.max_bs
                mtl_keep = mtl_vals[mtl_vals <= self.max_mtl]
                best = best_feasible_point(est[keep][:, :len(mtl_keep)],
                                           bs_vals[keep], mtl_keep,
                                           0.6 * self.slo)
                if best is not None:
                    model_start = (best[1], best[2])
                pred = None
            if pred is not None:
                est, support = pred
                bs_vals = np.asarray(lib.bs_values)
                mtl_vals = np.asarray(lib.mtl_values)
                keep = bs_vals <= self.max_bs
                mtl_keep = mtl_vals[mtl_vals <= self.max_mtl]
                sub = est[keep][:, :len(mtl_keep)]
                sup = support[keep][:, :len(mtl_keep)]
                # a completed row is an ESTIMATE: pin only SUPPORTED points
                # (some pooled observation dominates them) predicted well
                # over the SLO, so estimation error cannot wall off a
                # feasible region permanently
                self._surface = (bs_vals[keep], mtl_keep,
                                 np.where(sup, sub, 0.0))
                self._surface_margin = 1.3
                self.scaler.seed_surface(*self._surface,
                                         margin=self._surface_margin)
                # the 2-D analogue of MTScaler's matrix-completion jump:
                # START at the predicted steady point instead of climbing
                # from (1, 1) — a freshly admitted job otherwise serves a
                # fraction of its demand for the whole climb while its
                # queue (and every queued request's latency) explodes.
                # The jump targets a conservative 0.75*SLO (mean-to-p95
                # slack plus estimation error) and only SUPPORTED points —
                # an unsupported corner is extrapolation, not history.
                # The MTL jump's launch stall is charged by the engine
                # like any other reconfiguration, and a wrong jump is
                # undone by the scaler's gross-violation shrink within a
                # few decisions.
                from repro.serving.device_model import best_feasible_point
                sc = self.scaler
                best = best_feasible_point(
                    np.where(sup, sub, np.inf), bs_vals[keep], mtl_keep,
                    min(sc.alpha, 0.75) * self.slo)
                if best is not None:
                    _, sc.bs, sc.mtl = best
                return
        if hasattr(executor, "price_surface"):
            # 2-D analogue of the matrix-completion seed: price the
            # whole knob grid in ONE vectorized call and pin the
            # model-infeasible frontier before the first probe
            bs_vals = np.arange(1, self.max_bs + 1)
            mtl_vals = np.arange(1, self.max_mtl + 1)
            lat = executor.price_surface(bs_vals, mtl_vals)
            self._surface = (bs_vals, mtl_vals, lat)
            self.scaler.seed_surface(bs_vals, mtl_vals, lat)
        if model_start is not None:
            self.scaler.bs, self.scaler.mtl = model_start

    @property
    def approach(self) -> str:
        if self.mode == "auto":
            return self.profile.approach
        return "H" if self.mode == "hybrid" else self.mode

    def set_slo(self, slo_s: float) -> None:
        changed = slo_s != self.slo
        self.slo = slo_s
        self.scaler.set_slo(slo_s)
        if changed and getattr(self, "_surface", None) is not None:
            # set_slo cleared all pins; re-derive the infeasible frontier
            # for the new SLO from the already-priced surface (no re-pricing)
            self.scaler.seed_surface(*self._surface,
                                     margin=getattr(self, "_surface_margin",
                                                    1.0))

    def note_capacity_change(self, executor=None) -> None:
        """The job's device share changed (cluster migration): every pin
        and search bound was learned on a surface that no longer exists.
        Reset the scaler's search state — and this job's shared-surface
        row, whose old-share points would poison the completion — then
        re-seed the frontier from the new executor's pricing (or the
        shared surface library)."""
        sc = self.scaler
        if hasattr(sc, "reset_search"):
            sc.reset_search()
        if executor is not None:
            self.profiler.executor = executor
        if self.surface_library is not None:
            self.surface_library.reset_row(self.surface_key)
        if isinstance(sc, HybridScaler):
            self._seed_scaler_surface(executor if executor is not None
                                      else self.profiler.executor)

    @property
    def probe_count(self) -> int:
        return len(self.probed_points)

    def action(self) -> Action:
        act = self.scaler.action()
        self.probed_points.add((act.bs, act.mtl))
        return act

    def note_share_grant(self, share: float) -> None:
        """The cluster granted (possibly clipped) this job's partition
        share — align the scaler's ladder position with reality."""
        if hasattr(self.scaler, "set_granted_share"):
            self.scaler.set_granted_share(share)

    def note_share_cap(self, share: float) -> None:
        """Device headroom bound for future share requests."""
        if hasattr(self.scaler, "set_share_cap"):
            self.scaler.set_share_cap(share)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        if self.surface_library is not None and result is not None:
            st = result.get("step_time")
            if st:
                act = self.scaler.action()   # the point this step served
                self.surface_library.observe(self.surface_key,
                                             act.bs, act.mtl, st,
                                             share=act.share)
        self.scaler.observe(p95, result)


class StaticController:
    name = "static"

    def __init__(self, bs: int = 1, mtl: int = 1):
        self.bs = bs
        self.mtl = mtl

    def set_slo(self, slo_s: float) -> None:
        pass

    def action(self) -> Action:
        return Action(bs=self.bs, mtl=self.mtl)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        pass


__all__ = ["DNNScalerController", "ClipperController", "StaticController",
           "HybridScaler", "ALPHA"]
