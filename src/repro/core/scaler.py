"""Scaler module (paper §3.2.2, Algorithm 1 lines 10-41) plus a joint knob.

BatchScaler — pseudo binary search over batch size in [1, maxBS] with the
hysteresis band [alpha*SLO, SLO] (alpha = 0.85); dynamic batch sizing means
changes are free.  MTScaler — jump to the matrix-completion-estimated MTL,
then AIMD (+1 under alpha*SLO, -1 over SLO).  HybridScaler — beyond the
paper: coordinate descent over the joint (BS, MTL) grid (see its docstring).
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.serving.engine import Action

ALPHA = 0.85


class BatchScaler:
    """Algorithm 1, lines 10-29."""

    def __init__(self, slo_s: float, *, max_bs: int = 128, alpha: float = ALPHA,
                 decision_interval: int = 5):
        self.slo = slo_s
        self.alpha = alpha
        self.min_bs = 1
        self.max_bs = max_bs
        self.bs = 1
        self.hard_max = max_bs
        self.decision_interval = decision_interval
        self._steps = 0
        self.infeasible = False
        self.converged_steps = 0
        self._viol_streak = 0   # paper §4.4: short-lived spikes are skipped;
                                # only persistent violations trigger descent
        # Damping beyond the paper: when no batch size lands inside the
        # [alpha*SLO, SLO] band, Algorithm 1 as written oscillates between the
        # last feasible BS and the smallest infeasible one; remembering the
        # infeasible point pins the search at the feasible neighbour.
        self._known_bad: Optional[int] = None

    def set_slo(self, slo_s: float) -> None:
        if slo_s != self.slo:
            self.slo = slo_s
            self.reset_search()

    def reset_search(self) -> None:
        """Re-open the search bounds (SLO change — paper §4.5 — or a
        device-share change under cluster migration)."""
        self.min_bs, self.max_bs = 1, self.hard_max
        self._known_bad = None
        self.infeasible = False

    def action(self) -> Action:
        return Action(bs=self.bs, mtl=1)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        self._steps += 1
        if self._steps % self.decision_interval:
            return
        if self.converged_steps >= 12:
            # a known-bad point may have been a transient spike — allow the
            # search to re-probe upward after a long stable stretch
            self._known_bad = None
            self.converged_steps = 0
        if self.alpha * self.slo <= p95 <= self.slo:
            self.converged_steps += 1
            self._viol_streak = 0
            return                                        # line 13-14
        if p95 < self.alpha * self.slo:                   # line 15-18
            self._viol_streak = 0
            if self.bs == self.hard_max:
                return                # largest possible: no further gain
            self.min_bs = self.bs
            cand = min(math.ceil((self.min_bs + self.max_bs) / 2),
                       self.hard_max)
            if self._known_bad is not None and cand >= self._known_bad:
                cand = self._known_bad - 1
            if cand <= self.bs:
                self.converged_steps += 1
                return
            self.bs = cand
        else:                                             # line 19-29
            self._viol_streak += 1
            if self._viol_streak < 2:
                return                # skip short-lived spikes (paper §4.4)
            self._known_bad = self.bs if self._known_bad is None else \
                min(self._known_bad, self.bs)
            if self.bs == 1:
                self.infeasible = True                    # line 20-21
                return
            if self.bs == self.min_bs:                    # line 22-25
                self.max_bs = self.bs
                self.min_bs = 1
                self.bs = max(math.floor((self.min_bs + self.max_bs) / 2), 1)
            else:                                         # line 26-29
                self.max_bs = self.bs
                self.bs = max(math.floor((self.min_bs + self.max_bs) / 2), 1)
        self.converged_steps = 0


class MTScaler:
    """Algorithm 1, lines 30-41: matrix-completion jump + AIMD refinement."""

    def __init__(self, slo_s: float, estimator, observed: dict, *,
                 max_mtl: int = 10, alpha: float = ALPHA,
                 decision_interval: int = 5):
        self.slo = slo_s
        self.alpha = alpha
        self.max_mtl = max_mtl
        self.estimator = estimator
        self.observed = dict(observed)
        self.mtl, self.estimate = estimator.pick_mtl(observed, slo_s)  # line 31-32
        self.mtl = max(1, min(int(self.mtl), max_mtl))
        self.decision_interval = decision_interval
        self._steps = 0
        self.converged_steps = 0
        self._viol_streak = 0
        self._known_bad: Optional[int] = None   # oscillation damping (see
                                                # BatchScaler)

    def set_slo(self, slo_s: float) -> None:
        if slo_s != self.slo:
            self.reset_search()
        self.slo = slo_s

    def reset_search(self) -> None:
        self._known_bad = None

    def action(self) -> Action:
        return Action(bs=1, mtl=self.mtl)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        self._steps += 1
        if self._steps % self.decision_interval:
            return
        if self.converged_steps >= 12:
            self._known_bad = None    # transient-spike amnesty (see above)
            self.converged_steps = 0
        if self.alpha * self.slo <= p95 <= self.slo:      # line 34-35
            self.converged_steps += 1
            self._viol_streak = 0
            return
        if p95 < self.alpha * self.slo:                   # line 36-38
            self._viol_streak = 0
            nxt = self.mtl + 1
            if nxt <= self.max_mtl and nxt != self._known_bad:
                self.mtl = nxt
                self.converged_steps = 0
            else:
                self.converged_steps += 1
        elif p95 > self.slo:                              # line 39-41
            self._viol_streak += 1
            if self._viol_streak < 2:
                return                # skip short-lived spikes (paper §4.4)
            self._known_bad = self.mtl
            if self.mtl > 1:
                self.mtl -= 1
                self.converged_steps = 0


class HybridScaler:
    """Joint (BS, MTL) scaler — 2-D coordinate descent (beyond the paper).

    The paper's Algorithm 1 commits to ONE knob after profiling, but related
    work (D-STACK's spatio-temporal multiplexing; the multi-tenant inference
    survey's hybrid-knob taxonomy) shows the knobs compose: co-located
    instances each running batched inference can dominate either pure
    strategy.  HybridScaler searches the joint grid:

      * seed: the profiler's winning axis is the `primary` knob.  "MT"
        jumps straight to the matrix-completion MTL estimate at BS=1 (like
        MTScaler, so the expensive instance launches happen once); "B"
        starts at (1, 1) like BatchScaler;
      * coordinate descent under the same [alpha*SLO, SLO] hysteresis band.
        Inside the band nothing moves.  With slack, the primary knob grows
        first — BS doubles geometrically (free under dynamic batch sizing;
        doubling, not a midpoint jump, bounds the overshoot of a probe to
        2x the last feasible point, which matters when the other knob is
        already high and each step is expensive), MTL climbs +1 (AIMD,
        costs a launch stall).  Once the primary is saturated, the
        secondary knob grows the same way;
      * persistent violations first UNDO a freshly made move exactly, then
        shrink BS (one notch when the point was long-held — that's noise
        or a load shift — halving during active search), then shed
        instances; a gross violation (p95 > spike_guard * SLO) is acted on
        immediately — at cluster scale a mis-probe can cost whole seconds
        per step, so waiting out the paper's two-decision spike filter is
        itself expensive.  `infeasible` is only reachable at (BS=1, MTL=1);
      * the 1-D known-bad damping generalizes to a dict of pinned (BS, MTL)
        points with a decision-count amnesty window — a pinned point is
        never re-probed before the window expires.  Unlike 1-D (where the
        hysteresis band leaves a converged scaler with nowhere to probe),
        a 2-D search converged BELOW the band always has an orthogonal
        direction left, so amnesty alone would re-probe the same bad
        neighbours forever.  A *probe-target* pin (a deliberate move that
        failed) struck `persist_pins` times becomes permanent and prunes
        its whole upper-right quadrant (latency is monotone in both
        knobs); occupancy pins — the point we were sitting on when noise
        or load shifted — never persist, or noise alone would eventually
        ratchet every good point out of the search space;
      * measurements are judged carefully: after any move the tail window
        is reset, so p95 readings cool down until the window refills
        (`min_eval_samples`), and growth in refine mode (once a BS ceiling
        is known) waits for two consecutive slack readings — near the band
        edge a single below-band wobble is usually noise, and the probe it
        would trigger is served at over-SLO latency;
      * with a `share_ladder` (spatial partitioning — serving/partition.py)
        the search gains a THIRD coordinate-descent axis over discrete
        device-share rungs: share-up is the tertiary growth move (and the
        violation escape at the (1, 1) floor, before `infeasible`),
        share-down is probed under deep slack to hand capacity back to the
        cluster.  Share moves ride the same pending/revert machinery as
        the knob moves (throughput-guarded, so a share-up that demand
        cannot use is reverted), pins become (bs, mtl, rung) triples, and
        dominance extends along the new axis: latency is monotone
        DECREASING in share, so a persistent failure at (b0, m0, s0)
        prunes bs >= b0, mtl >= m0 at every share <= s0.  The cluster
        mediates actual grants (`set_granted_share` / `set_share_cap`);
      * latency slack alone is NOT a go signal in 2-D: host-bound jobs lose
        throughput as BS grows even while p95 stays under the SLO (the
        rho(BS) copy-pressure term).  Every growth move is therefore
        validated against the interval throughput it actually delivered;
        a move that reduced throughput by more than `revert_tol` is
        reverted and its target pinned.  MTL probes on the secondary axis
        must also pass an amortization gate: a launch stall of
        `mtl_move_cost_s` can never pay off for a job whose whole decision
        interval serves less than a tenth of that.
    """

    def __init__(self, slo_s: float, estimator=None, observed: dict = None,
                 *, primary: str = "B", max_bs: int = 128, max_mtl: int = 10,
                 alpha: float = ALPHA, decision_interval: int = 5,
                 amnesty: int = 20, revert_tol: float = 0.05,
                 spike_guard: float = 1.5, persist_pins: int = 2,
                 mtl_move_cost_s: float = 2.0, min_eval_samples: int = 60,
                 safety: float = 0.0, share_ladder=None, pool_ladder=None):
        self.slo = slo_s
        self.alpha = alpha
        self.primary = primary
        self.hard_max_bs = max_bs
        self.max_mtl = max_mtl
        self.decision_interval = decision_interval
        self.amnesty = amnesty
        self.revert_tol = revert_tol
        self.spike_guard = spike_guard
        self.persist_pins = persist_pins
        self.mtl_move_cost_s = mtl_move_cost_s
        self.min_eval_samples = min_eval_samples
        # optional margin on the internal latency target ((1-safety)*SLO)
        # for deployments that want headroom below the hard SLO; off by
        # default — on the Table-4 trace it shifted search trajectories
        # more than it bought compliance (measured in the cluster bench)
        self.safety = safety
        self.refine_gate = True   # require 2 slack readings in refine mode
        # third coordinate-descent axis (spatial partitioning): a discrete
        # ladder of device shares the scaler may request.  The CLUSTER
        # grants shares (legality: co-resident shares sum <= 1) — the
        # scaler requests; `set_granted_share` aligns it with the grant and
        # `set_share_cap` bounds requests by the device's headroom.  None
        # keeps the scaler exactly 2-D (every pin key carries a constant
        # share index, so behavior is bit-identical to the 2-D search).
        self.share_ladder = (tuple(sorted(float(s) for s in share_ladder))
                             if share_ladder else None)
        self._share_idx = (len(self.share_ladder) - 1
                           if self.share_ladder else 0)
        self._share_value = None       # off-ladder grant currently held
        self._share_cap_idx = self._share_idx
        # fourth axis (disaggregated serving): a ladder of prefill-pool
        # ratios — prefill devices per decode device.  Demand-capped like
        # the share axis: `note_pool_demand` bounds requests by the
        # measured prefill load, `observe_pool` grows under queue pressure
        # and releases rungs the demand no longer covers.  None keeps the
        # scaler exactly as before (no pool state is ever consulted).
        self.pool_ladder = (tuple(sorted(float(r) for r in pool_ladder))
                            if pool_ladder else None)
        self._pool_idx = (len(self.pool_ladder) - 1
                          if self.pool_ladder else 0)
        self._pool_cap_idx = self._pool_idx
        self.bs = 1
        self.estimate = None
        if primary == "MT" and estimator is not None and observed:
            mtl, self.estimate = estimator.pick_mtl(observed, slo_s)
            self.mtl = max(1, min(int(mtl), max_mtl))
        else:
            self.mtl = 1
        self.infeasible = False
        self.converged_steps = 0
        self._steps = 0
        self._decisions = 0
        self._viol_streak = 0
        self._slack_streak = 0
        self._known_bad: dict = {}     # (bs, mtl) -> decision index pinned
        self._dom_counts: dict = {}    # probe-target pins (dominance-safe)
        self._hi = max_bs              # BS ceiling (violation-tightened)
        self._pending = None           # ((bs, mtl), thr) state before move
        self._int_items = 0
        self._int_time = 0.0
        self._last_int_time = 0.0      # seconds of serving per decision
        self._move_decision = -10      # decision index of the last move
        self._samples_since_move = 10**9

    def set_slo(self, slo_s: float) -> None:
        if slo_s != self.slo:
            # re-open the whole 2-D search on SLO change (paper §4.5)
            self.reset_search()
        self.slo = slo_s

    def reset_search(self) -> None:
        """Forget every learned feasibility boundary: pins, the BS ceiling,
        and any pending probe.  Called on SLO change and when the job's
        device share changes (cluster migration) — the surface the pins
        were learned on no longer exists."""
        self._known_bad.clear()
        self._dom_counts.clear()
        self._hi = self.hard_max_bs
        self._pending = None
        self.infeasible = False
        self._viol_streak = 0
        self._slack_streak = 0
        self.converged_steps = 0

    def action(self) -> Action:
        return Action(bs=self.bs, mtl=self.mtl, share=self.share)

    # -- third axis: partition share ----------------------------------------
    @property
    def share(self):
        if self.share_ladder is None:
            return None
        if self._share_value is not None:
            return self._share_value    # holding an off-ladder grant
        return self.share_ladder[self._share_idx]

    def _rung_at_most(self, share: float) -> int:
        idx = 0
        for i, r in enumerate(self.share_ladder):
            if r <= share + 1e-9:
                idx = i
        return idx

    def set_granted_share(self, share: float) -> None:
        """Align with the cluster's actual grant (it may clip a request to
        the device's headroom, shrink the slice at an admission, or grant
        an off-ladder value like 1/3).  The scaler KEEPS reporting the
        granted value until it deliberately moves — snapping the report
        down to a rung would make the engine read the difference as a
        shrink request and charge a spurious resize one step later."""
        if self.share_ladder is None:
            return
        self._share_idx = self._rung_at_most(share)
        self._share_value = (None if abs(
            share - self.share_ladder[self._share_idx]) <= 1e-9 else share)

    def set_share_cap(self, share: float) -> None:
        """Bound future share requests by the device's current headroom."""
        if self.share_ladder is None:
            return
        self._share_cap_idx = self._rung_at_most(share)

    # -- fourth axis: prefill-pool ratio ------------------------------------
    @property
    def pool_ratio(self):
        if self.pool_ladder is None:
            return None
        return self.pool_ladder[self._pool_idx]

    def note_pool_demand(self, demand_ratio: float) -> None:
        """Demand-cap the pool axis: `demand_ratio` is the measured
        prefill load in device-seconds per second per decode device, so
        the smallest rung COVERING it is the largest pool worth holding —
        rungs above it would only idle prefill silicon.  Mirrors
        `set_share_cap` on the share axis."""
        if self.pool_ladder is None:
            return
        cap = len(self.pool_ladder) - 1
        for i, r in enumerate(self.pool_ladder):
            if r >= demand_ratio - 1e-9:   # first rung that covers demand
                cap = i
                break
        self._pool_cap_idx = cap

    def observe_pool(self, prefill_wait_s: float, ttft_slo_s: float) -> bool:
        """One pool-axis decision.  Releases a rung when the ratio sits
        above the demand cap (prefill silicon the load cannot keep busy),
        grows one when p95 prefill+transfer wait eats more than half the
        TTFT budget and the cap allows it.  Returns True when the ratio
        changed (the engine then resizes the pool's active membership)."""
        if self.pool_ladder is None:
            return False
        if self._pool_idx > self._pool_cap_idx:
            self._pool_idx -= 1
            return True
        if (prefill_wait_s > 0.5 * ttft_slo_s
                and self._pool_idx < min(self._pool_cap_idx,
                                         len(self.pool_ladder) - 1)):
            self._pool_idx += 1
            return True
        return False

    # -- surface seeding ----------------------------------------------------
    def seed_surface(self, bs_values, mtl_values, latency_s,
                     margin: float = 1.0) -> int:
        """Seed the dominance pins from a priced (bs, mtl) latency surface.

        `latency_s[i, j]` is the estimated MEAN latency at
        (bs_values[i], mtl_values[j]) — e.g. `SimExecutor.price_surface`,
        the 2-D analogue of the matrix-completion MTL curve.  Points whose
        mean already exceeds the SLO can never satisfy p95 <= SLO, so their
        minimal (lower-left) frontier is pinned permanently; dominance
        pruning in `is_pinned` rules out each frontier point's whole
        upper-right quadrant without a single wasted probe.  Also tightens
        the BS ceiling `_hi` at the current MTL.  Returns the number of
        frontier pins installed.

        `margin > 1` is for UNCERTAIN surfaces (a cross-job matrix
        completion rather than the exact analytic price): only points whose
        estimate exceeds margin*SLO are pinned, so a modest estimation
        error cannot permanently wall off a genuinely feasible point."""
        lat = np.asarray(latency_s, np.float64)
        bs_values = [int(b) for b in bs_values]
        mtl_values = [int(m) for m in mtl_values]
        bad = lat > self.slo * margin
        pins = 0
        prev_first = len(bs_values)      # first-bad row of the previous MTL
        for j, m in enumerate(mtl_values):
            rows = np.nonzero(bad[:, j])[0]
            if rows.size == 0:
                continue
            i = int(rows[0])             # latency is monotone in bs: the
            if i < prev_first:           # first bad bs rules the column out
                self._dom_counts[(bs_values[i], m, self._share_idx)] = \
                    self.persist_pins
                pins += 1
                prev_first = i
        # BS ceiling at the MTL we are sitting on (conservative for lower
        # MTLs by monotonicity, exactly like the ceiling kept by _grow_mtl)
        if self.mtl in mtl_values:
            rows = np.nonzero(bad[:, mtl_values.index(self.mtl)])[0]
            if rows.size:
                self._hi = min(self._hi, max(bs_values[int(rows[0])] - 1, 1))
        return pins

    # -- known-bad (3-D, amnesty-windowed) ----------------------------------
    def is_pinned(self, bs: int, mtl: int, si: int = None) -> bool:
        # probe-target pins prune by dominance: latency is monotone
        # increasing in bs and mtl and DECREASING in share, so a probe that
        # persistently failed at (b0, m0, s0) rules out every point with
        # bs >= b0, mtl >= m0 at the same or any SMALLER share.  Occupancy
        # pins (the point we were sitting on when load or noise shifted)
        # and fresh pins block the exact point only — a transient at the
        # steady point must not condemn the whole search space above it.
        # With no share ladder every key carries index 0 and this reduces
        # to the original 2-D dominance exactly.
        if si is None:
            si = self._share_idx
        for (b0, m0, s0), c in self._dom_counts.items():
            if c >= self.persist_pins and b0 <= bs and m0 <= mtl \
                    and si <= s0:
                return True
        # occupancy pins (generic shrinks at a held point) deliberately
        # never become permanent: over a long run, noise alone would strike
        # every good point twice eventually and ratchet the search into a
        # corner — only deliberate, post-cooldown probe verdicts persist
        t = self._known_bad.get((bs, mtl, si))
        return t is not None and self._decisions - t < self.amnesty

    def _pin(self, bs: int, mtl: int, dominant: bool = False,
             si: int = None) -> None:
        if si is None:
            si = self._share_idx
        self._known_bad[(bs, mtl, si)] = self._decisions
        if dominant:
            self._dom_counts[(bs, mtl, si)] = \
                self._dom_counts.get((bs, mtl, si), 0) + 1

    def _mark_move(self) -> None:
        """A knob just changed: the tail window was reset, so its p95 is
        max-dominated (one 2x OS-jitter spike IS the p95 of a near-empty
        window) until enough fresh samples land.  Judgments wait."""
        self._move_decision = self._decisions
        self._samples_since_move = 0

    # -- growth moves -------------------------------------------------------
    def _grow_bs(self) -> bool:
        hi = min(self._hi, self.hard_max_bs)
        if hi >= self.hard_max_bs:
            cand = min(self.bs * 2, hi)     # no ceiling known yet: double
        else:
            # ceiling known: refine by midpoint (like BatchScaler) so that
            # re-probes near the band edge overshoot by a notch, not by 2x
            cand = min(math.ceil((self.bs + hi) / 2), hi)
        while cand > self.bs and self.is_pinned(cand, self.mtl):
            cand = self.bs + (cand - self.bs) // 2   # halve the gap, not -1:
            # a -1 walk would mint a long chain of distinct candidates, each
            # needing its own pins before the search quiets down
        if cand <= self.bs:
            return False
        self.bs = cand
        self._mark_move()
        return True

    def _grow_mtl(self, secondary: bool = False) -> bool:
        nxt = self.mtl + 1
        if nxt > self.max_mtl or self.is_pinned(self.bs, nxt):
            return False
        if secondary and 0 < self._last_int_time < 0.1 * self.mtl_move_cost_s:
            # amortization gate: a speculative instance launch stalls the
            # job for mtl_move_cost_s; for a job whose whole decision
            # interval serves far less than that, the probe can never pay
            # for itself (a 2 s stall is ~600 SLOs for the 3.5 ms jobs)
            return False
        self.mtl = nxt
        # `_hi` is kept: latency is monotone in MTL, so a BS ceiling
        # learned at a lower MTL still bounds the feasible BS here —
        # resetting it would trigger a full doubling re-climb (and its
        # chain of gross overshoots) after every failed MTL probe
        self._mark_move()
        return True

    def _grow_share(self) -> bool:
        """Request the next share rung up (more spatial capacity).  Tried
        when both knob axes are saturated, and as the violation escape at
        the (1, 1) floor — a bigger slice is the only remaining move."""
        if self.share_ladder is None:
            return False
        nxt = self._share_idx + 1
        if nxt > min(self._share_cap_idx, len(self.share_ladder) - 1):
            return False
        if self.is_pinned(self.bs, self.mtl, nxt):
            return False
        if (self._share_value is not None
                and self.share_ladder[nxt] <= self._share_value + 1e-9):
            return False                 # the rung up is not actually more
        self._share_idx = nxt
        self._share_value = None
        self._mark_move()
        return True

    def _shrink_share(self) -> bool:
        """Probe one share rung down: frees cluster capacity.  Only worth
        trying under deep slack; the throughput guard reverts it when the
        smaller slice actually cost served items (closed loop), and keeps
        it when demand was the binding constraint anyway (open loop)."""
        if self.share_ladder is None or self._share_idx == 0:
            return False
        if self.is_pinned(self.bs, self.mtl, self._share_idx - 1):
            return False
        self._share_idx -= 1
        self._share_value = None
        self._mark_move()
        return True

    def _grow(self, allow_secondary: bool) -> bool:
        if self.primary == "MT":
            return (self._grow_mtl()
                    or (allow_secondary and self._grow_bs())
                    or (allow_secondary and self._grow_share()))
        return (self._grow_bs()
                or (allow_secondary and self._grow_mtl(secondary=True))
                or (allow_secondary and self._grow_share()))

    def _shrink(self) -> None:
        """Back off after a persistent/gross violation."""
        self.converged_steps = 0
        if self._pending is not None:
            # the violation is the direct result of the last move: undo it.
            # Dominance applies to bs/mtl/share-down probes (monotone
            # directions); a share-UP probe that 'violated' can only be
            # noise — latency shrinks with share — so pin the exact point
            (pbs, pmtl, psi, pval), _ = self._pending
            self._pin(self.bs, self.mtl,
                      dominant=self._share_idx <= psi)
            self._pending = None
            if self.mtl == pmtl and self.bs > pbs:
                self._hi = self.bs
            self.bs, self.mtl = pbs, pmtl
            self._share_idx, self._share_value = psi, pval
            self._mark_move()
            return
        self._pin(self.bs, self.mtl)
        # a point held for a while that suddenly violates is usually noise
        # or a load shift grazing the band top — step down one notch; only
        # a violation during active search warrants the halving descent
        stable = self._decisions - self._move_decision >= 6
        if self.bs > 1:
            self._hi = self.bs
            cand = self.bs - 1 if stable else max(self.bs // 2, 1)
            while cand > 1 and self.is_pinned(cand, self.mtl):
                cand //= 2
            self.bs = max(cand, 1)
            self._mark_move()
        elif self.mtl > 1:
            self.mtl -= 1
            # keep `_hi`: it is conservative at the lower MTL (the true
            # ceiling there is >= the one learned here); the amnesty
            # relaxation re-opens it gradually if there is room
            self._mark_move()
        elif self._grow_share():
            # (1, 1) still violates: a bigger spatial slice is the one
            # remaining escape before declaring the job infeasible
            return
        else:
            self.infeasible = True

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        self._steps += 1
        if result is not None:
            self._int_items += result.get("items", 0)
            self._int_time += result.get("step_time", 0.0)
            # the tail window receives at most 64 request samples per step
            self._samples_since_move += min(result.get("items", 64), 64)
        else:
            self._samples_since_move += 64   # no telemetry: assume refilled
        if self._steps % self.decision_interval:
            return
        self._decisions += 1
        thr = self._int_items / self._int_time if self._int_time else None
        self._last_int_time = self._int_time
        self._int_items, self._int_time = 0, 0.0

        # post-move cooldown: the window was reset by the move, so p95 is
        # max-dominated until it refills — freeze judgments (capped at 3
        # decisions so slow big-batch jobs are not stalled forever)
        cooling = (self._samples_since_move < self.min_eval_samples
                   and self._decisions - self._move_decision < 3)
        slo_t = self.slo * (1.0 - self.safety)   # internal target

        guard = max(2.5, self.spike_guard) if cooling else self.spike_guard
        if p95 > slo_t * guard:
            # gross violation: act now, the two-decision spike filter is too
            # slow when a mis-probe costs seconds of serving per step.
            # During cooldown the bar is one spiked sample ABOVE what a
            # healthy point could ever show (spike_mult * band top = 2x).
            self._viol_streak = 0
            self._slack_streak = 0
            self._shrink()
            return
        if cooling:
            return

        if self._pending is not None and p95 <= slo_t:
            (pbs, pmtl, psi, pval), pthr = self._pending
            self._pending = None
            revert = False
            if thr is not None and pthr is not None:
                revert = thr < pthr * (1.0 - self.revert_tol)
                if self._share_idx > psi and not revert:
                    # a share-UP consumes a cluster-wide resource: it must
                    # STRICTLY pay for itself.  A demand-capped job whose
                    # throughput stayed flat hands the slice back.
                    revert = thr <= pthr * (1.0 + self.revert_tol)
            if revert:
                # latency-feasible but throughput-negative: revert + pin.
                # A share-UP probe that bought nothing (demand was the
                # binding constraint) gets an exact-point pin only —
                # dominance along the share axis points the other way
                self._pin(self.bs, self.mtl,
                          dominant=self._share_idx <= psi)
                if self.mtl == pmtl and self.bs > pbs:
                    self._hi = self.bs    # larger BS is worse here: cap it
                self.bs, self.mtl = pbs, pmtl
                self._share_idx, self._share_value = psi, pval
                self._mark_move()
                self.converged_steps = 0
                return

        if self.converged_steps >= self.amnesty:
            # long-stable stretch: pins may have been transient spikes —
            # amnesty re-opens the search (mirrors the 1-D scalers).  The
            # BS ceiling `_hi` relaxes by roughly one notch (~12%), not to
            # the hard max: a steady point at the band edge must re-probe
            # its immediate neighbour, not leap halfway to 2x.
            self._known_bad.clear()
            self._hi = min(self.hard_max_bs,
                           max(self._hi, self.bs + max(1, self.bs // 8)))
            self.converged_steps = 0

        if self.alpha * slo_t <= p95 <= slo_t:
            self.converged_steps += 1
            self._viol_streak = 0
            self._slack_streak = 0
            return
        if p95 < self.alpha * slo_t:
            self._viol_streak = 0
            self._slack_streak += 1
            # any axis needs TWO slack readings once a BS ceiling is known
            # (refine mode): near the band edge a single wobble below the
            # band is usually noise, and every probe it triggers is served
            # at over-SLO latency.  During the initial climb (no ceiling
            # yet) the primary axis moves on the first reading.
            gate = (2 if self.refine_gate and self._hi < self.hard_max_bs
                    else 1)
            prev = (self.bs, self.mtl, self._share_idx, self._share_value)
            if (self._slack_streak >= gate
                    and self._grow(allow_secondary=self._slack_streak >= 2)):
                self._pending = (prev, thr)
                self.converged_steps = 0
            elif (self._slack_streak >= 3
                  and p95 < 0.5 * self.alpha * slo_t
                  and self._shrink_share()):
                # deep slack and nothing left to grow: probe one share rung
                # down — gives capacity back to the cluster; reverted by the
                # throughput guard / violation undo if the slice mattered
                self._pending = (prev, thr)
                self.converged_steps = 0
            else:
                self.converged_steps += 1
            return
        # slo_t < p95 <= spike_guard * slo_t
        self._slack_streak = 0
        if self._pending is not None:
            # the violation follows our own probe: undo it right away —
            # waiting out the spike filter doubles every probe's cost
            self._viol_streak = 0
            self._shrink()
            return
        self._viol_streak += 1
        if self._viol_streak < 2:
            return                    # skip short-lived spikes (paper §4.4)
        self._viol_streak = 0
        self._shrink()
