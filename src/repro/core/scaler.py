"""Scaler module (paper §3.2.2, Algorithm 1 lines 10-41).

BatchScaler — pseudo binary search over batch size in [1, maxBS] with the
hysteresis band [alpha*SLO, SLO] (alpha = 0.85); dynamic batch sizing means
changes are free.  MTScaler — jump to the matrix-completion-estimated MTL,
then AIMD (+1 under alpha*SLO, -1 over SLO).
"""

from __future__ import annotations

import math
from typing import Optional

from repro.serving.engine import Action

ALPHA = 0.85


class BatchScaler:
    """Algorithm 1, lines 10-29."""

    def __init__(self, slo_s: float, *, max_bs: int = 128, alpha: float = ALPHA,
                 decision_interval: int = 5):
        self.slo = slo_s
        self.alpha = alpha
        self.min_bs = 1
        self.max_bs = max_bs
        self.bs = 1
        self.hard_max = max_bs
        self.decision_interval = decision_interval
        self._steps = 0
        self.infeasible = False
        self.converged_steps = 0
        self._viol_streak = 0   # paper §4.4: short-lived spikes are skipped;
                                # only persistent violations trigger descent
        # Damping beyond the paper: when no batch size lands inside the
        # [alpha*SLO, SLO] band, Algorithm 1 as written oscillates between the
        # last feasible BS and the smallest infeasible one; remembering the
        # infeasible point pins the search at the feasible neighbour.
        self._known_bad: Optional[int] = None

    def set_slo(self, slo_s: float) -> None:
        if slo_s != self.slo:
            self.slo = slo_s
            # re-open the search bounds on SLO change (paper §4.5)
            self.min_bs, self.max_bs = 1, self.hard_max
            self._known_bad = None

    def action(self) -> Action:
        return Action(bs=self.bs, mtl=1)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        self._steps += 1
        if self._steps % self.decision_interval:
            return
        if self.converged_steps >= 12:
            # a known-bad point may have been a transient spike — allow the
            # search to re-probe upward after a long stable stretch
            self._known_bad = None
            self.converged_steps = 0
        if self.alpha * self.slo <= p95 <= self.slo:
            self.converged_steps += 1
            self._viol_streak = 0
            return                                        # line 13-14
        if p95 < self.alpha * self.slo:                   # line 15-18
            self._viol_streak = 0
            if self.bs == self.hard_max:
                return                # largest possible: no further gain
            self.min_bs = self.bs
            cand = min(math.ceil((self.min_bs + self.max_bs) / 2),
                       self.hard_max)
            if self._known_bad is not None and cand >= self._known_bad:
                cand = self._known_bad - 1
            if cand <= self.bs:
                self.converged_steps += 1
                return
            self.bs = cand
        else:                                             # line 19-29
            self._viol_streak += 1
            if self._viol_streak < 2:
                return                # skip short-lived spikes (paper §4.4)
            self._known_bad = self.bs if self._known_bad is None else \
                min(self._known_bad, self.bs)
            if self.bs == 1:
                self.infeasible = True                    # line 20-21
                return
            if self.bs == self.min_bs:                    # line 22-25
                self.max_bs = self.bs
                self.min_bs = 1
                self.bs = max(math.floor((self.min_bs + self.max_bs) / 2), 1)
            else:                                         # line 26-29
                self.max_bs = self.bs
                self.bs = max(math.floor((self.min_bs + self.max_bs) / 2), 1)
        self.converged_steps = 0


class MTScaler:
    """Algorithm 1, lines 30-41: matrix-completion jump + AIMD refinement."""

    def __init__(self, slo_s: float, estimator, observed: dict, *,
                 max_mtl: int = 10, alpha: float = ALPHA,
                 decision_interval: int = 5):
        self.slo = slo_s
        self.alpha = alpha
        self.max_mtl = max_mtl
        self.estimator = estimator
        self.observed = dict(observed)
        self.mtl, self.estimate = estimator.pick_mtl(observed, slo_s)  # line 31-32
        self.decision_interval = decision_interval
        self._steps = 0
        self.converged_steps = 0
        self._viol_streak = 0
        self._known_bad: Optional[int] = None   # oscillation damping (see
                                                # BatchScaler)

    def set_slo(self, slo_s: float) -> None:
        if slo_s != self.slo:
            self._known_bad = None
        self.slo = slo_s

    def action(self) -> Action:
        return Action(bs=1, mtl=self.mtl)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        self._steps += 1
        if self._steps % self.decision_interval:
            return
        if self.converged_steps >= 12:
            self._known_bad = None    # transient-spike amnesty (see above)
            self.converged_steps = 0
        if self.alpha * self.slo <= p95 <= self.slo:      # line 34-35
            self.converged_steps += 1
            self._viol_streak = 0
            return
        if p95 < self.alpha * self.slo:                   # line 36-38
            self._viol_streak = 0
            nxt = self.mtl + 1
            if nxt <= self.max_mtl and nxt != self._known_bad:
                self.mtl = nxt
                self.converged_steps = 0
            else:
                self.converged_steps += 1
        elif p95 > self.slo:                              # line 39-41
            self._viol_streak += 1
            if self._viol_streak < 2:
                return                # skip short-lived spikes (paper §4.4)
            self._known_bad = self.mtl
            if self.mtl > 1:
                self.mtl -= 1
                self.converged_steps = 0
