"""Clipper baseline (Crankshaw et al., NSDI'17) as described in the paper:
AIMD batch-size control — additive +4 while under the SLO, multiplicative
10% back-off on violation.  Batching only, no multi-tenancy."""

from __future__ import annotations

from typing import Optional

from repro.serving.engine import Action


class ClipperController:
    name = "clipper"

    def __init__(self, slo_s: float, *, step: int = 4, backoff: float = 0.10,
                 max_bs: int = 128, decision_interval: int = 5):
        self.slo = slo_s
        self.step = step
        self.backoff = backoff
        self.max_bs = max_bs
        self.bs = 1
        self.decision_interval = decision_interval
        self._steps = 0
        self._held = False   # converged after first violation+backoff; the
                             # additive probe resumes only on large slack
                             # (e.g. an SLO change) — paper Fig. 7 shows
                             # Clipper stabilizing, not sawtoothing.

    def set_slo(self, slo_s: float) -> None:
        if slo_s != self.slo:
            self._held = False
        self.slo = slo_s

    def action(self) -> Action:
        return Action(bs=self.bs, mtl=1)

    def observe(self, p95: float, result: Optional[dict] = None) -> None:
        self._steps += 1
        if self._steps % self.decision_interval:
            return
        if p95 > self.slo:
            self.bs = max(int(self.bs * (1.0 - self.backoff)), 1)
            self._held = True
        elif not self._held or p95 < 0.6 * self.slo:
            self.bs = min(self.bs + self.step, self.max_bs)
            if p95 < 0.6 * self.slo:
                self._held = False
