"""Profiler module (paper §3.2.1): decide Batching vs Multi-Tenancy.

Measures throughput at BS in {1, m} (MTL=1) and MTL in {1, n} (BS=1); m=32,
n=8 as in the paper.  TI_B (eq. 3) and TI_MT (eq. 4) are compared (eq. 5);
ties go to the lower-latency approach.  A few batches per point keep the
probe "of the order of seconds".
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ProfileResult:
    ti_b: float                 # % throughput improvement from batching
    ti_mt: float                # % from multi-tenancy
    approach: str               # 'B' | 'MT'
    thr_base: float             # items/s at BS=1, MTL=1
    thr_bs_m: float
    thr_mtl_n: float
    lat_base: float
    lat_bs_m: float
    lat_mtl_n: float
    probe_time_s: float

    def observed(self) -> dict:
        """Latency observations reusable by matrix completion (paper: the
        MTL=1 and MTL=n points come for free from profiling)."""
        return {1: self.lat_base, None: None}


class Profiler:
    def __init__(self, executor, *, m: int = 32, n: int = 8,
                 probe_steps: int = 3):
        self.executor = executor
        self.m = m
        self.n = n
        self.probe_steps = probe_steps

    def _measure(self, bs: int, mtl: int) -> tuple[float, float, float]:
        """Returns (throughput items/s, median step latency, time spent).

        Median over the probe batches — a single OS/thermal spike in a
        3-sample probe would otherwise flip the B-vs-MT decision."""
        times, items, tot_time = [], 0, 0.0
        for _ in range(self.probe_steps):
            r = self.executor.run_step(bs, mtl)
            items += r["items"]
            times.append(r["step_time"])
            tot_time += r["step_time"]
        times.sort()
        med = times[len(times) // 2]
        per_step_items = items / self.probe_steps
        return per_step_items / med, med, tot_time

    def probe(self) -> ProfileResult:
        thr1, lat1, t1 = self._measure(1, 1)
        thr_b, lat_b, t2 = self._measure(self.m, 1)
        thr_mt, lat_mt, t3 = self._measure(1, self.n)

        ti_b = (thr_b - thr1) / thr1 * 100.0          # eq. (3)
        ti_mt = (thr_mt - thr1) / thr1 * 100.0        # eq. (4)
        if ti_b > ti_mt:                              # eq. (5)
            approach = "B"
        elif ti_b < ti_mt:
            approach = "MT"
        else:
            approach = "B" if lat_b <= lat_mt else "MT"

        res = ProfileResult(
            ti_b=ti_b, ti_mt=ti_mt, approach=approach,
            thr_base=thr1, thr_bs_m=thr_b, thr_mtl_n=thr_mt,
            lat_base=lat1, lat_bs_m=lat_b, lat_mtl_n=lat_mt,
            probe_time_s=t1 + t2 + t3)
        return res

    def mt_observations(self, res: ProfileResult) -> dict:
        """{MTL: per-step latency} observed during profiling — the two free
        points for matrix completion."""
        return {1: res.lat_base, self.n: res.lat_mtl_n}
