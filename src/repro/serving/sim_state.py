"""Structure-of-arrays per-job simulator state (the vectorized hot path).

`ClusterEngine` historically kept every per-job scalar — clock, arrival
mark, backlog, stall/migration accounting — as Python attributes on a
`_JobState` object and drove the lockstep loop through a heap of
`(clock, idx, epoch)` tuples.  That representation tops out far below the
1000-job x 1000-device regime the ROADMAP's scale item targets: the event
loop, the admission scan, and the stall-skew scan all walk Python objects.

`SimState` holds the same scalars as parallel numpy arrays, one slot per
job state.  `_JobState` exposes them through properties (reads return
plain Python scalars, so all arithmetic downstream is bit-identical to
the old attribute code), and the engines query the arrays directly for
the whole-fleet operations:

  * ``frontier()``      — the next event (argmin over active clocks); ties
    break toward the lowest index, exactly the order the reference heap's
    ``(clock, idx, epoch)`` tuples give, so an argmin-driven loop replays
    the heap-driven loop event for event.
  * ``next_event_clock()`` — the admission loop's "next step event" bound.
  * ``min_other_active_clock(i)`` — the running min-clock the stall-skew
    accounting reads; replaces the O(jobs) Python list rebuild that ran on
    every stall.

The tail windows are already vectorized ring buffers
(`metrics.TailLatencyWindow`); backlogs are mirrored into ``backlog`` by
the engine after every open-loop step so fleet-wide queue scans need no
object walk.

Sentinel conventions (arrays cannot hold None): ``depart_s`` uses +inf
for "never departs", ``drained_at`` uses NaN for "still active", and
``feasible_at_serve`` is an int8 tri-state (-1 = never served, else 0/1
— the feasibility snapshot `report()` prefers over recomputing from
whoever lives on the device at the horizon).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

_FLOAT_FIELDS = (
    "clock", "arrival_mark", "admit_s", "depart_s", "drained_at",
    "stall_time", "migration_stall_s", "migration_modeled_s",
    "measured_migration_s", "resize_stall_s",
)
_INT_FIELDS = (
    "epoch", "migrations", "resizes", "submitted", "completed", "backlog",
    # spot revocation: 1 once the job was force-killed at a grace-window
    # deadline (its stranded backlog moved to rejected, not dropped)
    "preempted",
)
_BOOL_FIELDS = ("active",)


class SimState:
    """Parallel per-job state arrays; one slot per `_JobState`."""

    def __init__(self, capacity: int = 16):
        cap = max(int(capacity), 1)
        self._n = 0
        for f in _FLOAT_FIELDS:
            setattr(self, f, np.zeros(cap, np.float64))
        for f in _INT_FIELDS:
            setattr(self, f, np.zeros(cap, np.int64))
        for f in _BOOL_FIELDS:
            setattr(self, f, np.zeros(cap, np.bool_))
        self.feasible_at_serve = np.full(cap, -1, np.int8)

    def __len__(self) -> int:
        return self._n

    def _grow(self, need: int) -> None:
        cap = self.clock.shape[0]
        if need <= cap:
            return
        new = max(need, 2 * cap)
        for f in _FLOAT_FIELDS + _INT_FIELDS + _BOOL_FIELDS + \
                ("feasible_at_serve",):
            arr = getattr(self, f)
            ext = np.full(new, -1, np.int8) if f == "feasible_at_serve" \
                else np.zeros(new, arr.dtype)
            ext[:cap] = arr
            setattr(self, f, ext)

    def add_job(self, *, admit_s: float = 0.0,
                depart_s: Optional[float] = None) -> int:
        """Allocate one slot; returns its index."""
        i = self._n
        self._grow(i + 1)
        self._n = i + 1
        self.clock[i] = admit_s
        self.arrival_mark[i] = admit_s
        self.admit_s[i] = admit_s
        self.depart_s[i] = np.inf if depart_s is None else depart_s
        self.drained_at[i] = np.nan
        self.active[i] = True
        self.feasible_at_serve[i] = -1
        return i

    # -- whole-fleet queries the event loop runs every round ------------------
    def _masked_clocks(self) -> np.ndarray:
        n = self._n
        return np.where(self.active[:n], self.clock[:n], np.inf)

    def next_event_clock(self) -> float:
        """Smallest active clock (+inf when no job is active) — the bound
        the admission loop compares pending arrivals against."""
        if self._n == 0:
            return float("inf")
        return float(self._masked_clocks().min())

    def frontier(self) -> int:
        """Index of the next event: the active job with the smallest
        clock, ties toward the lowest index (argmin's first occurrence —
        the same tie-break as the reference heap's (clock, idx, epoch)
        tuples).  -1 when no job is active."""
        n = self._n
        if n == 0 or not self.active[:n].any():
            return -1
        return int(np.argmin(self._masked_clocks()))

    def min_other_active_clock(self, i: int) -> float:
        """min over every OTHER active job's clock (+inf when there is
        none) — the stall-skew scan, without rebuilding a Python list."""
        m = self._masked_clocks()
        if m.size == 0:
            return float("inf")
        m[i] = np.inf            # _masked_clocks returned a fresh array
        return float(m.min())
