"""Token-level continuous batching: the slot-based decode engine.

The paper's Batching axis treats `bs` as a per-REQUEST knob: a batch is
assembled, served for one fixed-shape step, and drained.  For LLM decode
jobs that shape is wasteful — a finished sequence holds its batch slot
until the whole bucketed step drains.  This module reinterprets `bs` as
*max live decode slots* and serves token by token:

  * admit-on-free-slot — an arriving request is inserted into the RUNNING
    decode batch the moment a slot frees, not at the next batch boundary;
  * evict-on-EOS — a sequence leaves the instant its last token is
    emitted, returning its slot (and its KV pages) immediately;
  * prefill is either time-sliced on the same tenant (decode stalls for
    `JobProfile.prefill_ms`) or priced as a co-resident prefill tenant
    (decode keeps stepping, inflated by the partition model's
    cross-tenant interference terms — the D-STACK-style spatio-temporal
    composition).

Per-token SLOs split a decode request's latency the way production LLM
serving does:

    TTFT  = first_token_s - arrival_s   (queue wait + prefill)
    TPOT  = decode_time_s / decode_tokens  (mean seconds per output token)

and *goodput* counts only the decode tokens of requests that met BOTH.

Pricing: a decode step with `s` live slots is a batch of `s` single-token
requests, so it is priced by the same calibrated laws as a `bs = s` batch
(`device_model.token_latency_grid`); the HybridScaler therefore drives
live slots with its existing `bs` axis — coordinate descent, pins, and
the share ladder all carry over unchanged.

The static bucketed baseline (`policy="static"`) is the same trace served
the old way — batches assembled to `bs`, fixed-shape decode at full `bs`
until the LONGEST member drains — so the continuous-vs-static goodput
ratio isolates exactly the slot-holding waste.

Request conservation (`submitted == completed + rejected + backlog`)
holds at every exit, mirroring the cluster engines' invariant.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import List, Optional, Sequence

import numpy as np

from repro.core.scaler import HybridScaler
from repro.serving import device_model as dm
from repro.serving.executor import SimExecutor
from repro.serving.metrics import TailLatencyWindow
from repro.serving.partition import TenantSlice


@dataclasses.dataclass
class TokenRequest:
    """One decode request: a prompt and a target number of output tokens."""
    req_id: int
    arrival_s: float
    prefill_tokens: int
    decode_tokens: int
    admit_s: float = -1.0          # left the queue (slot granted)
    first_token_s: float = -1.0    # prompt processed, first token out
    finish_s: float = -1.0         # EOS emitted
    decode_time_s: float = 0.0     # seconds spent inside decode steps

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> float:
        return self.decode_time_s / max(self.decode_tokens, 1)


def ragged_decode_trace(n_requests: int = 400, seed: int = 0, *,
                        rate_rps: float = 30.0, prefill_mean: int = 512,
                        decode_mean: int = 96, decode_sigma: float = 0.8,
                        max_decode: int = 1024) -> List[TokenRequest]:
    """Deterministic ragged-length decode trace: Poisson arrivals,
    uniform-ish prompts, LOGNORMAL output lengths (the raggedness that
    makes fixed-shape batching waste slots — max/mean per batch grows
    with `decode_sigma`)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    prefill = rng.integers(prefill_mean // 2, prefill_mean * 3 // 2 + 1,
                           n_requests)
    mu = math.log(decode_mean) - decode_sigma ** 2 / 2.0
    decode = np.clip(np.rint(np.exp(rng.normal(mu, decode_sigma,
                                               n_requests))),
                     1, max_decode).astype(int)
    return [TokenRequest(i, float(arrivals[i]), int(prefill[i]),
                         int(decode[i])) for i in range(n_requests)]


def memory_slot_cap(executor, max_slots: int, mtl: int = 1) -> int:
    """Largest live-slot count the executor's memory admission allows —
    the paged-KV budget (`kv_bytes_per_item`) applied to SLOTS, so a
    decode job cannot over-admit on memory.  At least 1 so the engine can
    always drain (a profile that cannot fit one slot raises instead)."""
    lo = max_slots
    while lo > 1 and not executor.fits(lo, mtl):
        lo -= 1
    if lo == 1 and not executor.fits(1, mtl):
        raise ValueError("profile does not fit a single decode slot")
    return lo


def build_token_controller(executor, tpot_slo_s: float, *,
                           max_slots: int = 64, mtl: int = 1,
                           share_ladder=None,
                           pool_ladder=None) -> HybridScaler:
    """HybridScaler over live slots: `bs` IS the slot cap, seeded from the
    priced token-latency surface so infeasible slot counts are pinned
    before a single over-SLO step is served.  With a `share_ladder` the
    scaler trades live slots against co-tenant device shares with the
    same coordinate-descent/pin machinery as whole-request serving; a
    `pool_ladder` arms the prefill-pool-ratio axis the disaggregated
    engine drives (see `serving.disagg.run_disagg`)."""
    scaler = HybridScaler(tpot_slo_s, primary="B", max_bs=max_slots,
                          max_mtl=mtl, share_ladder=share_ladder,
                          pool_ladder=pool_ladder)
    slots = [s for s in (1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 128)
             if s <= max_slots]
    surface = np.stack([
        dm.token_latency_grid(executor.device, executor.profile, slots, [m])
        [:, 0] for m in range(1, mtl + 1)], axis=1)
    scaler.seed_surface(slots, list(range(1, mtl + 1)), surface)
    return scaler


# ---------------------------------------------------------------------------
# Continuous (slot-based) engine
# ---------------------------------------------------------------------------
def run_continuous(trace: Sequence[TokenRequest], executor, *,
                   max_slots: int = 32, mtl: int = 1,
                   ttft_slo_s: float, tpot_slo_s: float,
                   controller: Optional[HybridScaler] = None,
                   prefill_mode: str = "cotenant",
                   chunk_tokens: int = 256,
                   decode_token_equiv: float = 16.0,
                   max_queue: Optional[int] = None,
                   max_steps: int = 2_000_000) -> dict:
    """Serve `trace` with slot-based continuous batching.

    `prefill_mode`:
      * "cotenant"  — an admitted request's prompt runs as a co-resident
        prefill tenant: decode keeps stepping, priced with
        `prefill_tenants` extra spatial tenants; the slot goes live when
        its prefill completes.
      * "timeslice" — prefill runs serially on the tenant's own clock;
        decode stalls for `prefill_ms` per admission.
      * "chunked"   — prefill is split into fixed token-budget chunks
        piggybacked into decode steps: each step advances up to
        `chunk_tokens` prefill tokens (FIFO across pending prompts),
        priced as `len(live) + chunk_tokens / decode_token_equiv` on the
        existing token-latency grid (`decode_token_equiv` prefill tokens
        cost one decode-token equivalent — prefill is compute-dense where
        decode is weight-streaming bound).  A prompt's slot goes live the
        step its last chunk lands; decode never stalls.
    """
    if prefill_mode not in ("cotenant", "timeslice", "chunked"):
        raise ValueError(prefill_mode)
    trace = [dataclasses.replace(r) for r in trace]   # engines never share
    prof = executor.profile
    prefill_s = prof.prefill_ms / 1e3
    mem_cap = memory_slot_cap(executor, max_slots, mtl)

    clock = 0.0
    queue: deque = deque()
    live: list = []       # [request, tokens_remaining]
    pending: list = []    # [request, prefill_done_t]   (cotenant mode)
    idx = 0               # next trace arrival
    completed = rejected = steps = 0
    tokens_out = 0
    energy_j = 0.0
    finished: list = []
    window = TailLatencyWindow(window=200)
    cur_share = None
    truncated = False

    def slot_cap() -> int:
        cap = max_slots
        if controller is not None:
            cap = min(cap, max(1, int(controller.action().bs)))
        return min(cap, mem_cap)

    while True:
        # 1. pull arrivals up to the clock into the bounded queue
        while idx < len(trace) and trace[idx].arrival_s <= clock:
            if max_queue is not None and len(queue) >= max_queue:
                rejected += 1
            else:
                queue.append(trace[idx])
            idx += 1
        # 2. spatial-share trading: align the executor's slice with the
        #    controller's current request (repricing only, no relaunch)
        if controller is not None and controller.share is not None:
            s = controller.share
            if s != cur_share:
                executor.set_partition(TenantSlice(share=s))
                controller.set_granted_share(s)
                cur_share = s
        # 3. admit-on-free-slot into the RUNNING batch
        cap = slot_cap()
        chunked = prefill_mode == "chunked"
        while queue and len(live) + len(pending) < cap:
            req = queue.popleft()
            req.admit_s = clock
            if prefill_mode == "timeslice":
                clock += prefill_s          # decode stalls on this tenant
                req.first_token_s = clock
                live.append([req, req.decode_tokens])
            elif chunked:                   # prompt joins the chunk queue
                pending.append([req, max(int(req.prefill_tokens), 1)])
            else:
                pending.append([req, clock + prefill_s])
        # 4. activate co-resident prefills that completed
        if pending and not chunked:
            still = []
            for req, done_t in pending:
                if done_t <= clock:
                    req.first_token_s = done_t
                    live.append([req, req.decode_tokens])
                else:
                    still.append([req, done_t])
            pending = still
        # 5. one decode step: every live slot emits one token (chunked
        #    mode also advances up to `chunk_tokens` prefill tokens)
        if live or (chunked and pending):
            extra = 0.0
            if chunked and pending:
                budget = chunk_tokens       # FIFO within the chunk budget
                for rec in pending:
                    if budget <= 0:
                        break
                    take = min(budget, rec[1])
                    rec[1] -= take
                    budget -= take
                extra = (chunk_tokens - budget) / decode_token_equiv
            if live:
                r = executor.run_token_step(
                    len(live), mtl,
                    prefill_tenants=0 if chunked else len(pending),
                    extra_slots=extra)
                lat = r["step_time"]
                power = r["power_w"]
            else:
                # chunked prefill-only step: no slot decodes; the chunk is
                # priced alone on the same grid (a batch of `extra`
                # decode-token equivalents, power at the bs=1 draw)
                mean = executor.token_step_latency(0, mtl, 0, extra)
                lat = float(executor.sampler.sample(mean, n=1)[0])
                executor.clock += lat
                power = executor.power_terms(1, mtl)[0]
            clock += lat
            steps += 1
            tokens_out += len(live) * mtl
            energy_j += power * lat
            if live:
                window.add_many(np.full(min(len(live), 64), lat))
                if controller is not None:
                    controller.observe(window.p95,
                                       {"items": len(live),
                                        "step_time": lat})
            still = []
            for rec in live:
                rec[1] -= 1
                rec[0].decode_time_s += lat
                if rec[1] == 0:             # evict-on-EOS: slot frees NOW
                    rec[0].finish_s = clock
                    completed += 1
                    finished.append(rec[0])
                else:
                    still.append(rec)
            live = still
            if chunked and pending:
                still_p = []
                for rec in pending:
                    if rec[1] <= 0:         # last chunk landed: KV is live
                        rec[0].first_token_s = clock
                        live.append([rec[0], rec[0].decode_tokens])
                    else:
                        still_p.append(rec)
                pending = still_p
        elif pending:                       # idle until a prefill lands
            clock = min(done_t for _, done_t in pending)
            continue
        elif idx < len(trace):              # idle until the next arrival
            clock = trace[idx].arrival_s
            continue
        else:
            break
        if steps >= max_steps:
            truncated = True
            break

    backlog = len(queue) + len(live) + len(pending)
    return _token_report(
        "continuous", finished, clock=clock, tokens_out=tokens_out,
        steps=steps, energy_j=energy_j, submitted=idx, completed=completed,
        rejected=rejected, backlog=backlog, ttft_slo_s=ttft_slo_s,
        tpot_slo_s=tpot_slo_s, truncated=truncated)


# ---------------------------------------------------------------------------
# Static bucketed baseline
# ---------------------------------------------------------------------------
def run_static(trace: Sequence[TokenRequest], executor, *,
               bs: int = 32, mtl: int = 1,
               ttft_slo_s: float, tpot_slo_s: float,
               max_steps: int = 2_000_000) -> dict:
    """The same trace under classic fixed-shape batching: wait for `bs`
    requests (or end of trace), batched prefill, then decode at FULL `bs`
    until the longest member drains — finished sequences HOLD their slots,
    which is precisely the waste continuous batching removes."""
    trace = [dataclasses.replace(r) for r in trace]
    prof = executor.profile
    prefill_s = prof.prefill_ms / 1e3
    bs = min(bs, memory_slot_cap(executor, bs, mtl))

    clock = 0.0
    steps = 0
    tokens_out = 0
    energy_j = 0.0
    finished: list = []
    truncated = False
    i = 0
    while i < len(trace):
        batch = trace[i:i + bs]
        i += len(batch)
        # the fixed-shape engine waits for its batch to fill
        start = max(clock, batch[-1].arrival_s)
        p_end = start + prefill_s * len(batch)   # batched, compute-bound
        d_max = max(r.decode_tokens for r in batch)
        n_steps = min(d_max, max_steps - steps)
        mean = executor.token_step_latency(len(batch), mtl)
        lats = executor.sampler.sample(mean, n=n_steps)
        cum = np.cumsum(lats)
        power = dm.power(executor.device, prof, len(batch), mtl)
        for req in batch:
            req.admit_s = start
            req.first_token_s = p_end
            d = min(req.decode_tokens, n_steps)
            if d == req.decode_tokens:
                req.finish_s = p_end + float(cum[d - 1])
                finished.append(req)
            req.decode_time_s = float(cum[d - 1]) if d else 0.0
            tokens_out += d * mtl
        steps += n_steps
        clock = p_end + float(cum[-1]) if n_steps else p_end
        executor.clock += float(cum[-1]) if n_steps else 0.0
        energy_j += power * float(cum[-1]) if n_steps else 0.0
        if steps >= max_steps:
            truncated = True
            break

    completed = len(finished)
    backlog = len(trace) - completed
    return _token_report(
        "static", finished, clock=clock, tokens_out=tokens_out, steps=steps,
        energy_j=energy_j, submitted=len(trace), completed=completed,
        rejected=0, backlog=backlog, ttft_slo_s=ttft_slo_s,
        tpot_slo_s=tpot_slo_s, truncated=truncated)


# ---------------------------------------------------------------------------
# Reports and entry points
# ---------------------------------------------------------------------------
def _token_report(policy: str, finished, *, clock, tokens_out, steps,
                  energy_j, submitted, completed, rejected, backlog,
                  ttft_slo_s, tpot_slo_s, truncated) -> dict:
    ttft = np.asarray([r.ttft_s for r in finished], np.float64)
    tpot = np.asarray([r.tpot_s for r in finished], np.float64)
    dtoks = np.asarray([r.decode_tokens for r in finished], np.float64)
    ok = ((ttft <= ttft_slo_s) & (tpot <= tpot_slo_s)) if len(finished) \
        else np.zeros(0, bool)
    makespan = max(clock, 1e-12)
    n = max(len(finished), 1)
    return {
        "policy": policy,
        "requests": list(finished),     # the engine's own copies, stamped
        "submitted": int(submitted),
        "completed": int(completed),
        "rejected": int(rejected),
        "backlog": int(backlog),
        "conserved": submitted == completed + rejected + backlog,
        "makespan_s": float(makespan),
        "steps": int(steps),
        "tokens_out": int(tokens_out),
        "throughput_tokens_s": tokens_out / makespan,
        # goodput: decode tokens of requests that met BOTH per-token SLOs
        "goodput_tokens_s": float(dtoks[ok].sum()) / makespan,
        "ttft_p95_s": float(np.quantile(ttft, 0.95)) if len(ttft) else 0.0,
        "tpot_p95_s": float(np.quantile(tpot, 0.95)) if len(tpot) else 0.0,
        "ttft_attainment": float((ttft <= ttft_slo_s).sum()) / n,
        "tpot_attainment": float((tpot <= tpot_slo_s).sum()) / n,
        "slo_attainment": float(ok.sum()) / n,
        "mean_live_slots": tokens_out / max(steps, 1),
        "energy_j": float(energy_j),
        "ttft_slo_s": float(ttft_slo_s),
        "tpot_slo_s": float(tpot_slo_s),
        "truncated": bool(truncated),
    }


def run_token_serving(profile: dm.JobProfile, *, policy: str = "continuous",
                      device: dm.Device = dm.TPU_V5E, seed: int = 0,
                      trace: Optional[Sequence[TokenRequest]] = None,
                      n_requests: int = 400, rate_rps: float = 30.0,
                      max_slots: int = 32, static_bs: Optional[int] = None,
                      mtl: int = 1, ttft_slo_s: float = 2.0,
                      tpot_slo_s: float = 0.25,
                      use_controller: bool = False,
                      share_ladder=None,
                      prefill_mode: str = "cotenant",
                      chunk_tokens: int = 256,
                      decode_token_equiv: float = 16.0,
                      max_queue: Optional[int] = None,
                      executor=None) -> dict:
    """One decode job served token by token — the `serve.py --token-engine`
    entry point.  `policy="continuous"` runs the slot engine (optionally
    under a HybridScaler driving live slots / shares), `policy="static"`
    the fixed-shape bucketed baseline on the SAME trace."""
    if trace is None:
        trace = ragged_decode_trace(n_requests, seed, rate_rps=rate_rps)
    if executor is None:
        executor = SimExecutor(profile, device, seed=seed)
    if policy == "static":
        return run_static(trace, executor, bs=static_bs or max_slots,
                          mtl=mtl, ttft_slo_s=ttft_slo_s,
                          tpot_slo_s=tpot_slo_s)
    if policy != "continuous":
        raise ValueError(policy)
    controller = None
    if use_controller:
        controller = build_token_controller(executor, tpot_slo_s,
                                            max_slots=max_slots, mtl=mtl,
                                            share_ladder=share_ladder)
    return run_continuous(trace, executor, max_slots=max_slots, mtl=mtl,
                          ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s,
                          controller=controller, prefill_mode=prefill_mode,
                          chunk_tokens=chunk_tokens,
                          decode_token_equiv=decode_token_equiv,
                          max_queue=max_queue)


def run_token_cluster(profiles: Sequence[dm.JobProfile], *,
                      device: dm.Device = dm.TPU_V5E, seed: int = 0,
                      **kwargs) -> dict:
    """Fleet-level per-token accounting: one token engine per decode job
    (job i on its own device with its own seeded noise stream), aggregated
    with the cluster engines' conservation convention — the fleet is
    conserved iff every job is and the totals add up."""
    jobs = [run_token_serving(p, device=device, seed=seed + 17 * i, **kwargs)
            for i, p in enumerate(profiles)]
    tot = {k: int(sum(j[k] for j in jobs))
           for k in ("submitted", "completed", "rejected", "backlog",
                     "tokens_out", "steps")}
    makespan = max(j["makespan_s"] for j in jobs)
    tot.update({
        "jobs": jobs,
        "n_jobs": len(jobs),
        "makespan_s": makespan,
        "throughput_tokens_s": sum(j["throughput_tokens_s"] for j in jobs),
        "goodput_tokens_s": sum(j["goodput_tokens_s"] for j in jobs),
        "slo_attainment": (sum(j["slo_attainment"] * j["completed"]
                               for j in jobs)
                           / max(sum(j["completed"] for j in jobs), 1)),
        "conserved": (all(j["conserved"] for j in jobs)
                      and tot["submitted"] == tot["completed"]
                      + tot["rejected"] + tot["backlog"]),
        "truncated": any(j["truncated"] for j in jobs),
    })
    return tot
