"""Disaggregated prefill/decode serving: prefill pool + KV-transfer fabric.

PR 7's continuous-batching engine prices prefill on the SAME device as
decode — time-sliced (decode stalls) or as a co-resident spatial tenant
(decode steps inflate).  Both couple two phases that sit on opposite ends
of the roofline: prefill is compute-dense (one big matmul over the whole
prompt), decode is weight-streaming bound (one token per slot per step).
Disaggregation makes the fleet itself the third answer to the paper's
batching-vs-multi-tenancy dichotomy:

  * a ``PrefillPool`` of prefill-specialized tenancies on DEDICATED
    devices (``place_disagg_fleet`` carves them out of a cluster
    ``DeviceSpec`` fleet) absorbs every prompt;
  * a ``KVTransferFabric`` prices the finished KV cache's handoff
    (``kv_bytes_per_item x prefill_len``) over the per-device-class
    interconnect model (``device_model.Interconnect``: NVLink / PCIe /
    ICI / DCN bandwidth + a per-transfer latency floor, the DCN class
    reusing the TPU checkpoint-transfer constant);
  * a router assigns each request's prefill to the LEAST-LOADED pool
    member, then streams the finished KV into a free decode slot on the
    least-loaded decode device.

TTFT becomes queue + prefill + transfer; TPOT stays PURE decode — the
decode devices never see a prefill tenant, so their step latency is the
uncontended token-latency law.

Request conservation extends the cluster invariant with an in-flight
term: ``submitted == completed + rejected + backlog`` where backlog folds
in requests still prefilling or mid-KV-transfer — it holds at every exit,
including truncation and mid-transfer revocation of a pool member (the
revoked member's in-flight requests conserve into ``rejected``).

The ``HybridScaler``'s pool-ratio axis (``pool_ladder``) drives the
number of ACTIVE prefill members per decode device, demand-capped like
the share axis: the engine feeds it measured prefill-queue pressure and
the pool's busy fraction between decision windows.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Sequence

import numpy as np

from repro.serving import device_model as dm
from repro.serving.executor import SimExecutor
from repro.serving.metrics import TailLatencyWindow
from repro.serving.token_engine import (TokenRequest, _token_report,
                                        build_token_controller,
                                        memory_slot_cap,
                                        ragged_decode_trace)


# ---------------------------------------------------------------------------
# KV-transfer fabric
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class KVTransferFabric:
    """Prices KV-cache handoff over one interconnect class and keeps the
    accounting the bench pins against the analytic formula:

        transfer_s(n) = ic.latency_s + kv_bytes_per_token * n / ic.bw_bps
    """

    interconnect: dm.Interconnect
    kv_bytes_per_token: float
    transfers: int = 0
    bytes_moved: float = 0.0
    busy_s: float = 0.0

    def transfer_s(self, prefill_tokens: int) -> float:
        """The analytic transfer time for one request's KV (no state)."""
        return self.interconnect.transfer_s(
            self.kv_bytes_per_token * prefill_tokens)

    def charge(self, prefill_tokens: int) -> float:
        """Account one transfer and return its duration (seconds)."""
        t = self.transfer_s(prefill_tokens)
        self.transfers += 1
        self.bytes_moved += self.kv_bytes_per_token * prefill_tokens
        self.busy_s += t
        return t


def fabric_for(profile: dm.JobProfile, *, device: dm.Device = dm.TPU_V5E,
               kv_seq_budget: int = 1024,
               interconnect: Optional[dm.Interconnect] = None
               ) -> KVTransferFabric:
    """The fabric for one decode profile: per-token KV bytes derived from
    the profile's paged-KV reservation at its sequence budget, link class
    from the device registry (override with `interconnect`)."""
    ic = interconnect if interconnect is not None \
        else dm.interconnect_for(device.name)
    return KVTransferFabric(ic, profile.kv_bytes_per_item
                            / max(int(kv_seq_budget), 1))


# ---------------------------------------------------------------------------
# Prefill pool
# ---------------------------------------------------------------------------
class PrefillPool:
    """Prefill-specialized tenancies on dedicated devices.

    Each member is one device running nothing but prompt processing; the
    router (`assign`) picks the least-loaded member (earliest `free_at`,
    ties to the lowest id — deterministic).  A prompt of `n` tokens costs
    `n * prefill_s_per_token` member-seconds (sampled through the
    member's own noise stream), so pool time is token-proportional where
    the single-device modes charge the profile's flat budget-priced
    `prefill_ms` — the same mean on a trace whose prompts average the
    budget."""

    def __init__(self, profile: dm.JobProfile, *,
                 device: dm.Device = dm.TPU_V5E, n_members: int = 2,
                 kv_seq_budget: int = 1024, seed: int = 0):
        if n_members < 1:
            raise ValueError("a prefill pool needs at least one member")
        self.profile = profile
        self.device = device
        self.n_members = int(n_members)
        self.prefill_s_per_token = (profile.prefill_ms / 1e3
                                    / max(int(kv_seq_budget), 1))
        self.samplers = [dm.LatencySampler(seed=seed + 101 * m)
                         for m in range(self.n_members)]
        self.free_at = [0.0] * self.n_members
        self.busy_s = [0.0] * self.n_members
        self.prefills = [0] * self.n_members
        self.active = self.n_members       # pool-ratio axis resizes this
        self.dead: set = set()             # revoked members never assign

    # -- membership ---------------------------------------------------------
    def set_active(self, k: int) -> None:
        """Resize the ACTIVE membership (the pool-ratio axis): members
        beyond `k` stop receiving assignments but finish what they hold."""
        self.active = max(1, min(int(k), self.n_members))

    def kill(self, member: int) -> None:
        """Revoke one member (spot capacity loss): it never assigns again;
        the engine conserves its in-flight requests into `rejected`."""
        self.dead.add(int(member))

    def _candidates(self) -> List[int]:
        return [m for m in range(min(self.active, self.n_members))
                if m not in self.dead]

    # -- routing ------------------------------------------------------------
    def assign(self, clock: float, prefill_tokens: int) -> tuple:
        """Route one prompt to the least-loaded live member.  Returns
        (member, done_t); raises RuntimeError with every member dead."""
        cands = self._candidates()
        if not cands:
            raise RuntimeError("prefill pool has no live members")
        m = min(cands, key=lambda i: (self.free_at[i], i))
        start = max(clock, self.free_at[m])
        mean = self.prefill_s_per_token * max(int(prefill_tokens), 1)
        dur = float(self.samplers[m].sample(mean, n=1)[0])
        done = start + dur
        self.free_at[m] = done
        self.busy_s[m] += dur
        self.prefills[m] += 1
        return m, done

    # -- accounting ---------------------------------------------------------
    def energy_j(self, makespan: float) -> float:
        """Pool energy: the idle floor over the run for every member that
        ever powered on, plus the dynamic range over busy (compute-bound
        prefill runs the device near peak)."""
        dyn = self.device.peak_w - self.device.idle_w
        total = 0.0
        for m in range(self.n_members):
            if self.prefills[m]:
                total += self.device.idle_w * makespan \
                    + dyn * min(self.busy_s[m], makespan)
        return total

    def stats(self) -> dict:
        return {
            "members": self.n_members,
            "active": int(self.active),
            "dead": sorted(self.dead),
            "prefills": list(self.prefills),
            "busy_s": [float(b) for b in self.busy_s],
        }


def place_disagg_fleet(fleet: Sequence, n_prefill: int) -> tuple:
    """Split a cluster `DeviceSpec` fleet into (prefill_specs,
    decode_specs): the LAST `n_prefill` members become dedicated prefill
    devices (mirroring `spot_fleet`'s tail convention), the rest serve
    decode.  The ClusterEngine's placement idiom for disaggregation —
    prefill tenancies live on devices no decode tenant ever lands on."""
    fleet = list(fleet)
    if not 0 < n_prefill < len(fleet):
        raise ValueError("need at least one prefill AND one decode device")
    return fleet[len(fleet) - n_prefill:], fleet[:len(fleet) - n_prefill]


# ---------------------------------------------------------------------------
# The disaggregated engine
# ---------------------------------------------------------------------------
def run_disagg(trace: Sequence[TokenRequest], decode_executors, pool,
               fabric, *, max_slots: int = 32, mtl: int = 1,
               ttft_slo_s: float, tpot_slo_s: float,
               controller=None, pool_decision_steps: int = 200,
               max_queue: Optional[int] = None,
               revoke: Optional[tuple] = None,
               max_steps: int = 2_000_000) -> dict:
    """Serve `trace` disaggregated: every prompt goes to the prefill pool
    the moment it arrives, its finished KV streams over `fabric` into a
    free decode slot, and the decode device(s) run PURE token steps.

    `decode_executors` — one executor per decode device (a single
    executor is wrapped); with several, KV-ready requests activate on the
    least-loaded device (fewest live slots, ties to the lowest id) and
    devices advance in lockstep (earliest clock steps first).

    `revoke=(at_s, member)` kills one pool member mid-run: requests whose
    prefill or KV transfer is still in flight on it at `at_s` conserve
    into `rejected`; everything already decoding keeps its landed KV.

    A `controller` built with a `pool_ladder` drives the pool-ratio axis:
    every `pool_decision_steps` decode steps the engine feeds it the p95
    prefill+transfer wait and the pool's demand (busy device-seconds per
    second), and applies the resized active membership.
    """
    if not isinstance(decode_executors, (list, tuple)):
        decode_executors = [decode_executors]
    n_dev = len(decode_executors)
    trace = [dataclasses.replace(r) for r in trace]   # engines never share
    mem_cap = min(memory_slot_cap(ex, max_slots, mtl)
                  for ex in decode_executors)

    clocks = [0.0] * n_dev
    queue: deque = deque()
    in_flight: list = []   # [req, member, kv_done_t] — prefill OR transfer
    live = [[] for _ in range(n_dev)]     # per device: [req, tokens_left]
    idx = 0                               # next trace arrival
    completed = rejected = steps = 0
    tokens_out = 0
    energy_j = 0.0
    finished: list = []
    truncated = False
    revoke_at, revoke_member = (revoke if revoke is not None
                                else (None, None))
    revoked = False
    wait_samples: deque = deque(maxlen=256)   # prefill+transfer waits
    pool_mark_busy = 0.0
    pool_mark_t = 0.0
    window = TailLatencyWindow(window=200)

    def slot_cap() -> int:
        cap = max_slots
        if controller is not None:
            cap = min(cap, max(1, int(controller.action().bs)))
        return min(cap, mem_cap)

    def fire_revocation(now: float) -> int:
        """Kill the member; in-flight requests on it become `rejected`."""
        pool.kill(revoke_member)
        still, killed = [], 0
        for rec in in_flight:
            if rec[1] == revoke_member and rec[2] > revoke_at:
                killed += 1
            else:
                still.append(rec)
        in_flight[:] = still
        return killed

    while True:
        d = int(np.argmin(clocks))        # lockstep: earliest device steps
        clock = clocks[d]
        if revoke_at is not None and not revoked and clock >= revoke_at:
            rejected += fire_revocation(clock)
            revoked = True
        # 1. arrivals up to this device's clock enter the bounded queue
        while idx < len(trace) and trace[idx].arrival_s <= clock:
            if max_queue is not None and len(queue) >= max_queue:
                rejected += 1
            else:
                queue.append(trace[idx])
            idx += 1
        # 2. route every queued prompt to the pool NOW — prefill never
        #    waits for a decode slot (that is the whole point)
        while queue:
            req = queue.popleft()
            req.admit_s = clock
            m, p_done = pool.assign(clock, req.prefill_tokens)
            kv_done = p_done + fabric.charge(req.prefill_tokens)
            in_flight.append([req, m, kv_done])
        # 3. stream landed KV into free decode slots on THIS device
        cap = slot_cap()
        if in_flight and len(live[d]) < cap:
            in_flight.sort(key=lambda rec: rec[2])
            still = []
            for rec in in_flight:
                if rec[2] <= clock and len(live[d]) < cap:
                    req = rec[0]
                    # TTFT = queue + prefill + transfer (+ slot wait when
                    # the decode side is the bottleneck)
                    req.first_token_s = max(rec[2], clock)
                    live[d].append([req, req.decode_tokens])
                else:
                    still.append(rec)
            in_flight = still
        # 4. one PURE decode step — no prefill tenant ever lands here
        if live[d]:
            r = decode_executors[d].run_token_step(len(live[d]), mtl)
            lat = r["step_time"]
            clocks[d] = clock + lat
            steps += 1
            tokens_out += len(live[d]) * mtl
            energy_j += r["power_w"] * lat
            window.add_many(np.full(min(len(live[d]), 64), lat))
            if controller is not None:
                controller.observe(window.p95, {"items": len(live[d]),
                                                "step_time": lat})
            still = []
            for rec in live[d]:
                rec[1] -= 1
                rec[0].decode_time_s += lat
                if rec[1] == 0:           # evict-on-EOS: slot frees NOW
                    rec[0].finish_s = clocks[d]
                    completed += 1
                    finished.append(rec[0])
                else:
                    still.append(rec)
            live[d] = still
        elif any(live[e] for e in range(n_dev)):
            # this device is empty but a peer still decodes: catch up to
            # the fleet's next event so the argmin keeps rotating
            clocks[d] = min(min((c for e, c in enumerate(clocks)
                                 if live[e]), default=clock),
                            *[rec[2] for rec in in_flight]) \
                if in_flight else min(c for e, c in enumerate(clocks)
                                      if live[e])
            clocks[d] = max(clocks[d], clock + 1e-9)
        elif in_flight:                   # idle until the next KV lands
            nxt = min(rec[2] for rec in in_flight)
            if revoke_at is not None and not revoked and nxt > revoke_at:
                nxt = revoke_at
            for e in range(n_dev):
                clocks[e] = max(clocks[e], nxt)
            continue
        elif idx < len(trace):            # idle until the next arrival
            nxt = trace[idx].arrival_s
            if revoke_at is not None and not revoked and nxt > revoke_at:
                nxt = revoke_at
            for e in range(n_dev):
                clocks[e] = max(clocks[e], nxt)
            continue
        else:
            break
        # 5. pool-ratio axis: feed pressure + demand every decision window
        if controller is not None \
                and getattr(controller, "pool_ladder", None) is not None \
                and steps and steps % pool_decision_steps == 0:
            now = max(clocks)
            for rec in in_flight:
                wait_samples.append(max(rec[2] - rec[0].admit_s, 0.0))
            busy = sum(pool.busy_s)
            dt = max(now - pool_mark_t, 1e-9)
            demand = (busy - pool_mark_busy) / dt   # prefill dev-seconds/s
            pool_mark_busy, pool_mark_t = busy, now
            controller.note_pool_demand(demand / max(n_dev, 1))
            wait = (float(np.quantile(np.asarray(wait_samples), 0.95))
                    if wait_samples else 0.0)
            if controller.observe_pool(wait, ttft_slo_s):
                pool.set_active(
                    int(round(controller.pool_ratio * max(n_dev, 1))))
        if steps >= max_steps:
            truncated = True
            break

    makespan = max(max(clocks), 0.0)
    energy_j += pool.energy_j(makespan)
    backlog = (len(queue) + len(in_flight)
               + sum(len(live[e]) for e in range(n_dev)))
    rep = _token_report(
        "disagg", finished, clock=makespan, tokens_out=tokens_out,
        steps=steps, energy_j=energy_j, submitted=idx, completed=completed,
        rejected=rejected, backlog=backlog, ttft_slo_s=ttft_slo_s,
        tpot_slo_s=tpot_slo_s, truncated=truncated)
    rep.update({
        "n_decode_devices": n_dev,
        "in_transfer": len(in_flight),    # folded into backlog above
        "pool": pool.stats(),
        "fabric": {
            "interconnect": fabric.interconnect.name,
            "bw_bps": float(fabric.interconnect.bw_bps),
            "latency_s": float(fabric.interconnect.latency_s),
            "kv_bytes_per_token": float(fabric.kv_bytes_per_token),
            "transfers": int(fabric.transfers),
            "bytes_moved": float(fabric.bytes_moved),
            "busy_s": float(fabric.busy_s),
        },
    })
    return rep


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def run_disagg_serving(profile: dm.JobProfile, *,
                       device: dm.Device = dm.TPU_V5E, seed: int = 0,
                       trace: Optional[Sequence[TokenRequest]] = None,
                       n_requests: int = 400, rate_rps: float = 30.0,
                       prefill_mean: int = 2048,
                       n_prefill: int = 2, n_decode: int = 1,
                       kv_seq_budget: int = 1024,
                       interconnect: Optional[dm.Interconnect] = None,
                       max_slots: int = 32, mtl: int = 1,
                       ttft_slo_s: float = 2.0, tpot_slo_s: float = 0.25,
                       use_controller: bool = False,
                       pool_ladder: Optional[Sequence[float]] = None,
                       max_queue: Optional[int] = None,
                       revoke: Optional[tuple] = None) -> dict:
    """One decode job served disaggregated — the `serve.py
    --prefill-mode disagg` entry point.  Builds `n_decode` decode
    executors, an `n_prefill`-member PrefillPool on the same device
    class, and the fabric from the device's interconnect registry."""
    if trace is None:
        trace = ragged_decode_trace(n_requests, seed, rate_rps=rate_rps,
                                    prefill_mean=prefill_mean)
    decode_executors = [SimExecutor(profile, device, seed=seed + 13 * e)
                        for e in range(max(int(n_decode), 1))]
    pool = PrefillPool(profile, device=device, n_members=n_prefill,
                       kv_seq_budget=kv_seq_budget, seed=seed + 7)
    fabric = fabric_for(profile, device=device,
                        kv_seq_budget=kv_seq_budget,
                        interconnect=interconnect)
    controller = None
    if use_controller:
        controller = build_token_controller(
            decode_executors[0], tpot_slo_s, max_slots=max_slots, mtl=mtl,
            pool_ladder=pool_ladder)
    return run_disagg(trace, decode_executors, pool, fabric,
                      max_slots=max_slots, mtl=mtl, ttft_slo_s=ttft_slo_s,
                      tpot_slo_s=tpot_slo_s, controller=controller,
                      max_queue=max_queue, revoke=revoke)


def run_disagg_cluster(profiles: Sequence[dm.JobProfile], *,
                       device: dm.Device = dm.TPU_V5E, seed: int = 0,
                       **kwargs) -> dict:
    """Fleet-level disaggregated accounting: one disagg engine per decode
    job (job i with its own pool slice and seeded noise streams),
    aggregated with the token cluster's conservation convention."""
    jobs = [run_disagg_serving(p, device=device, seed=seed + 17 * i,
                               **kwargs)
            for i, p in enumerate(profiles)]
    tot = {k: int(sum(j[k] for j in jobs))
           for k in ("submitted", "completed", "rejected", "backlog",
                     "tokens_out", "steps")}
    makespan = max(j["makespan_s"] for j in jobs)
    tot.update({
        "jobs": jobs,
        "n_jobs": len(jobs),
        "makespan_s": makespan,
        "throughput_tokens_s": sum(j["throughput_tokens_s"] for j in jobs),
        "goodput_tokens_s": sum(j["goodput_tokens_s"] for j in jobs),
        "slo_attainment": (sum(j["slo_attainment"] * j["completed"]
                               for j in jobs)
                           / max(sum(j["completed"] for j in jobs), 1)),
        "conserved": (all(j["conserved"] for j in jobs)
                      and tot["submitted"] == tot["completed"]
                      + tot["rejected"] + tot["backlog"]),
        "truncated": any(j["truncated"] for j in jobs),
    })
    return tot
