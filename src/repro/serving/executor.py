"""Executors: where (simulated or real) inference time comes from.

SimExecutor — analytical device model (device_model.py) + latency noise;
  prices (BS, MTL) for a JobProfile on a Device or a TPU submesh plan.

RealExecutor — actually runs a jitted model on this host and measures wall
  clock.  Multi-tenancy is emulated by stacking MTL independent instance
  batches on a leading axis (vmap), which shares the host compute the way
  co-located GPU contexts share SMs.  Used for reduced models in tests,
  examples, and the real-execution benchmarks.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import device_model as dm
from repro.serving import tenancy


class SimExecutor:
    """Closed-loop simulated executor for one job."""

    def __init__(self, profile: dm.JobProfile, device: dm.Device = dm.TESLA_P40,
                 seed: int = 0, mesh_shape: Optional[tuple] = None):
        self.profile = profile
        self.device = device
        self.sampler = dm.LatencySampler(seed=seed)
        self.mesh_shape = mesh_shape   # TPU mode: tenancy = submesh split
        self.clock = 0.0

    # -- pricing ------------------------------------------------------------
    def mean_latency(self, bs: int, mtl: int) -> float:
        if self.mesh_shape is not None:
            # non-divisor MTLs over-partition (plan_at_least) instead of
            # returning inf — an inf step would poison the engine clock
            # and every downstream metric the moment a scaler probes one
            p = tenancy.plan_at_least(self.mesh_shape, mtl)
            if p is None:
                return float("inf")
            return dm.step_latency(self.device, self.profile, bs,
                                   share=p.share)["t_step"]
        return dm.mt_latency(self.device, self.profile, bs, mtl)

    def fits(self, bs: int, mtl: int) -> bool:
        return dm.fits_memory(self.device, self.profile, bs, mtl)

    # -- execution ----------------------------------------------------------
    def run_step(self, bs: int, mtl: int) -> dict:
        """Simulate one synchronized step of all MTL instances."""
        mean = self.mean_latency(bs, mtl)
        lat = float(self.sampler.sample(mean, n=1)[0])
        self.clock += lat
        items = bs * mtl
        return {
            "step_time": lat,
            "items": items,
            "request_latencies": self.sampler.sample(lat, n=min(items, 64)),
            "power_w": dm.power(self.device, self.profile, bs, mtl),
            "throughput": items / lat,
        }


class RealExecutor:
    """Wall-clock executor over a jitted callable.

    `fn(params, batch)` consumes a batch pytree whose leaves have leading
    dim = instances*bs (instances folded in by the caller via make_batch)."""

    def __init__(self, fn: Callable, params, make_batch: Callable,
                 idle_w: float = 50.0, peak_w: float = 250.0):
        self.fn = fn
        self.params = params
        self.make_batch = make_batch
        self.idle_w = idle_w
        self.peak_w = peak_w
        self._compiled: dict = {}
        self.clock = 0.0

    def _get(self, bs: int, mtl: int):
        key = (bs, mtl)
        if key not in self._compiled:
            batch = self.make_batch(bs * mtl)
            out = self.fn(self.params, batch)   # trigger compile
            jax.block_until_ready(out)
            self._compiled[key] = batch
        return self._compiled[key]

    def mean_latency(self, bs: int, mtl: int, iters: int = 3) -> float:
        batch = self._get(bs, mtl)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = self.fn(self.params, batch)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    def fits(self, bs: int, mtl: int) -> bool:
        return bs * mtl <= 4096

    def run_step(self, bs: int, mtl: int) -> dict:
        batch = self._get(bs, mtl)
        t0 = time.perf_counter()
        out = self.fn(self.params, batch)
        jax.block_until_ready(out)
        lat = time.perf_counter() - t0
        self.clock += lat
        items = bs * mtl
        return {
            "step_time": lat,
            "items": items,
            "request_latencies": np.full(min(items, 64), lat),
            "power_w": self.peak_w * 0.6,
            "throughput": items / lat,
        }
