"""Executors: where (simulated or real) inference time comes from.

SimExecutor — analytical device model (device_model.py) + latency noise;
  prices (BS, MTL) for a JobProfile on a Device or a TPU submesh plan.
  ``price_surface`` prices a whole (bs, mtl) grid in one vectorized call
  (HybridScaler seeding), and per-point means are memoized — the serving
  loop stopped recomputing the same closed-form latency every step.

RealExecutor — actually runs a jitted model on this host and measures wall
  clock.  Multi-tenancy is emulated by stacking MTL independent instance
  batches on a leading axis (vmap), which shares the host compute the way
  co-located GPU contexts share SMs.  Used for reduced models in tests,
  examples, and the real-execution benchmarks.

  The executor is an AOT fast path: operating points are lowered and
  compiled ahead of execution (``jit(...).lower().compile()``), batch
  shapes are bucketed so scaler probes of nearby (bs, mtl) points reuse
  one executable instead of recompiling, and every compile's wall time is
  reported in ``result["compile_time"]`` so the engine charges it to the
  service clock like an instance-launch stall.  Cache hit/miss counters
  live in ``metrics.ExecCacheStats``; steady-state probing must show zero
  misses after warmup.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.perf import autotune
from repro.serving import device_model as dm
from repro.serving import tenancy
from repro.serving.metrics import ExecCacheStats


class SimExecutor:
    """Closed-loop simulated executor for one job."""

    def __init__(self, profile: dm.JobProfile, device: dm.Device = dm.TESLA_P40,
                 seed: int = 0, mesh_shape: Optional[tuple] = None,
                 partition=None, power_share: float = 1.0):
        self.profile = profile
        self.device = device
        self.sampler = dm.LatencySampler(seed=seed)
        self.mesh_shape = mesh_shape   # TPU mode: tenancy = submesh split
        self.partition = partition     # TenantSlice: spatial slice pricing
        self.power_share = power_share  # time-share fraction for power pricing
        self.clock = 0.0
        self._lat_cache: dict = {}     # (bs, mtl) -> mean latency (exact)
        self._power_cache: dict = {}   # (bs, mtl) -> (total_w, dynamic_w)
        self._tok_cache: dict = {}     # (slots, mtl, prefills) -> mean step

    def set_partition(self, ts) -> None:
        """Resize this executor's spatial slice (MPS set-percentage / MIG
        reconfigure): repricing only, no instance relaunch — the cheapness
        the cluster's resize-instead-of-migrate path exploits."""
        self.partition = ts
        self._lat_cache.clear()
        self._power_cache.clear()
        self._tok_cache.clear()

    # -- pricing ------------------------------------------------------------
    def mean_latency(self, bs: int, mtl: int) -> float:
        key = (bs, mtl)
        lat = self._lat_cache.get(key)
        if lat is None:
            lat = self._price(bs, mtl)
            self._lat_cache[key] = lat
        return lat

    def _price(self, bs: int, mtl: int) -> float:
        if self.partition is not None:
            ts = self.partition
            return dm.part_latency(self.device, self.profile, bs, mtl,
                                   inv_share=ts.inv_share,
                                   tenants=ts.tenants,
                                   isolation=ts.isolation)
        if self.mesh_shape is not None:
            # non-divisor MTLs over-partition (plan_at_least) instead of
            # returning inf — an inf step would poison the engine clock
            # and every downstream metric the moment a scaler probes one
            p = tenancy.plan_at_least(self.mesh_shape, mtl)
            if p is None:
                return float("inf")
            return dm.step_latency(self.device, self.profile, bs,
                                   share=p.share)["t_step"]
        return dm.mt_latency(self.device, self.profile, bs, mtl)

    def price_surface(self, bs_values, mtl_values) -> np.ndarray:
        """Mean-latency surface over the whole (bs, mtl) grid — one
        vectorized call per tenancy plan instead of a Python double loop.
        Shape (len(bs_values), len(mtl_values))."""
        bs_values = np.asarray(bs_values)
        if self.partition is not None:
            ts = self.partition
            return dm.part_latency_grid(self.device, self.profile,
                                        bs_values, mtl_values,
                                        inv_share=ts.inv_share,
                                        tenants=ts.tenants,
                                        isolation=ts.isolation)
        if self.mesh_shape is None:
            return dm.mt_latency_grid(self.device, self.profile,
                                      bs_values, mtl_values)
        cols = []
        for m in mtl_values:
            p = tenancy.plan_at_least(self.mesh_shape, int(m))
            if p is None:
                cols.append(np.full(len(bs_values), np.inf))
            else:
                cols.append(dm.step_latency_grid(
                    self.device, self.profile, bs_values,
                    share=p.share)["t_step"])
        return np.stack(cols, axis=1)

    def fits(self, bs: int, mtl: int) -> bool:
        dev = self.device
        if self.partition is not None:
            # the tenant sees only its memory slice, not the whole HBM
            import dataclasses
            dev = dataclasses.replace(
                dev, hbm_bytes=dev.hbm_bytes * self.partition.mem_fraction)
        return dm.fits_memory(dev, self.profile, bs, mtl)

    def power_terms(self, bs: int, mtl: int) -> tuple:
        """(total_w, dynamic_w) this executor's slice draws at (bs, mtl).

        Per-slice pricing (device_model.slice_power): a partitioned tenant
        draws its share of the idle floor plus share-scaled dynamic power on
        the partition latency law; a time-share tenant draws power_share of
        both.  dynamic_w = total_w - share * idle_w lets the cluster charge
        the idle floor ONCE per powered device instead of once per tenant.
        """
        key = (bs, mtl)
        terms = self._power_cache.get(key)
        if terms is None:
            ts = self.partition
            if ts is not None:
                share = ts.share
                total = dm.slice_power(self.device, self.profile, bs, mtl,
                                       share=share, inv_share=ts.inv_share,
                                       tenants=ts.tenants,
                                       isolation=ts.isolation)
            else:
                share = self.power_share
                total = dm.slice_power(self.device, self.profile, bs, mtl,
                                       share=share)
            terms = (total, total - share * self.device.idle_w)
            self._power_cache[key] = terms
        return terms

    # -- execution ----------------------------------------------------------
    def run_step(self, bs: int, mtl: int) -> dict:
        """Simulate one synchronized step of all MTL instances."""
        mean = self.mean_latency(bs, mtl)
        lat = float(self.sampler.sample(mean, n=1)[0])
        self.clock += lat
        items = bs * mtl
        power, dyn = self.power_terms(bs, mtl)
        return {
            "step_time": lat,
            "items": items,
            "request_latencies": self.sampler.sample(lat, n=min(items, 64)),
            "power_w": power,
            "dynamic_power_w": dyn,
            "throughput": items / lat,
        }

    # -- token engine --------------------------------------------------------
    def token_step_latency(self, live_slots: int, mtl: int = 1,
                           prefill_tenants: int = 0,
                           extra_slots: float = 0.0) -> float:
        """Mean decode-step latency with `live_slots` slots occupied.

        A co-scheduled prefill ("cotenant" prefill mode) is priced as an
        extra spatial tenant on TOP of any configured partition slice —
        the same cross-tenant interference terms the partition model
        calibrates against the paper's MTL curves.

        `extra_slots` ("chunked" prefill mode) piggybacks a prefill chunk
        into the step as fractional decode-token equivalents: the step is
        priced as a batch of `live_slots + extra_slots` on the same grid
        (the grids are float-polymorphic, so 16 + 0.0 prices bit-identical
        to 16 — the default is an exact no-op)."""
        key = (live_slots, mtl, prefill_tenants, extra_slots)
        lat = self._tok_cache.get(key)
        if lat is None:
            ts = self.partition
            lat = float(dm.token_latency_grid(
                self.device, self.profile, [live_slots + extra_slots],
                [mtl],
                inv_share=ts.inv_share if ts is not None else 1.0,
                tenants=(ts.tenants if ts is not None else 1)
                + prefill_tenants,
                isolation=ts.isolation if ts is not None else 0.0)[0, 0])
            self._tok_cache[key] = lat
        return lat

    def run_token_step(self, live_slots: int, mtl: int = 1, *,
                       prefill_tenants: int = 0,
                       extra_slots: float = 0.0) -> dict:
        """Simulate one decode step: every live slot emits one token (a
        nonzero `extra_slots` also advances piggybacked prefill chunks —
        priced into the step, not counted as output tokens)."""
        mean = self.token_step_latency(live_slots, mtl, prefill_tenants,
                                       extra_slots)
        lat = float(self.sampler.sample(mean, n=1)[0])
        self.clock += lat
        tokens = live_slots * mtl
        power, dyn = self.power_terms(live_slots, mtl)
        return {
            "step_time": lat,
            "tokens": tokens,
            "items": tokens,
            "power_w": power,
            "dynamic_power_w": dyn,
            "throughput": tokens / lat,
        }


# Default batch buckets: dense at small sizes (where the scalers live), a
# x1.5 / x2 ladder above — every (bs * mtl) rounds UP to one of these, so a
# probing scaler touches O(log) distinct executables instead of one per point.
DEFAULT_BUCKETS = (1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256,
                   384, 512, 768, 1024, 1536, 2048, 3072, 4096)

# fits() activation-estimate multiplier: per-item batch bytes amplified
# through the network (activations, workspace, output buffers).
ACT_MULT = 12.0
PARAM_OVERHEAD = 1.3   # optimizer-free serving copy + allocator slack


class RealExecutor:
    """Wall-clock executor over a jitted callable.

    `fn(params, batch)` consumes a batch pytree whose leaves have leading
    dim = instances*bs (instances folded in by the caller via make_batch).

    AOT + bucketing: `run_step(bs, mtl)` rounds bs*mtl up to a bucket,
    compiles that bucket's executable once ahead of time, and reuses it for
    every operating point that lands in the bucket (padding rows are masked
    out of the throughput accounting — only real items count).  With
    `donate_batch=True` input buffers are donated to the executable and a
    fresh device batch is staged per step (the real serving path, where
    every request brings new data); by default the cached device batch is
    reused and nothing is donated.
    """

    def __init__(self, fn: Callable, params, make_batch: Callable,
                 idle_w: float = 50.0, peak_w: float = 250.0, *,
                 mem_bytes: Optional[float] = None,
                 act_bytes_per_item: Optional[float] = None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 donate_batch: bool = False,
                 aot: bool = True,
                 tile_generation: Optional[Callable[[], int]] = None,
                 kv_bytes_per_item: float = 0.0):
        self.fn = fn
        self.params = params
        self.make_batch = make_batch
        self.idle_w = idle_w
        self.peak_w = peak_w
        self.mem_bytes = mem_bytes
        self.act_bytes_per_item = act_bytes_per_item
        self.kv_bytes_per_item = kv_bytes_per_item
        self.buckets = tuple(sorted(buckets))
        self.donate_batch = donate_batch
        self.aot = aot
        if donate_batch:
            # wrap so donation applies regardless of whether fn is jitted
            self._jfn = jax.jit(lambda p, b: fn(p, b), donate_argnums=(1,))
        elif hasattr(fn, "lower"):
            self._jfn = fn               # already jitted: AOT-lower directly
        else:
            self._jfn = jax.jit(fn)
        # bucket items -> (executable, batch, tuned-tile generation); a
        # generation bump (new tuning persisted) makes resident entries
        # stale — they are evicted and recompiled, never served
        self._exec: dict = {}
        self._tile_generation = tile_generation or autotune.generation
        self._param_bytes: Optional[float] = None
        self.cache_stats = ExecCacheStats()
        self._pending_compile = 0.0      # compile seconds not yet charged
        self.partition = None            # TenantSlice: capped-batch proxy
        self.clock = 0.0

    def set_partition(self, ts) -> None:
        """Spatial-partition proxy for a single-process host: this process
        cannot literally run inside an MPS percentage or MIG slice, so a
        slice is emulated by inflating the measured wall clock with the
        slice's calibrated slowdown (`TenantSlice.slowdown`) — the
        capped-compute proxy.  The raw wall measurement is still reported
        (``wall_step_time``) so callers can record the measured
        interference ratio into the profile store."""
        self.partition = ts

    # -- capacity -----------------------------------------------------------
    def bucket(self, n: int) -> int:
        """Smallest bucket >= n (or n itself beyond the largest bucket)."""
        for b in self.buckets:
            if b >= n:
                return b
        return n

    @property
    def param_bytes(self) -> float:
        if self._param_bytes is None:    # fits() runs per scaler candidate
            leaves = jax.tree_util.tree_leaves(self.params)
            self._param_bytes = float(sum(x.size * x.dtype.itemsize
                                          for x in leaves))
        return self._param_bytes

    def _batch_bytes_per_item(self) -> float:
        if self.act_bytes_per_item is not None:
            return self.act_bytes_per_item
        leaves = jax.tree_util.tree_leaves(self.make_batch(1))
        raw = sum(np.asarray(x).size * np.asarray(x).dtype.itemsize
                  for x in leaves)
        self.act_bytes_per_item = raw * ACT_MULT
        return self.act_bytes_per_item

    def fits(self, bs: int, mtl: int) -> bool:
        """Memory-aware admission when a `mem_bytes` budget is configured
        (param bytes + per-item activation estimate at the BUCKETED batch,
        since that is the shape actually compiled); the historical hard
        cap `bs * mtl <= 4096` when no budget is given.

        Decode-mode profiles additionally charge the paged KV cache:
        `kv_bytes_per_item` per LIVE slot (not bucketed — pages are
        allocated per admitted request, the compiled bucket shape only
        pads activations).  Without it a decode job could over-admit on
        memory the bucket estimate never sees."""
        n = bs * mtl
        if self.mem_bytes is None:
            return n <= 4096
        need = (self.param_bytes * PARAM_OVERHEAD
                + self.bucket(n) * self._batch_bytes_per_item()
                + n * self.kv_bytes_per_item)
        return need <= self.mem_bytes

    # -- executable cache ---------------------------------------------------
    def _get(self, n_bucket: int):
        entry = self._exec.get(n_bucket)
        if entry is not None:
            if entry[2] == int(self._tile_generation()):
                self.cache_stats.hits += 1
                return entry
            # compiled under superseded tile sizes: evict, never serve
            del self._exec[n_bucket]
            self.cache_stats.stale_evictions += 1
        self.cache_stats.misses += 1
        t0 = time.perf_counter()
        batch = self.make_batch(n_bucket)
        if self.donate_batch:
            # host template FIRST: a donating warmup call below would delete
            # the device buffers before they could be read back
            batch = jax.tree_util.tree_map(np.asarray, batch)
        if self.aot:
            executable = self._jfn.lower(self.params, batch).compile()
        else:
            executable = self._jfn
            jax.block_until_ready(
                executable(self.params, self._staged_batch(batch)))
        dt = time.perf_counter() - t0
        self.cache_stats.compile_time_s += dt
        self._pending_compile += dt
        # tagged with the generation read AFTER compiling — those are the
        # tiles the compile's kernel lookups actually consulted (a
        # tune_on_miss search triggered DURING the compile bumps the
        # generation, and this executable already uses its result)
        entry = (executable, batch, int(self._tile_generation()))
        self._exec[n_bucket] = entry
        return entry

    # -- migration instrumentation -------------------------------------------
    def shutdown(self) -> float:
        """Tear down the resident executables (the 'kill' half of a
        migration's kill+relaunch round) and return the seconds it took.
        The measurement feeds the profile store's migration calibration."""
        t0 = time.perf_counter()
        self._exec.clear()
        self._pending_compile = 0.0
        return time.perf_counter() - t0

    def warmup(self, bs: int, mtl: int) -> float:
        """Compile the bucket executable for (bs, mtl) ahead of serving and
        return the compile seconds (0.0 on a cache hit).  The pending
        compile charge is consumed here so the caller charging this as a
        migration/relaunch stall does not double-charge the next step."""
        self._get(self.bucket(bs * mtl))
        dt = self._pending_compile
        self._pending_compile = 0.0
        return dt

    def _staged_batch(self, batch):
        return jax.device_put(batch) if self.donate_batch else batch

    # -- pricing ------------------------------------------------------------
    def mean_latency(self, bs: int, mtl: int, iters: int = 3) -> float:
        executable, batch, _ = self._get(self.bucket(bs * mtl))
        staged = [self._staged_batch(batch) for _ in range(iters)]
        t0 = time.perf_counter()
        for b in staged:
            out = executable(self.params, b)
        jax.block_until_ready(out)
        wall = (time.perf_counter() - t0) / iters
        if self.partition is not None:
            return wall * self.partition.proxy_slowdown()
        return wall

    # -- execution ----------------------------------------------------------
    def run_step(self, bs: int, mtl: int) -> dict:
        nb = self.bucket(bs * mtl)
        executable, batch, gen = self._get(nb)
        comp = self._pending_compile
        self._pending_compile = 0.0
        staged = self._staged_batch(batch)
        t0 = time.perf_counter()
        out = executable(self.params, staged)
        jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        slowdown = (self.partition.proxy_slowdown()
                    if self.partition is not None else 1.0)
        lat = wall * slowdown
        if gen != int(self._tile_generation()):
            # a tuning landed between the cache lookup and this serve:
            # the step above ran on superseded tiles.  Count it (the
            # invariant steady-state serving asserts is ZERO) and evict
            # so the next step recompiles under the new generation.
            self.cache_stats.stale_hits += 1
            self._exec.pop(nb, None)
        self.clock += lat + comp
        items = bs * mtl                 # bucket padding rows do not count
        return {
            "step_time": lat,
            "items": items,
            "compile_time": comp,
            "bucket_items": nb,
            "wall_step_time": wall,
            "partition_slowdown": slowdown,
            "request_latencies": np.full(min(items, 64), lat),
            "power_w": self.peak_w * 0.6,
            "dynamic_power_w": max(self.peak_w * 0.6 - self.idle_w, 0.0),
            "throughput": items / lat,
        }

    # -- token engine --------------------------------------------------------
    def run_token_step(self, live_slots: int, mtl: int = 1, *,
                       prefill_tenants: int = 0,
                       extra_slots: float = 0.0) -> dict:
        """One measured decode step with `live_slots` slots occupied: the
        jitted callable IS the decode-step function, and the bucketed AOT
        ladder doubles as the slot ladder (a step at 37 live slots runs
        the 48-slot executable; padding slots don't count as tokens).
        A co-resident prefill on this single-process host shares the wall
        clock it is measured on, so no extra pricing term is added.
        Chunked-prefill `extra_slots` widen the measured batch (rounded up
        to whole rows) without counting as output tokens."""
        width = live_slots + int(np.ceil(extra_slots))
        r = self.run_step(width, mtl)
        r["tokens"] = live_slots * mtl
        r["items"] = r["tokens"]
        return r
