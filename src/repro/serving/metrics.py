"""Serving metrics: sliding-window tail latency, throughput, power/energy,
and the real-executor AOT compile-cache counters."""

from __future__ import annotations

import dataclasses

import numpy as np


class TailLatencyWindow:
    """p95 (the paper's SLO metric) over the most recent N request latencies.

    Ring buffer + memoized quantile: the cluster engines read ``p95`` twice
    per step (trace + controller observation), which made ``np.quantile``
    over a deque the single hottest line of the 30-job cluster bench.  The
    quantile is recomputed only after the buffer changes, via a partial
    sort, reproducing ``np.quantile``'s linear interpolation exactly."""

    def __init__(self, window: int = 200, quantile: float = 0.95):
        self.window = window
        self.quantile = quantile
        self._buf = np.empty(window, np.float64)
        self._n = 0            # valid samples (<= window)
        self._i = 0            # next write slot
        self._p95: float | None = None

    def __len__(self) -> int:
        return self._n

    def add(self, latency_s: float, count: int = 1) -> None:
        self.add_many([latency_s] * count)

    def add_many(self, latencies) -> None:
        lat = np.asarray(latencies, np.float64).ravel()
        if lat.size >= self.window:          # only the newest `window` survive
            self._buf[:] = lat[-self.window:]
            self._n, self._i = self.window, 0
        elif lat.size:
            end = min(self._i + lat.size, self.window)
            head = end - self._i
            self._buf[self._i:end] = lat[:head]
            if head < lat.size:              # wrap around
                self._buf[:lat.size - head] = lat[head:]
            self._i = (self._i + lat.size) % self.window
            self._n = min(self._n + lat.size, self.window)
        self._p95 = None

    @property
    def p95(self) -> float:
        if self._n == 0:
            return 0.0
        if self._p95 is None:
            a = self._buf[:self._n]
            pos = self.quantile * (self._n - 1)
            lo = int(pos)
            if lo + 1 >= self._n:
                self._p95 = float(a.max())
            else:
                part = np.partition(a, (lo, lo + 1))
                self._p95 = float(part[lo] + (pos - lo) * (part[lo + 1]
                                                           - part[lo]))
        return self._p95

    @property
    def mean(self) -> float:
        return float(self._buf[:self._n].mean()) if self._n else 0.0

    def reset(self) -> None:
        self._n, self._i, self._p95 = 0, 0, None


@dataclasses.dataclass
class ExecCacheStats:
    """Hit/miss counters for RealExecutor's AOT executable cache.

    ``reset_counters`` is the warmup boundary: steady-state serving must
    show ``misses == 0`` afterwards (every scaler probe reuses a compiled
    executable).

    Executables are keyed by (batch bucket, tuned-tile generation): when
    the autotune generation bumps, resident executables are STALE —
    ``stale_evictions`` counts the ones dropped and recompiled, and
    ``stale_hits`` counts any served anyway.  ``stale_hits`` must stay 0:
    serving an executable compiled under superseded tile sizes silently
    undoes the tuning."""

    hits: int = 0
    misses: int = 0
    compile_time_s: float = 0.0
    stale_hits: int = 0
    stale_evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0

    def reset_counters(self) -> None:
        self.hits = self.misses = 0
        self.compile_time_s = 0.0
        self.stale_hits = self.stale_evictions = 0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate,
                "compile_time_s": self.compile_time_s,
                "stale_hits": self.stale_hits,
                "stale_evictions": self.stale_evictions}


class RunAccumulator:
    """Aggregates a serving run: throughput, SLO attainment, energy."""

    def __init__(self):
        self.total_items = 0
        self.total_time = 0.0
        self.energy_j = 0.0
        self.latencies: list = []
        self._bulk_lats: list = []     # request-latency ARRAYS appended by
        #                                record_bulk — kept whole instead of
        #                                exploded into the Python list
        self.trace: list = []          # (t, bs_or_mtl, p95, throughput)
        self.violations = 0
        self.requests = 0
        self.compile_stall_s = 0.0     # XLA compile time charged to the run

    def record_step(self, *, items: int, step_time: float, power_w: float,
                    request_latencies, slo: float) -> None:
        self.total_items += items
        self.total_time += step_time
        self.energy_j += power_w * step_time
        lat = list(request_latencies)
        self.latencies.extend(lat)
        self.requests += len(lat)
        self.violations += sum(1 for x in lat if x > slo)

    def record_bulk(self, *, items: int, busy_s: float, energy_j: float,
                    request_latencies, slo: float) -> None:
        """Aggregate a whole CHUNK of steps at once (the vectorized
        cluster path): totals accumulate exactly as repeated
        `record_step` calls would, but the request latencies stay one
        numpy array instead of thousands of list appends."""
        self.total_items += int(items)
        self.total_time += float(busy_s)
        self.energy_j += float(energy_j)
        lat = np.asarray(request_latencies, np.float64).reshape(-1)
        if lat.size:
            self._bulk_lats.append(lat)
        self.requests += int(lat.size)
        self.violations += int(np.count_nonzero(lat > slo))

    def _lat_array(self) -> np.ndarray:
        """All request latencies in arrival order, whichever recording
        path produced them."""
        if not self._bulk_lats:
            return np.asarray(self.latencies)
        parts = ([np.asarray(self.latencies, np.float64)]
                 if self.latencies else []) + self._bulk_lats
        return np.concatenate(parts)

    @property
    def throughput(self) -> float:
        return self.total_items / self.total_time if self.total_time else 0.0

    @property
    def avg_power(self) -> float:
        return self.energy_j / self.total_time if self.total_time else 0.0

    @property
    def power_efficiency(self) -> float:
        return self.throughput / self.avg_power if self.avg_power else 0.0

    @property
    def p95(self) -> float:
        lat = self._lat_array()
        if not lat.size:
            return 0.0
        return float(np.quantile(lat, 0.95))

    def tail_p95(self, frac: float = 0.5) -> float:
        """p95 over the last `frac` of requests — the steady-state tail once
        the scaler's search transient (which p95 over the whole run mixes
        in) has died out."""
        lat = self._lat_array()
        if not lat.size:
            return 0.0
        n = max(1, int(lat.size * frac))
        return float(np.quantile(lat[-n:], 0.95))

    @property
    def slo_attainment(self) -> float:
        if not self.requests:
            return 1.0
        return 1.0 - self.violations / self.requests

    def summary(self) -> dict:
        return {
            "throughput": self.throughput,
            "p95_s": self.p95,
            "avg_power_w": self.avg_power,
            "power_efficiency": self.power_efficiency,
            "slo_attainment": self.slo_attainment,
            "items": self.total_items,
            "sim_time_s": self.total_time,
            "compile_stall_s": self.compile_stall_s,
        }
