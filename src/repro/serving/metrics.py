"""Serving metrics: sliding-window tail latency, throughput, power/energy."""

from __future__ import annotations

from collections import deque

import numpy as np


class TailLatencyWindow:
    """p95 (the paper's SLO metric) over the most recent N request latencies."""

    def __init__(self, window: int = 200, quantile: float = 0.95):
        self.window = window
        self.quantile = quantile
        self.buf: deque = deque(maxlen=window)

    def add(self, latency_s: float, count: int = 1) -> None:
        for _ in range(count):
            self.buf.append(latency_s)

    def add_many(self, latencies) -> None:
        self.buf.extend(latencies)

    @property
    def p95(self) -> float:
        if not self.buf:
            return 0.0
        return float(np.quantile(np.asarray(self.buf), self.quantile))

    @property
    def mean(self) -> float:
        return float(np.mean(self.buf)) if self.buf else 0.0

    def reset(self) -> None:
        self.buf.clear()


class RunAccumulator:
    """Aggregates a serving run: throughput, SLO attainment, energy."""

    def __init__(self):
        self.total_items = 0
        self.total_time = 0.0
        self.energy_j = 0.0
        self.latencies: list = []
        self.trace: list = []          # (t, bs_or_mtl, p95, throughput)
        self.violations = 0
        self.requests = 0

    def record_step(self, *, items: int, step_time: float, power_w: float,
                    request_latencies, slo: float) -> None:
        self.total_items += items
        self.total_time += step_time
        self.energy_j += power_w * step_time
        lat = list(request_latencies)
        self.latencies.extend(lat)
        self.requests += len(lat)
        self.violations += sum(1 for x in lat if x > slo)

    @property
    def throughput(self) -> float:
        return self.total_items / self.total_time if self.total_time else 0.0

    @property
    def avg_power(self) -> float:
        return self.energy_j / self.total_time if self.total_time else 0.0

    @property
    def power_efficiency(self) -> float:
        return self.throughput / self.avg_power if self.avg_power else 0.0

    @property
    def p95(self) -> float:
        if not self.latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies), 0.95))

    def tail_p95(self, frac: float = 0.5) -> float:
        """p95 over the last `frac` of requests — the steady-state tail once
        the scaler's search transient (which p95 over the whole run mixes
        in) has died out."""
        if not self.latencies:
            return 0.0
        n = max(1, int(len(self.latencies) * frac))
        return float(np.quantile(np.asarray(self.latencies[-n:]), 0.95))

    @property
    def slo_attainment(self) -> float:
        if not self.requests:
            return 1.0
        return 1.0 - self.violations / self.requests

    def summary(self) -> dict:
        return {
            "throughput": self.throughput,
            "p95_s": self.p95,
            "avg_power_w": self.avg_power,
            "power_efficiency": self.power_efficiency,
            "slo_attainment": self.slo_attainment,
            "items": self.total_items,
            "sim_time_s": self.total_time,
        }
