"""Serving engine: closed-loop request processing under a controller.

The engine owns the executor, the tail-latency window, instance lifecycle
costs (launching/terminating co-located instances stalls the service — the
very overhead that motivates the paper's matrix-completion jump), and the
metrics accumulator.  Controllers (repro.core) expose:

    action()              -> Action(bs, mtl)
    observe(p95, result)  -> None        (called after every step)

Dynamic batch-size changes are free (the paper's dynamic batch sizing);
MTL changes cost `instance_launch_s` per added and `instance_kill_s` per
removed instance.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.serving.metrics import RunAccumulator, TailLatencyWindow


@dataclasses.dataclass
class Action:
    bs: int = 1
    mtl: int = 1


class ServingEngine:
    def __init__(self, executor, slo_s: float, *,
                 window: int = 200,
                 instance_launch_s: float = 2.0,
                 instance_kill_s: float = 0.3,
                 slo_schedule: Optional[Callable[[float], float]] = None):
        self.executor = executor
        self.base_slo = slo_s
        self.window = TailLatencyWindow(window=window)
        self.acc = RunAccumulator()
        self.instance_launch_s = instance_launch_s
        self.instance_kill_s = instance_kill_s
        self.slo_schedule = slo_schedule
        self.reconfig_time = 0.0

    def current_slo(self) -> float:
        if self.slo_schedule is not None:
            return self.slo_schedule(self.acc.total_time)
        return self.base_slo

    def run(self, controller, *, max_steps: int = 2000,
            sim_time_limit: Optional[float] = None) -> RunAccumulator:
        prev = Action(bs=1, mtl=1)
        for _ in range(max_steps):
            slo = self.current_slo()
            if hasattr(controller, "set_slo"):
                controller.set_slo(slo)
            act = controller.action()

            # instance lifecycle cost
            if act.mtl != prev.mtl:
                delta = act.mtl - prev.mtl
                cost = (self.instance_launch_s * max(delta, 0) +
                        self.instance_kill_s * max(-delta, 0))
                self.acc.total_time += cost
                self.reconfig_time += cost
                self.window.reset()
            elif act.bs != prev.bs:
                # dynamic batch sizing is free, but the tail window must be
                # measured fresh at the new BS (the paper "processes a certain
                # number of batches and measures their tail latency" per BS)
                self.window.reset()

            res = self.executor.run_step(act.bs, act.mtl)
            self.window.add_many(res["request_latencies"])
            self.acc.record_step(
                items=res["items"], step_time=res["step_time"],
                power_w=res["power_w"],
                request_latencies=res["request_latencies"], slo=slo)
            self.acc.trace.append(
                (self.acc.total_time, act.bs, act.mtl, self.window.p95,
                 res["throughput"], slo))
            controller.observe(self.window.p95, res)
            prev = act
            if sim_time_limit and self.acc.total_time >= sim_time_limit:
                break
        return self.acc


class OpenLoopEngine(ServingEngine):
    """Open-loop serving: requests arrive via a (bursty) Poisson process and
    queue; per-request latency = queueing wait + batch service time.  This is
    the regime of the paper's §3.2 note that "some inference workloads arrive
    in a burst and not uniformly" — controllers must absorb bursts without
    violating the SLO for long.
    """

    def __init__(self, executor, slo_s: float, *, arrival_rate: float,
                 burst_factor: float = 1.0, burst_period_s: float = 30.0,
                 seed: int = 0, **kw):
        super().__init__(executor, slo_s, **kw)
        self.arrival_rate = arrival_rate
        self.burst_factor = burst_factor
        self.burst_period_s = burst_period_s
        import numpy as _np
        self._rng = _np.random.default_rng(seed)
        self.queue: list = []          # arrival timestamps
        self.dropped = 0
        self.max_queue = 100_000

    def _rate(self, t: float) -> float:
        if self.burst_factor <= 1.0:
            return self.arrival_rate
        phase = (t % self.burst_period_s) / self.burst_period_s
        return self.arrival_rate * (self.burst_factor if phase < 0.3 else 1.0)

    def run(self, controller, *, max_steps: int = 2000,
            sim_time_limit=None) -> RunAccumulator:
        import numpy as np
        prev = Action(bs=1, mtl=1)
        for _ in range(max_steps):
            slo = self.current_slo()
            if hasattr(controller, "set_slo"):
                controller.set_slo(slo)
            act = controller.action()
            win_start = self.acc.total_time   # arrivals span any stall too
            if act.mtl != prev.mtl:
                delta = act.mtl - prev.mtl
                cost = (self.instance_launch_s * max(delta, 0) +
                        self.instance_kill_s * max(-delta, 0))
                self.acc.total_time += cost
                self.reconfig_time += cost
                self.window.reset()
            elif act.bs != prev.bs:
                self.window.reset()

            res = self.executor.run_step(act.bs, act.mtl)
            t0 = self.acc.total_time
            t1 = t0 + res["step_time"]
            # arrivals during this step INCLUDING the launch/kill stall —
            # the outside world does not pause while instances restart
            window = t1 - win_start
            n_arr = int(self._rng.poisson(self._rate(win_start) * window))
            self.queue.extend(
                np.sort(win_start + self._rng.random(n_arr) * window)
                if n_arr else [])
            if len(self.queue) > self.max_queue:
                self.dropped += len(self.queue) - self.max_queue
                self.queue = self.queue[-self.max_queue:]
            capacity = act.bs * act.mtl
            served_ts, self.queue = self.queue[:capacity], self.queue[capacity:]
            lats = [t1 - ts for ts in served_ts]
            self.acc.record_step(
                items=len(served_ts), step_time=res["step_time"],
                power_w=res["power_w"], request_latencies=lats, slo=slo)
            # The controller observes SERVICE latency (as in the paper's
            # closed-loop measurement): feeding it queue-inclusive latency
            # would make the batch scaler shrink the batch exactly when the
            # backlog demands growing it (a death spiral).  End-to-end
            # (queue + service) latencies still go to the accumulator above.
            self.window.add_many(res["request_latencies"])
            self.acc.trace.append(
                (t1, act.bs, act.mtl, self.window.p95,
                 len(served_ts) / res["step_time"], slo))
            controller.observe(self.window.p95, res)
            prev = act
            if sim_time_limit and self.acc.total_time >= sim_time_limit:
                break
        return self.acc
