"""Serving engine: closed-loop request processing under a controller.

The engine owns the executor, the tail-latency window, instance lifecycle
costs (launching/terminating co-located instances stalls the service — the
very overhead that motivates the paper's matrix-completion jump), and the
metrics accumulator.  Controllers (repro.core) expose:

    action()              -> Action(bs, mtl)
    observe(p95, result)  -> None        (called after every step)

Dynamic batch-size changes are free (the paper's dynamic batch sizing);
MTL changes cost `instance_launch_s` per added and `instance_kill_s` per
removed instance.  Executors that compile on demand (RealExecutor's AOT
cache) report the compile wall time in ``result["compile_time"]``; it is
charged to the engine clock exactly like an instance-launch stall, so
adaptation cost is modeled rather than hidden.

The per-step open-loop mechanics (stall accounting, the stall-spanning
arrival window, bounded-queue overflow) are shared with
``serving.cluster.ClusterEngine`` via ``reconfig_stall`` and
``OpenLoopQueue`` — one implementation, patched once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.serving.metrics import RunAccumulator, TailLatencyWindow


@dataclasses.dataclass
class Action:
    bs: int = 1
    mtl: int = 1
    share: Optional[float] = None   # requested partition share (3rd knob);
    #                                 None = no spatial partitioning — the
    #                                 engines ignore it, ClusterEngine's
    #                                 partition mode mediates the grant


def reconfig_stall(prev: Action, act: Action, launch_s: float,
                   kill_s: float) -> float:
    """Stall seconds for moving prev -> act.  BS changes are free (dynamic
    batch sizing); MTL changes cost per instance launched/killed."""
    if act.mtl == prev.mtl:
        return 0.0
    delta = act.mtl - prev.mtl
    return launch_s * max(delta, 0) + kill_s * max(-delta, 0)


class OpenLoopQueue:
    """Open-loop request bookkeeping shared by OpenLoopEngine and
    ClusterEngine: a (possibly time-varying) Poisson arrival process, the
    stall-spanning arrival window, bounded-queue overflow (oldest dropped
    first), and exact request conservation —
    ``submitted == completed + rejected + backlog`` at every step."""

    def __init__(self, rate_fn: Callable[[float], float], *,
                 max_queue: int, seed: int = 0,
                 piecewise_s: Optional[float] = None,
                 step_breaks: Optional[Callable] = None):
        self.rate_fn = rate_fn
        self.rng = np.random.default_rng(seed)
        self.queue: list = []            # arrival timestamps
        self.submitted = 0
        self.rejected = 0
        self.max_queue = max_queue
        # sub-interval bound for the piecewise rate integral: a
        # time-varying rate_fn is integrated over knots at most this far
        # apart (trapezoid), so a stall-stretched window spanning a burst
        # phase boundary is priced by the rate it actually saw — not by
        # one sample at win_start.  None keeps the single-point product,
        # which is exact for constant rates (the cluster queues).
        self.piecewise_s = piecewise_s
        # registered step rate: rate_fn is piecewise-CONSTANT and
        # step_breaks(a, b) returns its jump points inside (a, b), sorted
        # ascending.  The integral is then an exact left-Riemann sum with
        # knots snapped at the discontinuities — the trapezoid above
        # averages the high/low rates on any sub-interval straddling a
        # jump, mispricing every burst edge (systematic under flash-crowd
        # traces).  Takes precedence over piecewise_s.
        self.step_breaks = step_breaks

    @property
    def backlog(self) -> int:
        return len(self.queue)

    def expected_arrivals(self, win_start: float, a_end: float) -> float:
        """Integral of rate_fn over [win_start, a_end]: the Poisson mean
        for the window.  With `piecewise_s` set, a trapezoid over
        sub-intervals no longer than it; a window over which every knot
        rate is equal — constant-rate traffic — keeps the exact
        rate * window product, bit-identical to the legacy single-point
        path."""
        window = max(a_end - win_start, 0.0)
        if window <= 0.0 or (self.piecewise_s is None
                             and self.step_breaks is None):
            return self.rate_fn(win_start) * window
        if self.step_breaks is not None:
            # exact integral of a registered piecewise-constant rate: each
            # segment between jump points is priced at its left endpoint
            knots = [win_start]
            for b in self.step_breaks(win_start, a_end):
                b = float(b)
                if win_start < b < a_end:
                    knots.append(b)
            knots.append(a_end)
            return float(sum(float(self.rate_fn(lo)) * (hi - lo)
                             for lo, hi in zip(knots, knots[1:])))
        seg = max(float(self.piecewise_s), 1e-12)
        n = max(int(np.ceil(window / seg)), 1)
        knots = np.linspace(win_start, a_end, n + 1)
        rates = np.asarray([float(self.rate_fn(float(t))) for t in knots],
                           np.float64)
        if np.all(rates == rates[0]):
            return float(rates[0]) * window
        trapezoid = getattr(np, "trapezoid", None) or np.trapz
        return float(trapezoid(rates, knots))

    def step(self, win_start: float, t_end: float, capacity: int,
             arrival_end: Optional[float] = None) -> tuple:
        """Arrivals over [win_start, t_end] — the window spans any
        launch/kill or compile stall, because the outside world does not
        pause while instances restart — then overflow, then serve up to
        `capacity` oldest requests.  Returns (served timestamps,
        end-to-end latencies).

        `arrival_end` clips the arrival window (a draining job stops
        receiving requests at its departure time even while it is still
        serving down its backlog); service still completes at `t_end`."""
        a_end = t_end if arrival_end is None else min(t_end, arrival_end)
        window = max(a_end - win_start, 0.0)
        n_arr = int(self.rng.poisson(
            self.expected_arrivals(win_start, a_end)))
        self.submitted += n_arr
        if n_arr:
            self.queue.extend(np.sort(
                win_start + self.rng.random(n_arr) * window))
        if len(self.queue) > self.max_queue:
            drop = len(self.queue) - self.max_queue
            self.rejected += drop
            self.queue = self.queue[drop:]
        served, self.queue = self.queue[:capacity], self.queue[capacity:]
        return served, [t_end - ts for ts in served]


class ServingEngine:
    def __init__(self, executor, slo_s: float, *,
                 window: int = 200,
                 instance_launch_s: float = 2.0,
                 instance_kill_s: float = 0.3,
                 slo_schedule: Optional[Callable[[float], float]] = None):
        self.executor = executor
        self.base_slo = slo_s
        self.window = TailLatencyWindow(window=window)
        self.acc = RunAccumulator()
        self.instance_launch_s = instance_launch_s
        self.instance_kill_s = instance_kill_s
        self.slo_schedule = slo_schedule
        self.reconfig_time = 0.0

    def current_slo(self) -> float:
        if self.slo_schedule is not None:
            return self.slo_schedule(self.acc.total_time)
        return self.base_slo

    def _charge_reconfig(self, prev: Action, act: Action) -> None:
        """Shared stall accounting: MTL moves stall the service; any knob
        change invalidates the tail window (the paper 'processes a certain
        number of batches and measures their tail latency' per point)."""
        cost = reconfig_stall(prev, act, self.instance_launch_s,
                              self.instance_kill_s)
        if cost:
            self.acc.total_time += cost
            self.reconfig_time += cost
        if (act.bs, act.mtl) != (prev.bs, prev.mtl):
            self.window.reset()

    def _charge_compile(self, res: dict) -> float:
        """AOT compile time reported by the executor is an engine stall."""
        comp = res.get("compile_time", 0.0)
        if comp:
            self.acc.total_time += comp
            self.acc.compile_stall_s += comp
        return comp

    def run(self, controller, *, max_steps: int = 2000,
            sim_time_limit: Optional[float] = None) -> RunAccumulator:
        prev = Action(bs=1, mtl=1)
        for _ in range(max_steps):
            slo = self.current_slo()
            if hasattr(controller, "set_slo"):
                controller.set_slo(slo)
            act = controller.action()
            self._charge_reconfig(prev, act)
            res = self.executor.run_step(act.bs, act.mtl)
            self._charge_compile(res)
            self.window.add_many(res["request_latencies"])
            self.acc.record_step(
                items=res["items"], step_time=res["step_time"],
                power_w=res["power_w"],
                request_latencies=res["request_latencies"], slo=slo)
            self.acc.trace.append(
                (self.acc.total_time, act.bs, act.mtl, self.window.p95,
                 res["throughput"], slo))
            controller.observe(self.window.p95, res)
            prev = act
            if sim_time_limit and self.acc.total_time >= sim_time_limit:
                break
        return self.acc


class OpenLoopEngine(ServingEngine):
    """Open-loop serving: requests arrive via a (bursty) Poisson process and
    queue; per-request latency = queueing wait + batch service time.  This is
    the regime of the paper's §3.2 note that "some inference workloads arrive
    in a burst and not uniformly" — controllers must absorb bursts without
    violating the SLO for long.
    """

    def __init__(self, executor, slo_s: float, *, arrival_rate: float,
                 burst_factor: float = 1.0, burst_period_s: float = 30.0,
                 seed: int = 0, max_queue: int = 100_000, **kw):
        super().__init__(executor, slo_s, **kw)
        self.arrival_rate = arrival_rate
        self.burst_factor = burst_factor
        self.burst_period_s = burst_period_s
        # the burst rate is piecewise-constant with known jump points, so
        # it registers them for the exact left-Riemann integral; constant
        # rates keep the exact single-point product
        self.oq = OpenLoopQueue(
            self._rate, max_queue=max_queue, seed=seed,
            step_breaks=(self._burst_breaks if burst_factor > 1.0
                         else None))

    # backwards-compatible views over the shared queue helper
    @property
    def queue(self) -> list:
        return self.oq.queue

    @property
    def dropped(self) -> int:
        return self.oq.rejected

    @property
    def max_queue(self) -> int:
        return self.oq.max_queue

    def _rate(self, t: float) -> float:
        if self.burst_factor <= 1.0:
            return self.arrival_rate
        phase = (t % self.burst_period_s) / self.burst_period_s
        return self.arrival_rate * (self.burst_factor if phase < 0.3 else 1.0)

    def _burst_breaks(self, a: float, b: float) -> list:
        """Jump points of _rate inside (a, b): m*period (burst on) and
        (m + 0.3)*period (burst off) for every period m the window spans."""
        period = self.burst_period_s
        out = []
        t = np.floor(a / period) * period
        while t <= b:
            for x in (t, t + 0.3 * period):
                if a < x < b:
                    out.append(x)
            t += period
        return out

    def run(self, controller, *, max_steps: int = 2000,
            sim_time_limit=None) -> RunAccumulator:
        prev = Action(bs=1, mtl=1)
        for _ in range(max_steps):
            slo = self.current_slo()
            if hasattr(controller, "set_slo"):
                controller.set_slo(slo)
            act = controller.action()
            win_start = self.acc.total_time   # arrivals span any stall too
            self._charge_reconfig(prev, act)
            res = self.executor.run_step(act.bs, act.mtl)
            self._charge_compile(res)
            t1 = self.acc.total_time + res["step_time"]
            served_ts, lats = self.oq.step(win_start, t1,
                                           act.bs * act.mtl)
            self.acc.record_step(
                items=len(served_ts), step_time=res["step_time"],
                power_w=res["power_w"], request_latencies=lats, slo=slo)
            # The controller observes SERVICE latency (as in the paper's
            # closed-loop measurement): feeding it queue-inclusive latency
            # would make the batch scaler shrink the batch exactly when the
            # backlog demands growing it (a death spiral).  End-to-end
            # (queue + service) latencies still go to the accumulator above.
            self.window.add_many(res["request_latencies"])
            self.acc.trace.append(
                (t1, act.bs, act.mtl, self.window.p95,
                 len(served_ts) / res["step_time"], slo))
            controller.observe(self.window.p95, res)
            prev = act
            if sim_time_limit and self.acc.total_time >= sim_time_limit:
                break
        return self.acc
