"""Spatial partition planner — the MPS/MIG-style third knob.

The paper's Multi-Tenancy knob time-shares the whole GPU among co-located
instances; D-STACK and the multi-tenant GPU inference survey show that
*spatial* partitioning — MPS compute percentages, MIG slices — is the other
half of the design space and often dominates time-slicing for small DNNs.
This module is the planning layer for that axis:

  * `TenantSlice` — one tenant's grant: a compute fraction, a memory
    fraction, the exact slowdown factor its kernels pay (`inv_share`,
    kept separately so uniform 1/k grants price BIT-IDENTICALLY to the
    paper's MTL curves — see `device_model.part_latency_grid`), and an
    isolation degree (0 = MPS shared memory paths, 1 = MIG/submesh
    hardware isolation).
  * `PartitionPlan` — the per-device plan: one slice per resident tenant,
    with backend-specific legality (`validate`): shares and memory
    fractions must sum to <= 1, MIG shares must sit on the discrete
    profile grid, submesh shares must correspond to feasible submesh
    splits.  `tenancy.TenancyPlan` — today's TPU submesh planner — maps
    onto the `submesh` backend via `from_tenancy`: the pod-slice split is
    just the discrete, fully-isolated instance of the same abstraction.
  * share ladders (`share_ladder`) — the discrete rungs a HybridScaler's
    third coordinate-descent axis may request, and `snap` — the largest
    legal rung at or below a requested fraction.

Kinds:
  "mps"     — continuous shares in (0, 1]; cross-tenant interference term
              calibrated so uniform shares reproduce MTL time-slicing.
  "mig"     — discrete shares from `MIG_PROFILES` (the A100/H100 1g/2g/
              3g/4g/7g compute grid with 1/8..1 memory slices); hardware
              isolation suppresses cross-tenant interference.
  "submesh" — TPU pod-slice splits (disjoint chips): shares from
              `tenancy.plan`, full isolation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.serving import tenancy

# A100/H100-style MIG grid: (compute fraction, memory fraction) per
# profile, out of 7 compute slices and 8 memory slices.
MIG_COMPUTE_SLICES = 7
MIG_MEMORY_SLICES = 8
MIG_PROFILES = (          # (compute_frac, mem_frac) — 1g.10gb .. 7g.80gb
    (1 / 7, 1 / 8),
    (2 / 7, 2 / 8),
    (3 / 7, 4 / 8),
    (4 / 7, 4 / 8),
    (7 / 7, 8 / 8),
)

# MPS rungs: active-thread-percentage style eighths of the device.
MPS_LADDER = tuple((k + 1) / 8 for k in range(8))

SHARE_TOL = 1e-9          # float-sum slack for legality checks


@dataclasses.dataclass(frozen=True)
class TenantSlice:
    """One tenant's spatial grant on a device."""

    share: float                       # compute fraction in (0, 1]
    mem_fraction: float = None         # memory fraction (defaults to share)
    inv_share: float = None            # exact slowdown factor (1/share);
    #                                    pass the integer k for uniform 1/k
    #                                    grants so pricing is bit-identical
    #                                    to the MTL curves at equal share
    tenants: int = 1                   # co-resident tenants on the device
    isolation: float = 0.0             # 0 = MPS shared, 1 = MIG/submesh

    def __post_init__(self):
        if self.mem_fraction is None:
            object.__setattr__(self, "mem_fraction", self.share)
        if self.inv_share is None:
            object.__setattr__(self, "inv_share", 1.0 / self.share)

    def slowdown(self, mtl: int = 1) -> float:
        """Latency inflation factor of this slice vs sole ownership of the
        whole device at mtl=1 (GPU-side term of the partition pricing)."""
        from repro.serving.device_model import EPS_MT
        x = (mtl - 1.0) + (1.0 - self.isolation) * (self.tenants - 1.0)
        return self.inv_share * mtl * (1.0 + EPS_MT * x)

    def proxy_slowdown(self) -> float:
        """Wall-clock inflation for the RealExecutor capped-batch proxy.
        The measured wall already contains the instance-stacked (vmap)
        compute, so only the share slowdown and the cross-tenant
        interference are applied on top — never the x mtl factor."""
        from repro.serving.device_model import EPS_MT
        x = (1.0 - self.isolation) * (self.tenants - 1.0)
        return self.inv_share * (1.0 + EPS_MT * x)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Per-device spatial plan: one slice per resident tenant."""

    kind: str                          # "mps" | "mig" | "submesh"
    slices: tuple                      # TenantSlice per tenant
    mesh_shape: Optional[tuple] = None  # submesh backend: the pod slice

    @property
    def tenants(self) -> int:
        return len(self.slices)

    @property
    def total_share(self) -> float:
        return sum(s.share for s in self.slices)

    @property
    def headroom(self) -> float:
        return max(0.0, 1.0 - self.total_share)

    def validate(self) -> list:
        """Legality violations (empty list = legal plan)."""
        errs = []
        if self.kind not in ("mps", "mig", "submesh"):
            errs.append(f"unknown kind {self.kind!r}")
            return errs
        for i, s in enumerate(self.slices):
            if not 0.0 < s.share <= 1.0:
                errs.append(f"tenant {i}: share {s.share} outside (0, 1]")
            if not 0.0 < s.mem_fraction <= 1.0:
                errs.append(f"tenant {i}: mem {s.mem_fraction} outside (0, 1]")
        if self.total_share > 1.0 + SHARE_TOL:
            errs.append(f"shares sum to {self.total_share:.4f} > 1")
        mem_total = sum(s.mem_fraction for s in self.slices)
        if mem_total > 1.0 + SHARE_TOL:
            errs.append(f"memory slices sum to {mem_total:.4f} > 1")
        if self.kind == "mig":
            for i, s in enumerate(self.slices):
                if not any(abs(s.share - c) <= SHARE_TOL
                           and s.mem_fraction >= m - SHARE_TOL
                           for c, m in MIG_PROFILES):
                    errs.append(f"tenant {i}: share {s.share:.4f} not on "
                                f"the MIG profile grid")
        if self.kind == "submesh":
            if self.mesh_shape is None:
                errs.append("submesh plan needs a mesh_shape")
            else:
                total = self.mesh_shape[-2] * self.mesh_shape[-1]
                for i, s in enumerate(self.slices):
                    chips = s.share * total
                    if abs(chips - round(chips)) > 1e-6 or round(chips) < 1:
                        errs.append(f"tenant {i}: share {s.share:.4f} is "
                                    f"not a whole-chip submesh of "
                                    f"{self.mesh_shape}")
        return errs

    def fits_memory(self, dev, profiles: Sequence, bs_mtl: Sequence) -> bool:
        """Every tenant's model + activations fit inside its memory slice
        (`profiles[i]` / `bs_mtl[i] = (bs, mtl)` per tenant)."""
        from repro.serving import device_model as dm
        for s, prof, (bs, mtl) in zip(self.slices, profiles, bs_mtl):
            sliced = dataclasses.replace(
                dev, hbm_bytes=dev.hbm_bytes * s.mem_fraction)
            if not dm.fits_memory(sliced, prof, bs, mtl):
                return False
        return True


def _isolation(kind: str) -> float:
    return 0.0 if kind == "mps" else 1.0


def uniform_plan(tenants: int, kind: str = "mps",
                 mesh_shape: Optional[tuple] = None) -> PartitionPlan:
    """Equal 1/k grants.  `inv_share` carries the exact integer factor so
    uniform partitions price bit-identically to MTL time-slicing."""
    if kind == "submesh":
        p = tenancy.plan_at_least(mesh_shape, tenants)
        if p is None:
            raise ValueError(f"{tenants} tenants do not fit {mesh_shape}")
        return from_tenancy(p, mesh_shape=mesh_shape)
    sl = TenantSlice(share=1.0 / tenants, mem_fraction=1.0 / tenants,
                     inv_share=float(tenants), tenants=tenants,
                     isolation=_isolation(kind))
    return PartitionPlan(kind=kind, slices=(sl,) * tenants)


def mps_plan(shares: Sequence[float],
             mem_fractions: Optional[Sequence[float]] = None) -> PartitionPlan:
    """Continuous (heterogeneous) MPS shares, one tenant each."""
    shares = tuple(float(s) for s in shares)
    mems = tuple(mem_fractions) if mem_fractions is not None else shares
    k = len(shares)
    slices = tuple(TenantSlice(share=s, mem_fraction=m, tenants=k,
                               isolation=0.0)
                   for s, m in zip(shares, mems))
    return PartitionPlan(kind="mps", slices=slices)


def mig_plan(shares: Sequence[float]) -> PartitionPlan:
    """Discrete MIG plan: each requested share snaps DOWN to the largest
    profile at or below it (a request below the smallest profile gets the
    smallest).  Raises on an illegal combination."""
    k = len(shares)
    slices = []
    for s in shares:
        c, m = MIG_PROFILES[0]
        for pc, pm in MIG_PROFILES:
            if pc <= s + SHARE_TOL:
                c, m = pc, pm
        slices.append(TenantSlice(share=c, mem_fraction=m, tenants=k,
                                  isolation=1.0))
    plan = PartitionPlan(kind="mig", slices=tuple(slices))
    errs = plan.validate()
    if errs:
        raise ValueError("; ".join(errs))
    return plan


def from_tenancy(p: tenancy.TenancyPlan,
                 mesh_shape: Optional[tuple] = None) -> PartitionPlan:
    """Wrap a TPU submesh split as the discrete backend of this
    abstraction: `p.replicas` equal fully-isolated slices of `p.share`."""
    mesh = mesh_shape if mesh_shape is not None else p.total
    sl = TenantSlice(share=p.share, mem_fraction=p.share,
                     tenants=p.replicas, isolation=1.0)
    return PartitionPlan(kind="submesh", slices=(sl,) * p.replicas,
                         mesh_shape=mesh)


def share_ladder(kind: str = "mps",
                 mesh_shape: Optional[tuple] = None) -> tuple:
    """The discrete rungs the scaler's third axis may request, ascending."""
    if kind == "mps":
        return MPS_LADDER
    if kind == "mig":
        return tuple(sorted({c for c, _ in MIG_PROFILES}))
    if kind == "submesh":
        total = mesh_shape[-2] * mesh_shape[-1]
        rungs = set()
        for k in range(1, total + 1):
            p = tenancy.plan(mesh_shape, k)
            if p is not None:
                rungs.add(p.share)
        return tuple(sorted(rungs))
    raise ValueError(f"unknown kind {kind!r}")


def packing_key(policy: Optional[str], *, occupied: bool,
                fill: float) -> tuple:
    """Device-ordering key fragment for the consolidate-vs-spread packing
    objective (ClusterEngine's `power_policy`).

    "pack" prefers already-powered devices, fullest first — admissions
    consolidate onto few devices so the rest stay power-gated (zero idle
    floor) at trough.  "spread" prefers empty devices, emptiest first —
    tail latency over joules at peak.  None returns the empty tuple, so
    legacy score tuples are byte-identical when no policy is set."""
    if policy == "pack":
        return (0 if occupied else 1, -fill)
    if policy == "spread":
        return (1 if occupied else 0, fill)
    return ()


def mig_step_down(share: float) -> Optional[float]:
    """The largest MIG compute fraction STRICTLY below `share`, or None
    when the share already sits at (or below) the smallest profile —
    the unit move of the admission shrink loop."""
    best = None
    for c, _ in MIG_PROFILES:
        if c < share - SHARE_TOL and (best is None or c > best):
            best = c
    return best


def snap(kind: str, share: float,
         mesh_shape: Optional[tuple] = None) -> float:
    """Largest legal rung at or below `share` (the smallest rung when the
    request sits below every rung)."""
    ladder = share_ladder(kind, mesh_shape)
    best = ladder[0]
    for r in ladder:
        if r <= share + SHARE_TOL:
            best = r
    return best


def split_for_instances(sl: TenantSlice, mtl: int,
                        kind: str = "mps") -> tuple:
    """Sub-slice one tenant's grant across its own `mtl` instances.

    MPS sub-slices are uniform; a MIG grant splits into the legal
    profiles that tile it, which is generally HETEROGENEOUS — e.g. a 7/7
    grant across 3 instances becomes (3g, 2g, 2g).  The synchronized
    batch step is gated by the slowest (smallest) instance, which is why
    `part_instances_latency` prices the max over sub-slices."""
    if mtl <= 1:
        return (sl,)
    if kind != "mig":
        child = dataclasses.replace(
            sl, share=sl.share / mtl, mem_fraction=sl.mem_fraction / mtl,
            inv_share=sl.inv_share * float(mtl))
        return (child,) * mtl
    # MIG: balanced greedy — the synchronized step is gated by the
    # SMALLEST sub-slice, so each instance takes the largest profile at or
    # below its fair share of the remaining slices (while leaving one
    # slice per remaining instance)
    total = round(sl.share * MIG_COMPUTE_SLICES)
    sizes = sorted((round(c * MIG_COMPUTE_SLICES) for c, _ in MIG_PROFILES),
                   reverse=True)
    out = []
    left, remaining = total, mtl
    for i in range(mtl):
        fair = -(-left // remaining)     # ceil(left / instances left)
        remaining -= 1
        pick = 1
        for sz in sizes:
            if sz <= min(left - remaining, fair):
                pick = sz
                break
        left -= pick
        frac = pick / MIG_COMPUTE_SLICES
        mem = next(m for c, m in MIG_PROFILES
                   if round(c * MIG_COMPUTE_SLICES) == pick)
        out.append(dataclasses.replace(
            sl, share=frac, mem_fraction=min(mem, sl.mem_fraction),
            inv_share=MIG_COMPUTE_SLICES / pick))
    return tuple(out)


def part_instances_latency(dev, prof, bs: int, slices: Sequence[TenantSlice],
                           isolation: Optional[float] = None) -> float:
    """Step latency (s) of one synchronized batch across possibly
    heterogeneous per-instance sub-slices: the slowest slice gates."""
    from repro.serving import device_model as dm
    worst = 0.0
    for s in slices:
        iso = s.isolation if isolation is None else isolation
        worst = max(worst, dm.part_latency(
            dev, prof, bs, 1, inv_share=s.inv_share,
            tenants=s.tenants, isolation=iso))
    return worst
