"""Trace recording + counterfactual replay (the capacity-planning tool).

`ClusterEngine(record="name")` captures a run: the construction inputs
(jobs, churn tenancies, fleet, every engine knob), the
admission/migration/resize/drain event stream (`churn_log`), and the
achieved aggregate, all persisted into the profile store's ``traces``
section.  Because the simulator is deterministic given those inputs
(frozen dataclasses, fixed seeds, and a JSON round-trip that preserves
every float bit-exactly), `replay_run(trace)` under the unchanged policy
reproduces the original `report()` EXACTLY — the determinism contract the
replay test pins — and under a counterfactual policy it answers the
what-if questions a capacity planner asks of a recorded production
window:

    "baseline"       — the recorded policy, verbatim (determinism check)
    "uniform-mtl"    — uniform multi-tenancy instead of the recorded
                       hybrid knobs (paper's MT column, fleet-wide)
    "mig"            — the same tenancies on a MIG-partitioned fleet
                       (discrete hardware slices, resize-not-migrate)
    "fewer-devices"  — the recorded workload on 80% of the fleet

`replay_diff` runs a set of policies and tabulates them against the
recorded aggregate (`launch/report.py --replay` prints it).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving import device_model as dm
from repro.serving.workload import ChurnJob, Job, Preemption

TRACE_SECTION = "traces"
TRACE_VERSION = 1
WHATIF_POLICIES = ("baseline", "uniform-mtl", "mig", "fewer-devices")


def _plain(obj):
    """Recursively coerce to JSON-serializable plain Python (numpy
    scalars included); floats survive a JSON round-trip bit-exactly."""
    if isinstance(obj, dict):
        return {str(k): _plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


# -- serialization ----------------------------------------------------------
def serialize_job(job: Job) -> dict:
    return _plain(dataclasses.asdict(job))


def deserialize_job(d: dict) -> Job:
    d = dict(d)
    po = d.pop("profile_override", None)
    return Job(**d, profile_override=(dm.JobProfile(**po)
                                      if po is not None else None))


def serialize_churn(e: ChurnJob) -> dict:
    return {"job": serialize_job(e.job), "admit_s": e.admit_s,
            "depart_s": e.depart_s, "arrival_rate": e.arrival_rate,
            "traffic": _plain(e.traffic)}


def deserialize_churn(d: dict) -> ChurnJob:
    return ChurnJob(job=deserialize_job(d["job"]),
                    admit_s=d["admit_s"], depart_s=d["depart_s"],
                    arrival_rate=d["arrival_rate"],
                    traffic=d.get("traffic"))


def serialize_spec(spec) -> dict:
    return {"device": _plain(dataclasses.asdict(spec.device)),
            "mesh_shape": (list(spec.mesh_shape)
                           if spec.mesh_shape is not None else None),
            "name": spec.name}


def deserialize_spec(d: dict):
    from repro.serving.cluster import DeviceSpec
    return DeviceSpec(device=dm.Device(**d["device"]),
                      mesh_shape=(tuple(d["mesh_shape"])
                                  if d["mesh_shape"] is not None else None),
                      name=d["name"])


def serialize_init(*, jobs, churn, fleet, meta: Optional[dict] = None,
                   **kwargs) -> dict:
    """Capture `ClusterEngine.__init__`'s inputs verbatim (called before
    any munging).  `kwargs` are the plain engine knobs."""
    return {
        "jobs": [serialize_job(j) for j in (jobs or [])],
        "churn": [serialize_churn(e) for e in (churn or [])],
        "fleet": [serialize_spec(s) for s in fleet],
        "kwargs": _plain(kwargs),
        "meta": _plain(meta or {}),
    }


def trace_from_engine(engine, rep: dict, *, sim_time_limit: float,
                      max_steps: int) -> dict:
    """One recorded run: construction inputs + run parameters + the
    admission/migration/resize/drain event stream + the aggregate."""
    return {
        "version": TRACE_VERSION,
        "init": engine._record_init,
        "run": {"sim_time_limit": float(sim_time_limit),
                "max_steps": int(max_steps)},
        "events": [_plain(list(ev)) for ev in engine.churn_log],
        "event_count": len(engine.event_log),
        "aggregate": _plain(rep["aggregate"]),
    }


# -- store plumbing ---------------------------------------------------------
def save_trace(store, name: str, trace: dict) -> None:
    store.record_trace(name, trace)


def load_trace(store, name: str) -> dict:
    trace = store.get_trace(name)
    if trace is None:
        raise KeyError(f"no recorded trace {name!r} in {store.root}")
    return trace


# -- counterfactual re-drive ------------------------------------------------
def _fewer(fleet: List, frac: float = 0.8) -> List:
    return fleet[:max(1, int(round(frac * len(fleet))))]


def replay_run(trace: dict, *, policy: str = "baseline",
               profile_store=None, vectorized: bool = False) -> dict:
    """Re-drive a recorded run under `policy` (one of WHATIF_POLICIES).

    "baseline" rebuilds the recorded scenario exactly — same entry point,
    same seeds, same fleet — and therefore reproduces the recorded
    `report()` bit for bit.  The counterfactuals perturb exactly one
    axis: the fleet size, the serving mode, or the sharing mechanism."""
    if policy not in WHATIF_POLICIES:
        raise ValueError(f"unknown what-if policy {policy!r}")
    from repro.serving import cluster as cl
    init = trace["init"]
    meta = init.get("meta", {})
    kw = init.get("kwargs", {})
    jobs = [deserialize_job(j) for j in init["jobs"]]
    churn = [deserialize_churn(e) for e in init["churn"]]
    fleet = [deserialize_spec(s) for s in init["fleet"]]
    horizon = trace["run"]["sim_time_limit"]
    seed = kw.get("seed", 0)
    entry = meta.get("entry", "churn")
    mode = meta.get("mode", "hybrid")
    cpolicy = meta.get("policy")
    power_policy = kw.get("power_policy")
    prees = [Preemption(**p) for p in (kw.get("preemptions") or [])] or None
    if policy == "fewer-devices":
        fleet = _fewer(fleet)
        if prees:       # revocations of devices the cut removed are moot
            prees = [p for p in prees if p.device < len(fleet)] or None
    if policy == "uniform-mtl" and entry != "partition":
        mode = "MT"            # uniform multi-tenancy instead of hybrid
    if entry == "scenario":
        if policy == "mig":
            # the same scenario (traffic shapes + revocations travel with
            # the churn entries / preemption kwargs) on MIG-grid discrete
            # slices instead of MPS fractional shares
            return cl.run_partition_cluster(
                "het-mig", trace=churn, fleet=fleet, horizon_s=horizon,
                mode=mode, seed=seed, profile_store=profile_store,
                power_policy=power_policy, preemptions=prees,
                vectorized=vectorized)
        return cl.run_scenario_cluster(
            meta.get("traffic", "steady"), spot=bool(meta.get("spot")),
            power_policy=power_policy, fleet=fleet, horizon_s=horizon,
            max_mtl=int(meta.get("max_mtl", 2)), mode=mode, seed=seed,
            vectorized=vectorized, trace=churn, preemptions=prees)
    if policy == "mig" or entry == "partition":
        part_policy = ("het-mig" if policy == "mig"
                       else ("uniform" if policy == "uniform-mtl"
                             else (cpolicy or "het")))
        entries = churn if churn else [ChurnJob(job=j) for j in jobs]
        return cl.run_partition_cluster(
            part_policy, trace=entries, fleet=fleet, horizon_s=horizon,
            mode=mode, seed=seed, profile_store=profile_store,
            power_policy=power_policy, preemptions=prees,
            vectorized=vectorized)
    if entry == "paper":
        rates = kw.get("arrival_rates") or None
        if rates is not None:
            rates = {int(k): v for k, v in rates.items()}
        return cl.run_paper_cluster(
            mode, jobs=jobs, fleet=fleet, sim_time_limit=horizon,
            arrival_rates=rates, seed=seed, vectorized=vectorized)
    return cl.run_churn_cluster(
        cpolicy or "dynamic", trace=churn, fleet=fleet, horizon_s=horizon,
        mode=mode, seed=seed, profile_store=profile_store,
        power_policy=power_policy, preemptions=prees,
        vectorized=vectorized)


def _brief(agg: dict) -> dict:
    return {
        "devices": int(agg.get("devices", 0)),
        "goodput": float(agg.get("goodput", 0.0)),
        "throughput": float(agg.get("aggregate_throughput", 0.0)),
        "migrations": int(agg.get("migrations", 0)),
        "stall_s": float(agg.get("total_stall_s", 0.0)),
        "truncated": bool(agg.get("truncated", False)),
    }


def replay_diff(trace: dict, *,
                policies: Sequence[str] = WHATIF_POLICIES,
                profile_store=None, vectorized: bool = False) -> List[dict]:
    """Rows for the what-if diff table: the recorded aggregate first,
    then each counterfactual with its goodput relative to the record."""
    base = _brief(trace["aggregate"])
    rows = [{"policy": "recorded", **base, "goodput_vs_recorded": 1.0}]
    denom = base["goodput"]
    for p in policies:
        agg = replay_run(trace, policy=p, profile_store=profile_store,
                         vectorized=vectorized)["aggregate"]
        b = _brief(agg)
        rows.append({"policy": p, **b,
                     "goodput_vs_recorded":
                         (b["goodput"] / denom) if denom else float("nan")})
    return rows


def diff_table(rows: Sequence[dict]) -> str:
    """The replay diff as a markdown table."""
    cols = ("policy", "devices", "goodput", "throughput", "migrations",
            "stall_s", "goodput_vs_recorded", "truncated")
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        cells = []
        for c in cols:
            v = r[c]
            cells.append(f"{v:.2f}" if isinstance(v, float) else str(v))
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines)
