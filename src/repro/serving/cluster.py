"""Cluster-scale multi-job serving (beyond the paper's single-job scope).

The paper evaluates DNNScaler one job at a time on one Tesla P40; the
ROADMAP north-star is a production fleet serving heavy multi-job traffic.
This module adds the missing layer:

  * `DeviceSpec` / `gpu_fleet` describe a heterogeneous fleet: whole GPUs
    (co-resident jobs each get an equal fractional share of the device,
    priced through `Device.share`) and TPU pod slices (each job gets a
    disjoint submesh via `tenancy.plan` — the pod-scale translation of
    co-location; the job's own MTL knob then subdivides its submesh).
  * `place` is a greedy SLO-aware packer: jobs are placed tightest-SLO
    first onto the least-loaded device whose residents (old and new) would
    still meet alpha*SLO at (bs=1, mtl=1) under the post-placement share;
    if no device qualifies, the least-loaded one is used anyway (the report
    surfaces the resulting violation instead of hiding it).
  * `ClusterEngine` runs one controller per job in lockstep simulated
    time: an event loop always advances the job with the smallest local
    clock, so co-scheduled jobs interleave exactly as a shared wall clock
    would order them.  Instance launch/kill stalls land on the owning
    job's timeline AND are accounted globally (`stall_time`).  Open-loop
    mode attaches a Poisson arrival process per job and accounts every
    request exactly once: completed, rejected (queue overflow), or left in
    the backlog at the horizon — the conservation invariant the cluster
    tests pin.
  * `run_paper_cluster` is the first-class scenario: the 30 Table-4 jobs
    on a simulated fleet under {paper DNNScaler, HybridScaler, Clipper,
    pure-B, pure-MT} controller policies.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional, Sequence

from repro.serving import device_model as dm
from repro.serving import tenancy
from repro.serving.engine import Action, OpenLoopQueue, reconfig_stall
from repro.serving.executor import SimExecutor
from repro.serving.metrics import RunAccumulator, TailLatencyWindow

PLACEMENT_ALPHA = 0.85   # the scalers' hysteresis floor (paper alpha)


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One fleet member: a whole accelerator or a TPU pod slice."""

    device: dm.Device
    mesh_shape: Optional[tuple] = None    # None = whole-GPU sharing
    name: str = ""

    def label(self, idx: int) -> str:
        return self.name or f"{self.device.name}/{idx}"


def gpu_fleet(n: int, device: dm.Device = dm.TESLA_P40) -> List[DeviceSpec]:
    return [DeviceSpec(device=device, name=f"{device.name}/{i}")
            for i in range(n)]


def _submesh_for(mesh_shape: tuple, n_jobs: int):
    """Smallest feasible split of the pod slice into >= n_jobs submeshes."""
    return tenancy.plan_at_least(mesh_shape, n_jobs)


def _job_share(spec: DeviceSpec, n_jobs: int) -> float:
    """Fraction of `spec` each of n_jobs co-resident jobs receives."""
    if n_jobs <= 1:
        return 1.0
    if spec.mesh_shape is not None:
        p = _submesh_for(spec.mesh_shape, n_jobs)
        # over-subscribed slice (more jobs than chips): time-multiplexed
        # equal share, mirroring the executor construction
        return p.share if p is not None else 1.0 / n_jobs
    return 1.0 / n_jobs


def _base_latency(spec: DeviceSpec, prof: dm.JobProfile, n_jobs: int) -> float:
    share = _job_share(spec, n_jobs)
    if share <= 0.0:
        return float("inf")
    return dm.batch_latency(spec.device, prof, 1, share=share)


def place(jobs: Sequence, fleet: Sequence[DeviceSpec], *,
          alpha: float = PLACEMENT_ALPHA) -> List[int]:
    """Greedy SLO-aware placement -> device index per job (same order)."""
    profs = [j.profile() for j in jobs]
    assign: List[Optional[int]] = [None] * len(jobs)
    residents: List[List[int]] = [[] for _ in fleet]

    def load(d: int) -> float:
        return sum(profs[j].occupancy for j in residents[d])

    for i in sorted(range(len(jobs)), key=lambda i: jobs[i].slo_s):
        feasible, fallback = [], []
        for d, spec in enumerate(fleet):
            k = len(residents[d]) + 1
            ok = all(_base_latency(spec, profs[j], k)
                     <= alpha * jobs[j].slo_s
                     for j in residents[d] + [i])
            (feasible if ok else fallback).append(d)
        pool = feasible or fallback
        best = min(pool, key=lambda d: (load(d), len(residents[d]), d))
        assign[i] = best
        residents[best].append(i)
    return assign


class _JobState:
    """Per-job serving state inside the cluster (one controller each)."""

    def __init__(self, job, controller, executor, *, window: int,
                 arrival_rate: Optional[float], max_queue: int, seed: int):
        self.job = job
        self.controller = controller
        self.executor = executor
        self.window = TailLatencyWindow(window=window)
        self.acc = RunAccumulator()
        self.clock = 0.0
        self.prev = Action(bs=1, mtl=1)
        self.stall_time = 0.0
        # open-loop mechanics (arrival window, overflow, conservation) are
        # the shared OpenLoopQueue helper — same code path as OpenLoopEngine
        self.oq = (OpenLoopQueue(lambda t, r=arrival_rate: r,
                                 max_queue=max_queue, seed=seed)
                   if arrival_rate is not None else None)
        self.submitted = 0                # closed-loop accounting
        self.completed = 0

    @property
    def queue(self) -> list:
        return self.oq.queue if self.oq is not None else []


class ClusterEngine:
    """Serve many jobs across a fleet, one controller each, in lockstep
    simulated time (see module docstring)."""

    def __init__(self, jobs: Sequence, fleet: Sequence[DeviceSpec], *,
                 controller_factory: Callable, window: int = 200,
                 instance_launch_s: float = 2.0, instance_kill_s: float = 0.3,
                 arrival_rates: Optional[dict] = None, max_queue: int = 10_000,
                 seed: int = 0):
        self.jobs = list(jobs)
        self.fleet = list(fleet)
        self.instance_launch_s = instance_launch_s
        self.instance_kill_s = instance_kill_s
        self.placement = place(self.jobs, self.fleet)
        counts = [self.placement.count(d) for d in range(len(self.fleet))]
        self.stall_time = 0.0
        self.compile_stall_s = 0.0
        self.event_log: list = []         # (global time, job_id) pop order

        self.states: List[_JobState] = []
        arrival_rates = arrival_rates or {}
        for i, job in enumerate(self.jobs):
            spec = self.fleet[self.placement[i]]
            share = _job_share(spec, counts[self.placement[i]])
            prof = job.profile()
            if spec.mesh_shape is not None:
                k = counts[self.placement[i]]
                p = _submesh_for(spec.mesh_shape, k)
                if p is not None:
                    mesh, dev = p.replica_shape, spec.device.share(p.share)
                else:
                    # more jobs than chips: no disjoint submesh exists, so
                    # the slice is time-multiplexed — price an equal 1/k
                    # share (pricing the FULL device here would serve every
                    # over-subscribed job as sole owner and overstate the
                    # aggregate k-fold)
                    mesh, dev = spec.mesh_shape, spec.device.share(1.0 / k)
                mk = lambda s, dev=dev, mesh=mesh, prof=prof: SimExecutor(
                    prof, device=dev, mesh_shape=mesh, seed=s)
            else:
                dev = spec.device.share(share) if share < 1.0 else spec.device
                mk = lambda s, dev=dev, prof=prof: SimExecutor(
                    prof, device=dev, seed=s)
            serving_ex = mk(seed + i)
            profiling_ex = mk(seed + 1000 + i)   # probes stay off the books
            controller = controller_factory(job, profiling_ex)
            self.states.append(_JobState(
                job, controller, serving_ex, window=window,
                arrival_rate=arrival_rates.get(job.job_id),
                max_queue=max_queue, seed=seed + 2000 + i))

    # -- one serving step for one job ---------------------------------------
    def _step(self, st: _JobState) -> None:
        ctrl = st.controller
        if hasattr(ctrl, "set_slo"):
            ctrl.set_slo(st.job.slo_s)
        act = ctrl.action()
        win_start = st.clock        # arrivals keep coming during any stall
        cost = reconfig_stall(st.prev, act, self.instance_launch_s,
                              self.instance_kill_s)
        if cost:
            st.clock += cost
            st.stall_time += cost
            self.stall_time += cost
            st.acc.total_time += cost
        if (act.bs, act.mtl) != (st.prev.bs, st.prev.mtl):
            st.window.reset()            # re-measure the tail at the new knobs

        res = st.executor.run_step(act.bs, act.mtl)
        comp = res.get("compile_time", 0.0)
        if comp:                         # AOT compile = stall, like a launch
            st.clock += comp
            st.acc.total_time += comp
            st.acc.compile_stall_s += comp
            self.compile_stall_s += comp
        t1 = st.clock + res["step_time"]
        slo = st.job.slo_s
        if st.oq is not None:            # open loop: queue + conservation
            # the arrival window spans the launch/kill/compile stall too —
            # the outside world does not pause while instances restart, and
            # served latencies (t1 - ts) must include that wait
            served, lats = st.oq.step(win_start, t1, act.bs * act.mtl)
            st.completed += len(served)
            st.acc.record_step(
                items=len(served), step_time=res["step_time"],
                power_w=res["power_w"], request_latencies=lats, slo=slo)
        else:                            # closed loop: every item completes
            st.submitted += res["items"]
            st.completed += res["items"]
            st.acc.record_step(
                items=res["items"], step_time=res["step_time"],
                power_w=res["power_w"],
                request_latencies=res["request_latencies"], slo=slo)
        # controllers observe SERVICE latency (see OpenLoopEngine's note)
        st.window.add_many(res["request_latencies"])
        st.acc.trace.append((t1, act.bs, act.mtl, st.window.p95,
                             res["throughput"], slo))
        ctrl.observe(st.window.p95, res)
        st.clock = t1
        st.prev = act

    def run(self, *, sim_time_limit: float = 120.0,
            max_steps: int = 500_000) -> dict:
        heap = [(st.clock, i) for i, st in enumerate(self.states)]
        heapq.heapify(heap)
        steps = 0
        while heap and steps < max_steps:
            t, i = heapq.heappop(heap)
            if t >= sim_time_limit:
                continue                 # this job reached the horizon
            self.event_log.append((t, self.states[i].job.job_id))
            self._step(self.states[i])
            heapq.heappush(heap, (self.states[i].clock, i))
            steps += 1
        return self.report()

    def report(self) -> dict:
        counts = [self.placement.count(d) for d in range(len(self.fleet))]
        per_job = []
        for st, d in zip(self.states, self.placement):
            s = st.acc.summary()
            # a job is SLO-feasible on its slice iff even (bs=1, mtl=1)
            # fits under the SLO there; infeasible jobs are served
            # best-effort and flagged, not hidden
            base = _base_latency(self.fleet[d], st.job.profile(), counts[d])
            per_job.append({
                "job_id": st.job.job_id,
                "dnn": f"{st.job.dnn}/{st.job.dataset}",
                "device": self.fleet[d].label(d),
                "approach": getattr(st.controller, "approach",
                                    getattr(st.controller, "name", "?")),
                "bs": st.prev.bs, "mtl": st.prev.mtl,
                "slo_ms": float(st.job.slo_ms),
                "p95_ms": float(s["p95_s"]) * 1e3,
                "tail_p95_ms": float(st.acc.tail_p95()) * 1e3,
                "feasible": bool(base <= st.job.slo_s),
                "slo_attainment": float(s["slo_attainment"]),
                "throughput": float(s["throughput"]),
                "stall_s": float(st.stall_time),
                "submitted": (st.oq.submitted if st.oq is not None
                              else st.submitted),
                "completed": st.completed,
                "rejected": st.oq.rejected if st.oq is not None else 0,
                "backlog": st.oq.backlog if st.oq is not None else 0,
            })
        makespan = float(max((st.clock for st in self.states), default=0.0))
        completed = sum(st.completed for st in self.states)
        feasible = [r for r in per_job if r["feasible"]]
        return {
            "per_job": per_job,
            "aggregate": {
                "jobs": len(self.states),
                "devices": len(self.fleet),
                "makespan_s": makespan,
                "aggregate_throughput":
                    completed / makespan if makespan else 0.0,
                "total_stall_s": float(self.stall_time),
                "compile_stall_s": float(self.compile_stall_s),
                "min_attainment":
                    min((r["slo_attainment"] for r in per_job), default=1.0),
                "feasible_jobs": len(feasible),
                "jobs_meeting_slo":
                    int(sum(r["tail_p95_ms"] <= r["slo_ms"]
                            for r in feasible)),
            },
        }


# ---------------------------------------------------------------------------
# The first-class scenario: the paper's 30 jobs as one cluster workload.
# ---------------------------------------------------------------------------
def paper_controller_factory(mode: str = "auto", *, max_mtl: int = 10,
                             library_jobs: int = 8):
    """Factory of per-job controllers for `ClusterEngine`.

    mode: "auto" (the paper's B-or-MT pick), "hybrid", "B", "MT" — all via
    DNNScalerController — or "clipper".  The matrix-completion estimator is
    seeded with a shared library of 'historically profiled' jobs, exactly
    like the single-job launchers do.
    """
    from repro.core.controller import ClipperController, DNNScalerController
    from repro.core.matrix_completion import LatencyEstimator
    from repro.serving.workload import PAPER_JOBS

    mtls = list(range(1, max_mtl + 1))
    library = []
    for j in PAPER_JOBS[:library_jobs]:
        # whole MTL curve priced in one vectorized call (mt_latency_grid)
        curve = dm.mt_latency_curve(dm.TESLA_P40, j.profile(), 1, mtls)
        library.append((j.job_id, dict(zip(mtls, curve))))

    def make(job, executor):
        if mode == "clipper":
            return ClipperController(job.slo_s)
        # on a TPU submesh the MTL knob cannot exceed the replica's chip
        # count — an estimate past it would send the scaler into the
        # infeasible (inf-latency) region and poison the job clock
        cap = max_mtl
        if getattr(executor, "mesh_shape", None) is not None:
            cap = max(1, min(cap, tenancy.max_tenancy(executor.mesh_shape)))
        est = LatencyEstimator(max_mtl=cap)
        for jid, row in library:
            if jid != job.job_id:    # never leak the served job's own
                est.add_library_row(row)   # ground-truth curve (held-out,
                                           # like build_library's exclude_id)
        return DNNScalerController(executor, job.slo_s, estimator=est,
                                   max_mtl=cap, mode=mode)

    return make


def run_paper_cluster(mode: str = "auto", *, jobs: Optional[Sequence] = None,
                      fleet: Optional[Sequence[DeviceSpec]] = None,
                      n_devices: int = 12, sim_time_limit: float = 90.0,
                      arrival_rates: Optional[dict] = None,
                      seed: int = 0) -> dict:
    """Serve the Table-4 jobs on a simulated fleet under one policy."""
    from repro.serving.workload import PAPER_JOBS
    jobs = list(jobs) if jobs is not None else list(PAPER_JOBS)
    fleet = list(fleet) if fleet is not None else gpu_fleet(n_devices)
    eng = ClusterEngine(jobs, fleet,
                        controller_factory=paper_controller_factory(mode),
                        arrival_rates=arrival_rates, seed=seed)
    rep = eng.run(sim_time_limit=sim_time_limit)
    rep["aggregate"]["mode"] = mode
    return rep
