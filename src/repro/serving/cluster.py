"""Cluster-scale multi-job serving (beyond the paper's single-job scope).

The paper evaluates DNNScaler one job at a time on one Tesla P40; the
ROADMAP north-star is a production fleet serving heavy multi-job traffic.
This module adds the missing layer:

  * `DeviceSpec` / `gpu_fleet` describe a heterogeneous fleet: whole GPUs
    (co-resident jobs each get an equal fractional share of the device,
    priced through `Device.share`) and TPU pod slices (each job gets a
    disjoint submesh via `tenancy.plan` — the pod-scale translation of
    co-location; the job's own MTL knob then subdivides its submesh).
  * `place` is a greedy SLO-aware packer: jobs are placed tightest-SLO
    first onto the least-loaded device whose residents (old and new) would
    still meet alpha*SLO at (bs=1, mtl=1) under the post-placement share;
    if no device qualifies, the least-loaded one is used anyway (the report
    surfaces the resulting violation instead of hiding it).
  * `ClusterEngine` runs one controller per job in lockstep simulated
    time: an event loop always advances the job with the smallest local
    clock, so co-scheduled jobs interleave exactly as a shared wall clock
    would order them.  Instance launch/kill stalls land on the owning
    job's timeline AND are accounted globally (`stall_time`).  Open-loop
    mode attaches a Poisson arrival process per job and accounts every
    request exactly once: completed, rejected (queue overflow), or left in
    the backlog at the horizon — the conservation invariant the cluster
    tests pin.
  * Online churn (`churn=` trace of `workload.ChurnJob`s): jobs admit and
    drain mid-run.  Admission re-runs the SLO-aware packer incrementally —
    and, when `anticipate=True`, scores candidate devices by each job's
    PREDICTED HYBRID STEADY STATE (the throughput-optimal (bs, mtl) under
    alpha*SLO on the post-admission share, from the shared `SurfaceLibrary`
    completion when it has history, else the analytic latency grid) rather
    than the (bs=1, mtl=1) point.  Any job whose device share changes pays
    an explicit migration cost: its current instances are killed and
    relaunched at the new share (charged to its own clock AND to global
    `stall_time`/`migration_stall_s`), plus a checkpoint-transfer term for
    TPU submesh moves (params must stream to the new submesh over DCN).
    When no device can host a new job, the packer attempts ONE relocation:
    moving the cheapest-to-migrate resident elsewhere to open room
    (migration-aware re-placement).  Draining frees share; the departing
    job stops receiving arrivals at its departure time but serves down its
    backlog first, so request conservation holds across every
    reconfiguration.  `static_union=True` disables all of this (placement
    fixed over the union of every tenancy that ever appears) — the
    baseline the churn example compares against.
  * Spatial partitioning (`partition="mps"|"mig"` — serving/partition.py):
    tenancies are placed into explicit compute/memory SLICES of a device
    instead of uniform time-shares.  Each job holds a granted share
    (heterogeneous across co-residents), priced through
    `device_model.part_latency_grid` — calibrated so uniform 1/k MPS
    grants reproduce the paper's MTL curves bit-identically.  The
    HybridScaler's third axis requests shares from a discrete ladder; the
    engine mediates grants against device headroom (`note_share_cap` /
    `note_share_grant`).  Churn re-placement RESIZES partitions (MPS
    set-percentage / MIG reconfigure, contexts stay alive — cheap,
    store-calibrated under a `resize|` key) instead of paying the
    kill+relaunch migration round; `partition_uniform=True` is the
    uniform-MTL baseline under the same pricing model, where every share
    change is still a full migration.  `run_partition_cluster` compares
    the two on a mixed small/large-DNN trace.
  * Lockstep fairness (`stall_cap_s`): a wall-clock compile or migration
    stall charged to a sub-millisecond simulated job clock starves that
    job in the lockstep loop until every peer catches up.  The cap bounds
    the clock charge per event (excess recorded in `stall_capped_s`,
    divergence tracked in `max_clock_skew_s`), keeping clock skew bounded
    in real-executor churn.
  * `run_paper_cluster` serves the 30 Table-4 jobs statically;
    `run_churn_cluster` is the churn scenario under {static-union, dynamic
    re-placement, dynamic + shared surface} policies.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.perf import autotune
from repro.perf import cost_model as cost_model_mod
from repro.serving import device_model as dm
from repro.serving import partition as pt
from repro.serving import tenancy
from repro.serving.engine import Action, OpenLoopQueue, reconfig_stall
from repro.serving.executor import SimExecutor
from repro.serving.metrics import RunAccumulator, TailLatencyWindow
from repro.serving.sim_state import SimState
from repro.serving.workload import ChurnJob, Preemption, make_rate_fn

PLACEMENT_ALPHA = 0.85   # the scalers' hysteresis floor (paper alpha)
CKPT_TRANSFER_BPS = dm.DCN_BPS  # DCN bandwidth for TPU submesh checkpoint
#                          moves — the same 8 GB/s wire the KV-transfer
#                          fabric's DCN link class prices (device_model.DCN)
PART_RESIZE_S = 0.25     # modeling default for one partition resize (MPS
#                          set-percentage / MIG reconfigure): the contexts
#                          keep running — no kill+relaunch round — so it is
#                          an order of magnitude below the migration cost.
#                          Real executors calibrate it through the profile
#                          store exactly like migrations (key prefix
#                          "resize|").


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One fleet member: a whole accelerator or a TPU pod slice."""

    device: dm.Device
    mesh_shape: Optional[tuple] = None    # None = whole-GPU sharing
    name: str = ""

    def label(self, idx: int) -> str:
        return self.name or f"{self.device.name}/{idx}"


def gpu_fleet(n: int, device: dm.Device = dm.TESLA_P40) -> List[DeviceSpec]:
    return [DeviceSpec(device=device, name=f"{device.name}/{i}")
            for i in range(n)]


def _submesh_for(mesh_shape: tuple, n_jobs: int):
    """Smallest feasible split of the pod slice into >= n_jobs submeshes."""
    return tenancy.plan_at_least(mesh_shape, n_jobs)


def _job_share(spec: DeviceSpec, n_jobs: int) -> float:
    """Fraction of `spec` each of n_jobs co-resident jobs receives."""
    if n_jobs <= 1:
        return 1.0
    if spec.mesh_shape is not None:
        p = _submesh_for(spec.mesh_shape, n_jobs)
        # over-subscribed slice (more jobs than chips): time-multiplexed
        # equal share, mirroring the executor construction
        return p.share if p is not None else 1.0 / n_jobs
    return 1.0 / n_jobs


def _base_latency(spec: DeviceSpec, prof: dm.JobProfile, n_jobs: int) -> float:
    share = _job_share(spec, n_jobs)
    if share <= 0.0:
        return float("inf")
    return dm.batch_latency(spec.device, prof, 1, share=share)


def place(jobs: Sequence, fleet: Sequence[DeviceSpec], *,
          alpha: float = PLACEMENT_ALPHA) -> List[int]:
    """Greedy SLO-aware placement -> device index per job (same order)."""
    profs = [j.profile() for j in jobs]
    assign: List[Optional[int]] = [None] * len(jobs)
    residents: List[List[int]] = [[] for _ in fleet]

    def load(d: int) -> float:
        return sum(profs[j].occupancy for j in residents[d])

    for i in sorted(range(len(jobs)), key=lambda i: jobs[i].slo_s):
        feasible, fallback = [], []
        for d, spec in enumerate(fleet):
            k = len(residents[d]) + 1
            ok = all(_base_latency(spec, profs[j], k)
                     <= alpha * jobs[j].slo_s
                     for j in residents[d] + [i])
            (feasible if ok else fallback).append(d)
        pool = feasible or fallback
        best = min(pool, key=lambda d: (load(d), len(residents[d]), d))
        assign[i] = best
        residents[best].append(i)
    return assign


def _scalar_prop(field: str, cast) -> property:
    """Array-backed scalar attribute: `_JobState.<field>` reads and writes
    its slot in the engine's `SimState` arrays.  Reads return a plain
    Python scalar, so every downstream arithmetic expression is
    bit-identical to the old object-attribute code."""

    def fget(self):
        return cast(getattr(self.sim, field)[self.idx])

    def fset(self, v):
        getattr(self.sim, field)[self.idx] = v

    return property(fget, fset)


class _JobState:
    """Per-job serving state inside the cluster (one controller each).

    Scalar fields live in the engine's `SimState` structure-of-arrays
    (serving/sim_state.py) so the event loop, admission scan, and skew
    scan can query the whole fleet without walking Python objects; this
    object keeps the unvectorizable parts — controller, executor, tail
    window, accumulator, open-loop queue.  Semantics carried over:
    ``arrival_mark`` is where arrivals were last sampled up to, kept
    separate from the clock so stalls charged between steps (migrations)
    never swallow an arrival window; ``epoch`` bumps whenever the clock
    moves outside a step (the stale-heap guard); ``migration_modeled_s``
    is what the modeling defaults would have charged (vs the calibrated
    stalls actually charged); ``measured_migration_s`` is instrumented
    kill+relaunch wall time."""

    clock = _scalar_prop("clock", float)
    arrival_mark = _scalar_prop("arrival_mark", float)
    admit_s = _scalar_prop("admit_s", float)
    stall_time = _scalar_prop("stall_time", float)
    migration_stall_s = _scalar_prop("migration_stall_s", float)
    migration_modeled_s = _scalar_prop("migration_modeled_s", float)
    measured_migration_s = _scalar_prop("measured_migration_s", float)
    resize_stall_s = _scalar_prop("resize_stall_s", float)
    epoch = _scalar_prop("epoch", int)
    migrations = _scalar_prop("migrations", int)
    resizes = _scalar_prop("resizes", int)       # partition share changes
    submitted = _scalar_prop("submitted", int)   # closed-loop accounting
    completed = _scalar_prop("completed", int)
    active = _scalar_prop("active", bool)

    preempted = _scalar_prop("preempted", int)   # spot forced-kill flag

    def __init__(self, job, controller, executor, *, sim: SimState,
                 window: int, arrival_rate: Optional[float], max_queue: int,
                 seed: int, admit_s: float = 0.0,
                 depart_s: Optional[float] = None,
                 traffic: Optional[dict] = None):
        self.job = job
        self.controller = controller
        self.executor = executor
        self.window = TailLatencyWindow(window=window)
        self.acc = RunAccumulator()
        self.sim = sim
        self.idx = sim.add_job(admit_s=admit_s, depart_s=depart_s)
        self.prev = Action(bs=1, mtl=1)
        self.arrival_rate = arrival_rate
        # open-loop mechanics (arrival window, overflow, conservation) are
        # the shared OpenLoopQueue helper — same code path as
        # OpenLoopEngine.  `traffic` compiles a declarative time-varying
        # spec (diurnal / flash-crowd) into the rate_fn + integration
        # hints; constant rates keep the legacy exact single-point path.
        if arrival_rate is not None:
            rate_fn, piecewise_s, step_breaks = \
                make_rate_fn(arrival_rate, traffic)
            self.oq = OpenLoopQueue(rate_fn, max_queue=max_queue, seed=seed,
                                    piecewise_s=piecewise_s,
                                    step_breaks=step_breaks)
        else:
            self.oq = None

    @property
    def depart_s(self) -> Optional[float]:
        v = self.sim.depart_s[self.idx]
        return None if np.isinf(v) else float(v)

    @property
    def drained_at(self) -> Optional[float]:
        v = self.sim.drained_at[self.idx]
        return None if np.isnan(v) else float(v)

    @drained_at.setter
    def drained_at(self, v: float) -> None:
        self.sim.drained_at[self.idx] = v

    @property
    def queue(self) -> list:
        return self.oq.queue if self.oq is not None else []


class ClusterEngine:
    """Serve many jobs across a fleet, one controller each, in lockstep
    simulated time, with optional online churn (see module docstring)."""

    def __init__(self, jobs: Sequence, fleet: Sequence[DeviceSpec], *,
                 controller_factory: Callable, window: int = 200,
                 instance_launch_s: float = 2.0, instance_kill_s: float = 0.3,
                 arrival_rates: Optional[dict] = None, max_queue: int = 10_000,
                 seed: int = 0, churn: Optional[Sequence[ChurnJob]] = None,
                 static_union: bool = False, anticipate: bool = False,
                 surface_library=None, ckpt_bps: float = CKPT_TRANSFER_BPS,
                 executor_factory: Optional[Callable] = None,
                 profile_store=None, partition: Optional[str] = None,
                 partition_resize_s: float = PART_RESIZE_S,
                 partition_uniform: bool = False,
                 stall_cap_s: Optional[float] = None,
                 power_policy: Optional[str] = None,
                 preemptions: Optional[Sequence] = None,
                 record: Optional[str] = None, record_store=None,
                 record_meta: Optional[dict] = None,
                 retrain_every_rows: int = 8,
                 power_price_fn: Optional[Callable] = None):
        if partition not in (None, "mps", "mig"):
            raise ValueError(f"unknown partition kind {partition!r}")
        if power_policy not in (None, "pack", "spread"):
            raise ValueError(f"unknown power_policy {power_policy!r}")
        # trace recording (serving/replay.py): capture the construction
        # inputs verbatim BEFORE any munging, so `replay_run` can re-drive
        # the identical scenario under counterfactual policies
        self.record = record
        self._record_store = record_store
        if record is not None:
            from repro.serving import replay as _replay
            self._record_init = _replay.serialize_init(
                jobs=jobs, churn=churn, fleet=fleet, window=window,
                instance_launch_s=instance_launch_s,
                instance_kill_s=instance_kill_s,
                arrival_rates=arrival_rates, max_queue=max_queue,
                seed=seed, static_union=static_union, anticipate=anticipate,
                ckpt_bps=ckpt_bps, partition=partition,
                partition_resize_s=partition_resize_s,
                partition_uniform=partition_uniform,
                stall_cap_s=stall_cap_s, power_policy=power_policy,
                preemptions=[dataclasses.asdict(p)
                             for p in (preemptions or [])],
                meta=record_meta)
        self.partition = partition
        self.partition_resize_s = partition_resize_s
        # the uniform-MTL baseline under the SAME spatial pricing model:
        # grants pinned at 1/k (uniform MPS is calibrated bit-identical to
        # MTL time-slicing), every share change charged as a full
        # kill+relaunch migration — isolating exactly what heterogeneous
        # shares + cheap resizes buy
        self.partition_uniform = partition_uniform
        # lockstep fairness: one wall-clock compile/migration stall charged
        # to a sub-millisecond simulated job clock makes that job starve in
        # the lockstep loop until every peer catches up.  `stall_cap_s`
        # bounds the skew: any single event charges at most this much to
        # the job's CLOCK (metrics still record the full cost via
        # `stall_capped_s`), so clock divergence stays bounded.
        self.stall_cap_s = stall_cap_s
        self.stall_capped_s = 0.0
        self.max_clock_skew_s = 0.0
        self.fleet = list(fleet)
        self.controller_factory = controller_factory
        self.window_size = window
        self.instance_launch_s = instance_launch_s
        self.instance_kill_s = instance_kill_s
        self.max_queue = max_queue
        self.seed = seed
        self.static_union = static_union
        self.anticipate = anticipate
        self.surface_library = surface_library
        self.ckpt_bps = ckpt_bps
        self.executor_factory = executor_factory
        self.profile_store = profile_store
        self.store_report: Optional[dict] = None
        self._arrival_rates = arrival_rates or {}
        self.cost_models: dict = {}       # device class -> fitted CostModel
        self._job_feats: dict = {}        # job_id -> ModelFeatures | None
        if profile_store is not None and surface_library is not None:
            # seed the shared surface from prior runs' persisted rows so a
            # recurring architecture in a FRESH process hits the
            # matrix-completion fast path (staleness- and LOO-gated)
            gen = autotune.generation()
            self.store_report = {"loaded": [], "evicted": []}
            for dc in sorted({spec.device.name for spec in fleet}):
                res = profile_store.load_surfaces(
                    surface_library, device_class=dc,
                    autotune_generation=gen)
                self.store_report["loaded"] += res["loaded"]
                self.store_report["evicted"] += res["evicted"]
        if profile_store is not None:
            # learned HLO cost models (perf/cost_model.py): the zero-probe
            # THIRD prediction tier.  Per device class, staleness-evicted
            # at load like surface rows; with an empty cost_model section
            # every prediction path below is byte-identical to before.
            gen = autotune.generation()
            for dc in sorted({spec.device.name for spec in fleet}):
                model = cost_model_mod.load_cost_model(
                    profile_store, dc, autotune_generation=gen)
                if model is not None:
                    self.cost_models[dc] = model
            if self.cost_models:
                if surface_library is not None:
                    # the shared library serves ONE prior: the model of
                    # the fleet's most common device class that has one
                    counts: dict = {}
                    for spec in fleet:
                        counts[spec.device.name] = \
                            counts.get(spec.device.name, 0) + 1
                    primary = max(self.cost_models,
                                  key=lambda dc: counts.get(dc, 0))
                    surface_library.set_cost_model(self.cost_models[primary])
                if self.store_report is not None:
                    self.store_report["cost_model"] = \
                        sorted(self.cost_models)

        # online cost-model retraining: every surface row persisted by a
        # drain or forced kill counts as FRESH training data; once a device
        # class accrues `retrain_every_rows` of them the class model is
        # refit from the store at drain time (train_cost_model itself
        # enforces its minimum-row floor, so a retrain never fires thin)
        self.retrain_every_rows = int(retrain_every_rows)
        self._fresh_rows: dict = {}       # device class -> rows since fit
        self.retrains: dict = {}          # device class -> refit count
        # carbon-aware power pricing: a time-varying $/J signal integrated
        # over each device's powered intervals (plus the dynamic joules
        # accrued while stepping).  None prices nothing and changes nothing.
        self.power_price_fn = power_price_fn
        self._price_ref: Optional[float] = None

        self.stall_time = 0.0
        self.compile_stall_s = 0.0
        self.migration_stall_s = 0.0
        self.migration_modeled_s = 0.0
        self.resizes = 0
        self.resize_stall_s = 0.0
        self.resize_equiv_migration_s = 0.0   # what full migrations would
        #                                       have cost the same events
        self._grant: dict = {}                # state idx -> partition share
        self._timeshared: set = set()         # devices whose tenant count
        #                                       outgrew the legal grid and
        #                                       fell back to 1/k time-
        #                                       multiplexing
        self.admissions = 0
        self.drains = 0
        self.migrations = 0
        self._rebuilds = 0
        # consolidate-vs-spread packing objective ("pack" power-gates empty
        # devices at trough, "spread" trades joules for tail latency)
        self.power_policy = power_policy
        # per-device energy decomposition: dynamic joules accumulate from
        # each step's dynamic_power_w; the idle floor is charged ONCE per
        # powered device over its powered interval (report() closes open
        # intervals at the makespan) — a power-gated device burns nothing
        self._dev_dynamic_j = [0.0] * len(fleet)
        self._dev_powered_s = [0.0] * len(fleet)
        self._dev_on_since: List[Optional[float]] = [None] * len(fleet)
        # closed powered intervals, kept so a time-varying power price can
        # be integrated over them in report(); the dynamic-cost ledger
        # accrues alongside dynamic joules at each step's own clock
        self._dev_intervals: List[list] = [[] for _ in fleet]
        self._dynamic_cost_usd = 0.0
        # spot revocations: (time, kind, Preemption) events consumed in
        # timestamp order interleaved with pending admissions
        self._cap_events: list = []
        for p in (preemptions or []):
            if not 0 <= p.device < len(fleet):
                raise ValueError(f"preemption targets unknown device "
                                 f"{p.device}")
            self._cap_events.append((p.at_s, 0, p))
            if p.restore_s is not None:
                self._cap_events.append((p.restore_s, 1, p))
        self._cap_events.sort(key=lambda e: (e[0], e[1]))
        self._cap_i = 0
        self._revoked: set = set()
        self._kill_at: dict = {}          # state idx -> forced-kill deadline
        self.preemptions_fired = 0
        self.preempt_evacuated = 0
        self.preempt_killed = 0
        self._horizon = float("inf")
        self._heap: Optional[list] = None
        self._steady_cache: dict = {}     # (job_id, d, k) -> analytic grid
        self._feas_cache: dict = {}       # feasibility-snapshot memo
        self.event_log: list = []         # (global time, job_id) pop order
        self.churn_log: list = []         # (time, kind, job_id, device)
        self._sim = SimState()            # per-job scalar state arrays
        self.truncated = False            # last run hit max_steps with
        #                                   simulated work still remaining
        self.steps_run = 0                # serving steps of the last run

        churn = sorted(churn or [], key=lambda e: e.admit_s)
        entries = ([ChurnJob(job=j) for j in jobs]
                   + [e for e in churn if e.admit_s <= 0.0])
        self._pending: List[ChurnJob] = [e for e in churn if e.admit_s > 0.0]
        self._pending_i = 0               # admission cursor (the pending
        #                                   list is consumed in admit order;
        #                                   no O(n^2) pop-from-front)
        if static_union:
            # the baseline: shares fixed over the union of every tenancy
            # that EVER appears — late arrivals hold their slice from t=0
            entries = entries + self._pending
            self._pending = []

        self.jobs = [e.job for e in entries]
        self.states: List[_JobState] = []
        self.placement: List[int] = []
        self.residents: List[List[int]] = [[] for _ in self.fleet]
        assign = self._initial_placement(entries)
        counts = [assign.count(d) for d in range(len(self.fleet))]
        for e, d in zip(entries, assign):
            share = None
            if self.partition is not None:
                share = self._legal_share(1.0 / counts[d])
            i = self._spawn(e, d, counts[d], share=share)
            self.residents[d].append(i)
            self._note_residency(d, self.states[i].admit_s)

    # -- partition helpers ----------------------------------------------------
    def _legal_share(self, share: float) -> float:
        """Snap a share onto the backend's legal grid (MIG profiles; MPS
        is continuous)."""
        if self.partition == "mig":
            return pt.snap("mig", share)
        return share

    def _min_grant(self) -> float:
        return pt.share_ladder(self.partition)[0]

    def _tenant_slice(self, share: float, tenants: int,
                      d: Optional[int] = None) -> pt.TenantSlice:
        # a time-multiplexed (over-subscribed) device shares memory paths
        # like MPS even under a MIG kind — no hardware isolation left
        iso = (1.0 if self.partition == "mig"
               and (d is None or d not in self._timeshared) else 0.0)
        k = round(1.0 / share) if share > 0 else 1
        # uniform 1/k grants carry the exact integer slowdown so partition
        # pricing is bit-identical to the MTL curves at equal share
        inv = float(k) if k >= 1 and share == 1.0 / k else 1.0 / share
        return pt.TenantSlice(share=share, mem_fraction=share,
                              inv_share=inv, tenants=tenants, isolation=iso)

    def _headroom(self, d: int) -> float:
        used = sum(self._grant.get(j, 0.0) for j in self.residents[d])
        return max(0.0, 1.0 - used)

    def partition_plan(self, d: int) -> pt.PartitionPlan:
        """The device's current spatial plan (report / legality checks).
        An over-subscribed device reports as time-multiplexed ("mps") —
        its 1/k grants are no longer spatial slices on the MIG grid."""
        k = len(self.residents[d])
        slices = tuple(self._tenant_slice(self._grant.get(j, 0.0), k, d)
                       for j in self.residents[d])
        kind = self.partition or "mps"
        if d in self._timeshared:
            kind = "mps"
        return pt.PartitionPlan(kind=kind, slices=slices)

    # -- construction helpers -----------------------------------------------
    def _initial_placement(self, entries: Sequence[ChurnJob]) -> List[int]:
        if not self.anticipate and self.power_policy is None:
            return place([e.job for e in entries], self.fleet)
        # anticipation-aware batch packing: same tightest-SLO-first greedy,
        # but each pick scores devices by the predicted steady state (or,
        # under a power_policy alone, by the consolidate/spread key)
        assign: List[Optional[int]] = [None] * len(entries)
        residents: List[List[int]] = [[] for _ in self.fleet]

        def rate_of(e: ChurnJob) -> Optional[float]:
            return (e.arrival_rate if e.arrival_rate is not None
                    else self._arrival_rates.get(e.job.job_id))

        order = sorted(range(len(entries)),
                       key=lambda i: entries[i].job.slo_s)
        for i in order:
            res_info = [[(entries[j].job, rate_of(entries[j])) for j in r]
                        for r in residents]
            d = self._choose_device(entries[i].job, rate_of(entries[i]),
                                    res_info, at=0.0)
            assign[i] = d
            residents[d].append(i)
        return assign

    def _executor_params(self, spec: DeviceSpec, k: int) -> tuple:
        """(device, mesh_shape, share) for one of k co-residents."""
        share = _job_share(spec, k)
        if spec.mesh_shape is not None:
            p = _submesh_for(spec.mesh_shape, k)
            if p is not None:
                return spec.device.share(p.share), p.replica_shape, p.share
            # more jobs than chips: no disjoint submesh exists, so the
            # slice is time-multiplexed — price an equal 1/k share
            # (pricing the FULL device here would serve every
            # over-subscribed job as sole owner and overstate the
            # aggregate k-fold)
            return spec.device.share(1.0 / k), spec.mesh_shape, 1.0 / k
        dev = spec.device.share(share) if share < 1.0 else spec.device
        return dev, None, share

    def _make_executor(self, job, d: int, k: int, seed: int,
                       part_share: Optional[float] = None):
        spec = self.fleet[d]
        if self.partition is not None and part_share is not None:
            # spatial partition: the tenant holds an explicit slice instead
            # of the uniform 1/k time-share
            ts = self._tenant_slice(part_share, k, d)
            if self.executor_factory is not None:
                ex = self.executor_factory(job, spec, part_share, None, seed)
                if hasattr(ex, "set_partition"):
                    ex.set_partition(ts)
            else:
                ex = SimExecutor(job.profile(), device=spec.device,
                                 seed=seed, partition=ts)
            try:
                ex._cluster_share = part_share
            except AttributeError:
                pass
            return ex
        dev, mesh, share = self._executor_params(spec, k)
        if self.executor_factory is not None:
            ex = self.executor_factory(job, spec, share, mesh, seed)
        else:
            prof = job.profile()
            if mesh is not None:
                ex = SimExecutor(prof, device=dev, mesh_shape=mesh,
                                 seed=seed, power_share=share)
            else:
                ex = SimExecutor(prof, device=dev, seed=seed,
                                 power_share=share)
        try:
            ex._cluster_share = share    # lets _reshare skip no-op rebuilds
            ex.power_share = share       # per-slice power attribution
        except AttributeError:           # exotic executors with __slots__
            pass
        return ex

    def _spawn(self, entry: ChurnJob, d: int, k: int,
               share: Optional[float] = None) -> int:
        """Create the per-job state on device d (with k co-residents)."""
        i = len(self.states)
        job = entry.job
        if share is not None:
            self._grant[i] = share
        serving_ex = self._make_executor(job, d, k, self.seed + i,
                                         part_share=share)
        profiling_ex = self._make_executor(job, d, k, self.seed + 1000 + i,
                                           part_share=share)
        if self.cost_models and self.surface_library is not None:
            # the controller's surface seeding keys the library by job_id;
            # features must be registered BEFORE the factory runs so the
            # zero-probe tier can answer its very first predict()
            self.surface_library.register_features(job.job_id,
                                                   self._job_features(job))
        controller = self.controller_factory(job, profiling_ex)
        if share is not None and hasattr(controller, "note_share_grant"):
            controller.note_share_grant(share)
        rate = (entry.arrival_rate if entry.arrival_rate is not None
                else self._arrival_rates.get(job.job_id))
        st = _JobState(job, controller, serving_ex, sim=self._sim,
                       window=self.window_size,
                       arrival_rate=rate, max_queue=self.max_queue,
                       seed=self.seed + 2000 + i, admit_s=entry.admit_s,
                       depart_s=entry.depart_s,
                       traffic=getattr(entry, "traffic", None))
        assert st.idx == i               # state index == SimState slot
        self.states.append(st)
        self.placement.append(d)
        if len(self.jobs) < len(self.states):
            self.jobs.append(job)
        return i

    # -- steady-state anticipation ------------------------------------------
    def _predicted_steady(self, job, d: int, k: int,
                          *, alpha: float = PLACEMENT_ALPHA
                          ) -> Optional[tuple]:
        """(throughput, bs, mtl) at the predicted hybrid steady state of
        `job` on device d with k residents: the throughput-optimal grid
        point whose predicted latency fits under alpha*SLO.  Prefers the
        cross-job SurfaceLibrary completion (re-anchored to this share's
        analytic base point); falls back to the analytic latency grid.
        None when even (bs=1, mtl=1) does not fit."""
        spec = self.fleet[d]
        dev, mesh, share = self._executor_params(spec, k)
        prof = job.profile()
        lib = self.surface_library
        bs_vals = np.asarray(lib.bs_values if lib is not None
                             else (1, 2, 4, 8, 16, 32, 64, 128))
        mtl_vals = np.asarray(lib.mtl_values if lib is not None
                              else tuple(range(1, 11)))
        n_mtl = len(mtl_vals)
        if mesh is not None:
            cap = tenancy.max_tenancy(mesh)
            mtl_vals = mtl_vals[mtl_vals <= max(cap, 1)]
            n_mtl = len(mtl_vals)
        surface = None
        if lib is not None:
            # library tier only: the model tier's surface is absolute (not
            # a normalized shape) and carries no support, so it must not
            # ride the re-anchoring below — it gets its own branch
            pred = lib.predict(job.job_id, allow_model=False)
            if pred is not None:
                est, support = pred
                est, support = est[:, :n_mtl], support[:, :n_mtl]
                # the completed row is a SHAPE (normalized by the job's
                # observed base at its old share); re-anchor it to the
                # candidate share's analytic (1, 1) point.  Unsupported
                # corners are extrapolation — never promise capacity there
                base = _base_latency(spec, prof, k)
                surface = np.where(support, est / est[0, 0] * base,
                                   np.inf)
        if surface is None and self.cost_models:
            # zero-probe tier: a never-before-seen job (no similar probed
            # history) is priced from its MODEL-PREDICTED profile through
            # the same mesh/share-aware laws, instead of the generic
            # profile fallback — placement SCORES only; the scaler's pins
            # and capacity promises still come from probed support
            model = self.cost_models.get(spec.device.name)
            feat = self._job_features(job) if model is not None else None
            if feat is not None:
                ck = ("cm", job.job_id, d, k)
                surface = self._steady_cache.get(ck)
                if surface is None:
                    pprof = model.predict_profile(
                        feat, name=f"{job.dnn}/{job.dataset}")
                    if mesh is not None:
                        ex = SimExecutor(pprof, device=dev, mesh_shape=mesh)
                        surface = ex.price_surface(bs_vals, mtl_vals)
                    else:
                        surface = dm.mt_latency_grid(dev, pprof, bs_vals,
                                                     mtl_vals)
                    self._steady_cache[ck] = surface
        if surface is None:
            # the analytic grid depends only on (job, device, k): memoize —
            # the relocation/rebalance scans re-price the same triple many
            # times per churn event
            ck = (job.job_id, d, k)
            surface = self._steady_cache.get(ck)
            if surface is None:
                if mesh is not None:
                    ex = SimExecutor(prof, device=dev, mesh_shape=mesh)
                    surface = ex.price_surface(bs_vals, mtl_vals)
                else:
                    surface = dm.mt_latency_grid(dev, prof, bs_vals,
                                                 mtl_vals)
                self._steady_cache[ck] = surface
        return dm.best_feasible_point(surface, bs_vals, mtl_vals,
                                      alpha * job.slo_s)

    def _modeled_migration_cost(self, st: _JobState,
                                spec: DeviceSpec) -> float:
        """Modeling-default seconds a share change costs `st`: its
        currently running instances are killed and relaunched at the new
        share in ONE parallel round (unlike the scaler's one-at-a-time MTL
        climbs, a share resize restarts every context at once — the 2.3 s
        default), plus a checkpoint-transfer term for TPU submesh moves —
        each instance's params stream to the new submesh over shared DCN
        bandwidth (8 GB/s default), so that term IS serial in bytes."""
        mtl = max(st.prev.mtl, 1)
        cost = self.instance_kill_s + self.instance_launch_s
        if spec.mesh_shape is not None:
            cost += st.job.profile().param_bytes * mtl / self.ckpt_bps
        return cost

    def _job_features(self, job):
        """Memoized cost-model features for one job (None is memoized too:
        a featureless architecture is asked exactly once)."""
        jid = job.job_id
        if jid not in self._job_feats:
            self._job_feats[jid] = cost_model_mod.features_for_job(job)
        return self._job_feats[jid]

    def _calibration_key(self, st: _JobState, spec: DeviceSpec) -> str:
        return f"{st.job.dnn}/{st.job.dataset}|{spec.device.name}"

    def _migration_cost(self, st: _JobState, spec: DeviceSpec) -> float:
        """Stall seconds charged for one share change of `st`: the profile
        store's calibrated percentile when enough instrumented
        kill+relaunch measurements exist for this (architecture, device
        class) — real executors only; a simulated executor has nothing the
        measurements describe — else the modeling defaults."""
        modeled = self._modeled_migration_cost(st, spec)
        if (self.profile_store is not None
                and hasattr(st.executor, "cache_stats")):
            cal = self.profile_store.migration_cost(
                self._calibration_key(st, spec))
            if cal is not None:
                return cal
        return modeled

    def _disruption_items(self, d: int) -> float:
        """Requests the residents of d would forgo while paying the
        migration stall a new admission forces on them."""
        total = 0.0
        for j in self.residents[d]:
            st = self.states[j]
            total += st.acc.throughput * self._migration_cost(st,
                                                              self.fleet[d])
        return total

    # -- carbon-aware power pricing -----------------------------------------
    def _power_price(self, at: float) -> float:
        return float(self.power_price_fn(max(at, 0.0)))

    def _price_reference(self) -> float:
        """Lazy mean of the price signal over the run horizon (a day when
        the horizon is open) — the flat level the pack deferral compares
        against."""
        if self._price_ref is None:
            end = self._horizon if np.isfinite(self._horizon) else 86_400.0
            ts = np.linspace(0.0, max(float(end), 1.0), 97)
            self._price_ref = float(np.mean([self._power_price(t)
                                             for t in ts]))
        return self._price_ref

    def _effective_power_policy(self, at: float) -> Optional[str]:
        """The packing objective in force at time `at`.  Under a
        time-varying power price, a `pack` fleet DEFERS consolidation
        while energy is cheap (price at or below half the signal's mean):
        power-gating an empty device saves little off-peak while the
        migrations it forces cost the same, so placements fall back to
        the neutral key until the price recovers.  Flat pricing
        (`power_price_fn=None`) and `spread` are untouched."""
        if (self.power_price_fn is not None and self.power_policy == "pack"
                and self._power_price(at) <= 0.5 * self._price_reference()):
            return None
        return self.power_policy

    def _choose_device(self, job, rate: Optional[float],
                       res_info: List[List[tuple]],
                       *, at: float, with_disruption: bool = False) -> int:
        """Incremental SLO-aware pick for one job over current residents
        (`res_info[d]` = [(job, arrival_rate or None), ...]).

        Feasibility is the same alpha*SLO check as `place`; among feasible
        devices, anticipation mode maximizes the cluster-level gain: the
        new job's predicted steady-state throughput — CAPPED at its
        arrival rate, a job never serves demand it doesn't have — over
        the remaining horizon, net of every co-resident's demand-capped
        steady-state loss from the share shrink and of the one-off
        migration disruption."""
        prof = job.profile()
        feasible, fallback = [], []
        for d, spec in enumerate(self.fleet):
            if d in self._revoked:
                continue                 # spot capacity gone: never place
            k = len(res_info[d]) + 1
            ok = (_base_latency(spec, prof, k) <= PLACEMENT_ALPHA * job.slo_s
                  and all(_base_latency(spec, rj.profile(), k)
                          <= PLACEMENT_ALPHA * rj.slo_s
                          for rj, _ in res_info[d]))
            (feasible if ok else fallback).append(d)
        pool = feasible or fallback
        if not pool:
            return -1                    # the whole fleet is revoked

        def load(d: int) -> float:
            return sum(rj.profile().occupancy for rj, _ in res_info[d])

        def pack(d: int) -> tuple:
            return pt.packing_key(self._effective_power_policy(at),
                                  occupied=bool(res_info[d]), fill=load(d))

        if not self.anticipate:
            return min(pool, key=lambda d: pack(d)
                       + (load(d), len(res_info[d]), d))
        remaining = max(self._horizon - at, 0.0) if np.isfinite(
            self._horizon) else 1.0
        remaining = max(remaining, 1e-9)

        served = self._served_rate

        def score(d: int) -> tuple:
            k0, k1 = len(res_info[d]), len(res_info[d]) + 1
            gain = served(job, rate, d, k1) * remaining
            loss = sum((served(rj, rr, d, k0) - served(rj, rr, d, k1))
                       * remaining for rj, rr in res_info[d])
            cost = self._disruption_items(d) if with_disruption else 0.0
            return ((-(gain - loss - cost),) + pack(d)
                    + (load(d), len(res_info[d]), d))

        return min(pool, key=score)

    def _served_rate(self, job, rate: Optional[float], d: int,
                     k: int) -> float:
        """Demand-capped predicted steady throughput: a job never serves
        requests it does not receive, so capacity beyond the arrival rate
        is worth nothing to the packer."""
        pred = self._predicted_steady(job, d, k)
        cap = pred[0] if pred is not None else 0.0
        return min(cap, rate) if rate is not None else cap

    def _resident_info(self) -> List[List[tuple]]:
        return [[(self.states[j].job, self.states[j].arrival_rate)
                 for j in r] for r in self.residents]

    # -- churn: admission, drain, migration ---------------------------------
    def _capped(self, cost: float) -> float:
        """Lockstep-fairness cap: the clock charge for one stall event.
        The excess is recorded in `stall_capped_s`, never silently lost."""
        if self.stall_cap_s is None:
            return cost
        charged = min(cost, self.stall_cap_s)
        self.stall_capped_s += cost - charged
        return charged

    def _note_residency(self, d: int, t: float) -> None:
        """Track device d's powered interval for the idle-floor charge: a
        device powers ON when its first resident lands and OFF when its
        last one leaves (so "pack" placement power-gates the empties);
        `report()` closes any interval still open at the makespan.  Every
        residents[d] mutation calls this with the event time."""
        on = self._dev_on_since[d]
        if self.residents[d]:
            if on is None:
                self._dev_on_since[d] = t
        elif on is not None:
            self._dev_powered_s[d] += max(t - on, 0.0)
            self._dev_intervals[d].append((on, max(t, on)))
            self._dev_on_since[d] = None

    def _charge_migration(self, j: int, d: int, k: int, *, at: float,
                          kind: str,
                          part_share: Optional[float] = None) -> None:
        """One migration round for state j on device d (k co-residents):
        rebuild the executor at the new share, charge the stall to the
        job's clock and the global counters, reset its tail window, and
        let the controller re-seed its search."""
        st = self.states[j]
        spec = self.fleet[d]
        # cost resolves BEFORE this round's own measurement lands in the
        # store: calibration always reflects prior rounds only
        cost = self._migration_cost(st, spec)
        modeled = self._modeled_migration_cost(st, spec)
        self._rebuilds += 1
        seed = self.seed + 3000 + self._rebuilds
        if hasattr(st.executor, "cache_stats"):
            # real executor: instrument the actual kill + relaunch +
            # recompile round and feed the migration calibration
            kill_s = (st.executor.shutdown()
                      if hasattr(st.executor, "shutdown") else 0.0)
            t0 = time.perf_counter()
            st.executor = self._make_executor(st.job, d, k, seed,
                                              part_share=part_share)
            build_s = time.perf_counter() - t0
            warm_s = (st.executor.warmup(st.prev.bs, st.prev.mtl)
                      if hasattr(st.executor, "warmup") else 0.0)
            measured = kill_s + build_s + warm_s
            st.measured_migration_s += measured
            if self.profile_store is not None:
                self.profile_store.record_migration(
                    self._calibration_key(st, spec), measured)
        else:
            st.executor = self._make_executor(st.job, d, k, seed,
                                              part_share=part_share)
        st.migration_modeled_s += modeled
        self.migration_modeled_s += modeled
        charged = self._capped(cost)
        st.clock += charged
        st.epoch += 1
        st.stall_time += charged
        st.migration_stall_s += charged
        st.migrations += 1
        st.acc.total_time += charged
        self.stall_time += charged
        self.migration_stall_s += charged
        self.migrations += 1
        st.window.reset()              # the latency surface just changed
        if hasattr(st.controller, "note_capacity_change"):
            st.controller.note_capacity_change(st.executor)
        self.churn_log.append((at, kind, st.job.job_id, spec.label(d)))
        if self._heap is not None:
            heapq.heappush(self._heap, (st.clock, j, st.epoch))

    # -- partition mode: resize instead of migrate ---------------------------
    def _resize_cost(self, st: _JobState, spec: DeviceSpec) -> float:
        """Stall seconds for one partition resize: an MPS set-percentage /
        MIG reconfigure keeps the serving contexts alive, so it is far
        below a kill+relaunch round.  Real executors calibrate it through
        the profile store under a `resize|` key, exactly like migrations."""
        if (self.profile_store is not None
                and hasattr(st.executor, "cache_stats")):
            cal = self.profile_store.migration_cost(
                "resize|" + self._calibration_key(st, spec))
            if cal is not None:
                return cal
        return self.partition_resize_s

    def _charge_resize(self, j: int, d: int, new_share: float, *, at: float,
                       kind: str = "resize",
                       tenant_change: bool = False) -> None:
        """Move state j's partition grant to `new_share` on its device:
        update the executor's slice in place (no relaunch), charge the
        cheap resize stall, and record what a full migration WOULD have
        cost the same event (`resize_equiv_migration_s` — the comparison
        the partition example pins)."""
        st = self.states[j]
        spec = self.fleet[d]
        as_migration = self.partition_uniform
        cost = (self._migration_cost(st, spec) if as_migration
                else self._resize_cost(st, spec))
        equiv = self._modeled_migration_cost(st, spec)
        self._grant[j] = new_share
        ts = self._tenant_slice(new_share, max(len(self.residents[d]), 1), d)
        if hasattr(st.executor, "cache_stats"):
            # real executor: instrument the reconfigure + re-warm round and
            # feed the resize calibration (PR 4 store, `resize|` prefix)
            t0 = time.perf_counter()
            if hasattr(st.executor, "set_partition"):
                st.executor.set_partition(ts)
            if hasattr(st.executor, "warmup"):
                st.executor.warmup(st.prev.bs, st.prev.mtl)
            measured = time.perf_counter() - t0
            if self.profile_store is not None:
                self.profile_store.record_migration(
                    "resize|" + self._calibration_key(st, spec), measured)
        elif hasattr(st.executor, "set_partition"):
            st.executor.set_partition(ts)
        charged = self._capped(cost)
        st.clock += charged
        st.epoch += 1
        st.stall_time += charged
        st.acc.total_time += charged
        self.stall_time += charged
        if as_migration:               # uniform baseline: a reshare IS a
            st.migration_stall_s += charged    # kill+relaunch round
            st.migrations += 1
            st.migration_modeled_s += equiv
            self.migration_stall_s += charged
            self.migrations += 1
            self.migration_modeled_s += equiv
        else:
            st.resize_stall_s += charged
            st.resizes += 1
            self.resize_stall_s += charged
            self.resizes += 1
            self.resize_equiv_migration_s += equiv
        st.window.reset()              # the latency surface just moved
        ctrl = st.controller
        if hasattr(ctrl, "note_share_grant"):
            ctrl.note_share_grant(new_share)
        if tenant_change and hasattr(ctrl, "note_capacity_change"):
            ctrl.note_capacity_change(st.executor)
        self.churn_log.append((at, kind, st.job.job_id, spec.label(d)))
        if self._heap is not None:
            heapq.heappush(self._heap, (st.clock, j, st.epoch))

    def _refresh_slices(self, d: int) -> None:
        """The device's tenant count changed: update every resident's
        slice interference term in place (shares untouched — an MPS
        repricing, not a reconfigure, so nothing is charged) and reset
        their tail windows."""
        k = max(len(self.residents[d]), 1)
        for j in self.residents[d]:
            st = self.states[j]
            ts = self._tenant_slice(self._grant.get(j, 1.0), k, d)
            if hasattr(st.executor, "set_partition"):
                st.executor.set_partition(ts)
            st.window.reset()

    def _maybe_grant_resize(self, i: int, requested: float,
                            at: float) -> None:
        """Mediate a scaler's share request: grant up to the device's
        headroom (snapped to the backend's legal grid), align the scaler
        with the actual grant, and charge the resize."""
        d = self.placement[i]
        st = self.states[i]
        cur = self._grant.get(i, 1.0)
        new = requested
        if requested > cur:
            new = min(requested, cur + self._headroom(d))
        new = self._legal_share(new)
        if new <= 0.0 or abs(new - cur) <= 1e-9:
            if hasattr(st.controller, "note_share_grant"):
                st.controller.note_share_grant(cur)
            return
        self._charge_resize(i, d, new, at=at, kind="resize",
                            tenant_change=False)

    @staticmethod
    def _struggling(st: _JobState) -> bool:
        """A resident that is NOT keeping up — growing backlog or a tail
        over its SLO — and therefore worth the stall of a bigger slice
        (the one gate shared by `_reshare(optional=True)`, the partition
        upsize, and the uniform-baseline drain path)."""
        behind = (st.oq is not None and st.oq.backlog
                  > 2 * max(st.prev.bs * st.prev.mtl, 1))
        return behind or st.window.p95 > st.job.slo_s

    def _partition_upsize(self, d: int, *, at: float) -> None:
        """A drain freed share: hand it to residents that are actually
        struggling (the same gate as `_reshare(optional=True)`); a
        keeping-up resident is left alone."""
        if d in self._timeshared:
            k = len(self.residents[d])
            if k * self._min_grant() <= 1.0 + pt.SHARE_TOL:
                # the tenant count fits the grid again: leave the
                # time-multiplex fallback, snapping every grant back
                # onto a legal slice
                self._timeshared.discard(d)
                for j in list(self.residents[d]):
                    legal = self._legal_share(self._grant.get(j, 0.0))
                    if abs(legal - self._grant.get(j, 0.0)) > 1e-9:
                        self._charge_resize(j, d, legal, at=at,
                                            kind="resize",
                                            tenant_change=True)
        needy = [j for j in self.residents[d]
                 if self._struggling(self.states[j])]
        if not needy:
            return
        extra = self._headroom(d) / len(needy)
        if extra <= 1e-9:
            return
        for j in needy:
            new = self._legal_share(
                min(1.0, self._grant.get(j, 0.0) + extra))
            if new > self._grant.get(j, 0.0) + 1e-9:
                self._charge_resize(j, d, new, at=at, kind="grow",
                                    tenant_change=False)

    def _partition_pick(self, job, at: float) -> Optional[tuple]:
        """Score every (unrevoked) device for a partition-mode insertion;
        returns (d, prospect, needs_shrink) for the best, or None when
        the whole fleet is revoked.  The score prefers feasible-without-
        shrink devices, then (under a power_policy) the consolidate or
        spread key, then most headroom / least load."""
        prof = job.profile()
        min_g = self._legal_share(self._min_grant())
        iso = 1.0 if self.partition == "mig" else 0.0
        scored = []
        for d, spec in enumerate(self.fleet):
            if d in self._revoked:
                continue                 # spot capacity gone: never place
            k = len(self.residents[d]) + 1
            head = self._headroom(d)
            target = self._legal_share(1.0 / k)     # uniform entitlement
            if self.partition_uniform:
                needs_shrink = False
                prospect = target
            elif self.power_policy is not None:
                # entitlement-fair admission (scenario cells): a newcomer
                # squeezed below its uniform 1/k slice by grown residents
                # reclaims up to the entitlement via cheap resizes — an
                # evacuee landing next to a 0.875-share hog must not be
                # pinned at the ladder floor for the rest of the run
                needs_shrink = head < target - 1e-9
                prospect = target if needs_shrink else \
                    self._legal_share(min(max(head if head < target
                                              else target, min_g), 1.0))
            else:
                needs_shrink = head < min_g - 1e-9
                prospect = min_g if needs_shrink else \
                    self._legal_share(min(max(head if head < target
                                              else target, min_g), 1.0))
            inv = 1.0 / prospect
            lat = dm.part_latency(spec.device, prof, 1, 1, inv_share=inv,
                                  tenants=k, isolation=iso)
            feasible = lat <= PLACEMENT_ALPHA * job.slo_s
            load = sum(self.states[j].job.profile().occupancy
                       for j in self.residents[d])
            pack = pt.packing_key(self._effective_power_policy(at),
                                  occupied=bool(self.residents[d]),
                                  fill=1.0 - head)
            scored.append(((not feasible, needs_shrink) + pack
                           + (-head, load, d),
                           d, prospect, needs_shrink))
        if not scored:
            return None
        _, d, prospect, needs_shrink = min(scored)
        return d, prospect, needs_shrink

    def _partition_reserve(self, d: int, prospect: float,
                           needs_shrink: bool, at: float) -> float:
        """Make room for one more tenant on device d (shrinks / uniform
        re-grants / time-multiplex fallback) and return the share the
        newcomer actually gets."""
        min_g = self._legal_share(self._min_grant())
        if self.partition_uniform:
            # every resident is re-granted its uniform 1/k slice; each
            # change is a full kill+relaunch migration (the baseline)
            knew = len(self.residents[d]) + 1
            prospect = self._legal_share(1.0 / knew)
            for j in list(self.residents[d]):
                if abs(self._grant.get(j, 0.0) - prospect) > 1e-9:
                    self._charge_resize(j, d, prospect, at=at,
                                        kind="migrate", tenant_change=True)
        elif needs_shrink:
            if self.partition == "mig":
                # discrete grid: residents step down one PROFILE at a
                # time, largest slice first, until the smallest profile
                # fits — a proportional scale would snap right back to the
                # rung a floor-sized resident already holds and free
                # nothing, silently oversubscribing the device
                progress = True
                while (self._headroom(d) < min_g - pt.SHARE_TOL
                       and progress):
                    progress = False
                    order = sorted(self.residents[d],
                                   key=lambda j: -self._grant.get(j, 0.0))
                    for j in order:
                        nxt = pt.mig_step_down(self._grant.get(j, 0.0))
                        if nxt is None:
                            continue
                        self._charge_resize(j, d, nxt, at=at,
                                            kind="shrink",
                                            tenant_change=True)
                        progress = True
                        if self._headroom(d) >= min_g - pt.SHARE_TOL:
                            break
            else:
                # free the newcomer's slice proportionally: its uniform
                # entitlement under a power_policy (see _partition_pick),
                # the ladder floor otherwise
                want = prospect if self.power_policy is not None else min_g
                used = sum(self._grant.get(j, 0.0)
                           for j in self.residents[d])
                scale = max(1.0 - want, 1e-9) / max(used, 1e-9)
                for j in list(self.residents[d]):
                    new = self._legal_share(self._grant.get(j, 0.0) * scale)
                    if new < self._grant.get(j, 0.0) - 1e-9:
                        self._charge_resize(j, d, new, at=at,
                                            kind="shrink",
                                            tenant_change=True)
            head = self._headroom(d)
            if head < min_g - pt.SHARE_TOL:
                # more tenants than the grid has slices: no legal spatial
                # plan exists, so the device falls back to time-multiplexed
                # equal shares — the same degradation the TPU submesh path
                # takes when jobs outnumber chips.  Every resident is
                # re-granted 1/k; `partition_plan` reports the device as
                # "mps" (time-shared) so legality reflects reality.
                knew = len(self.residents[d]) + 1
                eq = 1.0 / knew
                self._timeshared.add(d)
                for j in list(self.residents[d]):
                    if abs(self._grant.get(j, 0.0) - eq) > 1e-9:
                        self._charge_resize(j, d, eq, at=at,
                                            kind="shrink",
                                            tenant_change=True)
                prospect = eq
            else:
                prospect = self._legal_share(max(min(head, prospect),
                                                 min_g))
        return prospect

    def _admit_partition(self, entry: ChurnJob) -> int:
        """Partition-mode admission: the newcomer takes a slice out of the
        chosen device's HEADROOM; only when no device has a minimal slice
        free are co-residents shrunk — via cheap resizes, never the
        kill+relaunch migration round the uniform time-sharing path pays."""
        job = entry.job
        pick = self._partition_pick(job, entry.admit_s)
        if pick is None:
            raise RuntimeError("admission with every device revoked")
        d, prospect, needs_shrink = pick
        prospect = self._partition_reserve(d, prospect, needs_shrink,
                                           entry.admit_s)
        i = self._spawn(entry, d, len(self.residents[d]) + 1, share=prospect)
        self.residents[d].append(i)
        self._note_residency(d, entry.admit_s)
        self.admissions += 1
        self.churn_log.append((entry.admit_s, "admit", job.job_id,
                               self.fleet[d].label(d)))
        self._refresh_slices(d)
        return i

    def _reshare(self, d: int, *, at: float,
                 exclude: Optional[int] = None,
                 optional: bool = False) -> None:
        """Device d's resident count changed: rebuild every resident whose
        share moved, charging each the migration cost.

        `optional=True` (a drain freed share) gates each upgrade on need:
        a resident that is keeping up — no backlog growth, tail under the
        SLO — gains nothing from a bigger slice but would still pay the
        relaunch stall, so it keeps serving on its old share."""
        spec = self.fleet[d]
        k = len(self.residents[d])
        if k == 0:
            return
        _, _, new_share = self._executor_params(spec, k)
        for j in list(self.residents[d]):
            if j == exclude:
                continue
            st = self.states[j]
            old_share = getattr(st.executor, "_cluster_share", None)
            if old_share is not None and old_share == new_share:
                continue               # e.g. a 4->3 drain on a (4,4) slice
            if optional and not self._struggling(st):
                continue
            self._charge_migration(j, d, k, at=at, kind="migrate")

    def _best_relocation_for(self, job, rate: Optional[float], at: float,
                             direct_value: float) -> Optional[tuple]:
        """Migration-aware re-placement at admission: consider swapping
        ONE resident (victim v: home device dt -> destination d2) so the
        new job takes v's slot.  The swap leaves dt's resident count
        unchanged — v's old co-residents pay NO reshare — so the net value
        is the new job's served rate at dt, plus the victim's served-rate
        delta, minus what d2's residents lose to the extra tenant and the
        one-off migration stalls.  Returns (victim idx, d2, dt) when the
        best swap beats `direct_value` by a margin, else None."""
        remaining = max(self._horizon - at, 0.0)
        if not np.isfinite(remaining) or remaining <= 0.0:
            return None
        served = self._served_rate
        info = self._resident_info()
        best = None   # (value, victim idx, d2, dt)
        for dt, spec in enumerate(self.fleet):
            k_dt = len(self.residents[dt])
            if k_dt == 0 or dt in self._revoked:
                continue
            # everyone on dt (minus any one victim, plus the new job) keeps
            # the same count — feasibility only needs the new job's check
            if (_base_latency(spec, job.profile(), k_dt)
                    > PLACEMENT_ALPHA * job.slo_s):
                continue
            gain_new = served(job, rate, dt, k_dt)
            for j in self.residents[dt]:
                st = self.states[j]
                v_cur = served(st.job, st.arrival_rate, dt, k_dt)
                for d2, spec2 in enumerate(self.fleet):
                    if d2 == dt or d2 in self._revoked:
                        continue
                    k2 = len(self.residents[d2]) + 1
                    ok = (_base_latency(spec2, st.job.profile(), k2)
                          <= PLACEMENT_ALPHA * st.job.slo_s
                          and all(_base_latency(spec2, rj.profile(), k2)
                                  <= PLACEMENT_ALPHA * rj.slo_s
                                  for rj, _ in info[d2]))
                    if not ok:
                        continue
                    v_new = served(st.job, st.arrival_rate, d2, k2)
                    loss = sum((served(rj, rr, d2, k2 - 1)
                                - served(rj, rr, d2, k2))
                               for rj, rr in info[d2])
                    one_off = (st.acc.throughput
                               * self._migration_cost(st, spec2)
                               + self._disruption_items(d2))
                    value = ((gain_new + v_new - v_cur - loss) * remaining
                             - one_off)
                    if value > direct_value and (best is None
                                                 or value > best[0]):
                        best = (value, j, d2, dt)
        if best is None:
            return None
        return best[1], best[2], best[3]

    def _move(self, j: int, d2: int, *, at: float,
              reshare_origin: bool = True, kind: str = "move") -> None:
        """Relocate resident j to device d2, cascading share changes.

        `reshare_origin=False` is for admission swaps: the caller refills
        j's old slot immediately, so the origin's count never really
        changes — upsizing the survivors now would charge them a full
        migration stall that the admission reshare would undo one call
        later."""
        d = self.placement[j]
        self.residents[d].remove(j)
        self.residents[d2].append(j)
        self.placement[j] = d2
        self._note_residency(d, at)
        self._note_residency(d2, at)
        self._charge_migration(j, d2, len(self.residents[d2]), at=at,
                               kind=kind)
        if reshare_origin:
            # survivors MAY upsize (only if struggling)
            self._reshare(d, at=at, optional=True)
        # d2 residents MUST shrink — the device is now shared more ways
        self._reshare(d2, at=at, exclude=j)

    def _rebalance(self, at: float, *, max_moves: int = 2) -> None:
        """Drain-time re-placement: freed capacity is only worth something
        if a struggling job moves onto it.  Greedily executes up to
        `max_moves` single-job relocations while the best one's predicted
        net gain — the mover's demand-capped served-rate delta, plus what
        its old co-residents regain, minus what the destination's
        residents lose and every one-off migration stall — is positive."""
        if self.static_union:
            return
        remaining = max(self._horizon - at, 0.0)
        if remaining <= 0.0 or not np.isfinite(remaining):
            return
        served = self._served_rate
        for _ in range(max_moves):
            info = self._resident_info()
            best = None      # (net gain items, state idx, destination)
            for d in range(len(self.fleet)):
                if d in self._revoked:
                    continue     # doomed residents ride out their grace
                for j in list(self.residents[d]):
                    st = self.states[j]
                    k_d = len(self.residents[d])
                    cur = served(st.job, st.arrival_rate, d, k_d)
                    old_mates = [(rj, rr) for rj, rr in info[d]
                                 if rj is not st.job]
                    regain = sum(
                        (served(rj, rr, d, k_d - 1)
                         - served(rj, rr, d, k_d))
                        for rj, rr in old_mates)
                    for d2, spec2 in enumerate(self.fleet):
                        if d2 == d or d2 in self._revoked:
                            continue
                        k2 = len(self.residents[d2]) + 1
                        ok = (_base_latency(spec2, st.job.profile(), k2)
                              <= PLACEMENT_ALPHA * st.job.slo_s
                              and all(_base_latency(spec2, rj.profile(), k2)
                                      <= PLACEMENT_ALPHA * rj.slo_s
                                      for rj, _ in info[d2]))
                        if not ok:
                            continue
                        new = served(st.job, st.arrival_rate, d2, k2)
                        if new <= cur * 1.05:
                            continue     # hysteresis against move thrash
                        loss = sum(
                            (served(rj, rr, d2, k2 - 1)
                             - served(rj, rr, d2, k2))
                            for rj, rr in info[d2])
                        one_off = (st.acc.throughput
                                   * self._migration_cost(st, spec2)
                                   + self._disruption_items(d2))
                        net = ((new - cur + regain - loss) * remaining
                               - one_off)
                        if net > 0 and (best is None or net > best[0]):
                            best = (net, j, d2)
            if best is None:
                return
            self._move(best[1], best[2], at=at)

    def _admit(self, entry: ChurnJob) -> int:
        """Admit a churn arrival: incremental packing, with one
        migration-aware relocation considered whenever direct placement
        leaves the new job underserved (or infeasible); then charge
        co-residents their share change."""
        if self.partition is not None:
            return self._admit_partition(entry)
        job = entry.job
        rate = (entry.arrival_rate if entry.arrival_rate is not None
                else self._arrival_rates.get(job.job_id))
        info = self._resident_info()
        d = self._choose_device(job, rate, info, at=entry.admit_s,
                                with_disruption=True)
        if d < 0:
            raise RuntimeError("admission with every device revoked")
        if self.anticipate:
            k = len(self.residents[d]) + 1
            served = self._served_rate(job, rate, d, k)
            remaining = max(self._horizon - entry.admit_s, 0.0)
            underserved = (rate is not None and served < 0.95 * rate) or \
                (_base_latency(self.fleet[d], job.profile(), k)
                 > PLACEMENT_ALPHA * job.slo_s)
            if underserved and np.isfinite(remaining):
                loss = sum(
                    (self._served_rate(rj, rr, d, k - 1)
                     - self._served_rate(rj, rr, d, k))
                    for rj, rr in info[d])
                direct_value = ((served - loss) * remaining
                                - self._disruption_items(d))
                swap = self._best_relocation_for(job, rate, entry.admit_s,
                                                 direct_value)
                if swap is not None:
                    victim, d2, dt = swap
                    self._move(victim, d2, at=entry.admit_s,
                               reshare_origin=False)
                    d = dt
        i = self._spawn(entry, d, len(self.residents[d]) + 1)
        self.residents[d].append(i)
        self._note_residency(d, entry.admit_s)
        self.admissions += 1
        self.churn_log.append((entry.admit_s, "admit", job.job_id,
                               self.fleet[d].label(d)))
        self._reshare(d, at=entry.admit_s, exclude=i)
        return i

    def _maybe_drain(self, i: int) -> bool:
        """Drain i once its departure time passed AND its backlog is
        served (arrivals were already clipped at depart_s, so the backlog
        is finite); frees its share for the co-residents."""
        st = self.states[i]
        if st.depart_s is None or st.clock < st.depart_s:
            return False
        if st.oq is not None and st.oq.queue:
            return False
        st.active = False
        st.drained_at = st.clock
        st.epoch += 1
        d = self.placement[i]
        # the departing tenancy's probed surface row is history worth
        # keeping — persist it NOW, before the freed share triggers
        # reshare migrations that reset co-residents' rows
        self._persist_job_surface(i, d)
        if i in self.residents[d]:
            self.residents[d].remove(i)
        self._note_residency(d, st.clock)
        self._kill_at.pop(i, None)       # drained before its kill deadline
        self.drains += 1
        self.churn_log.append((st.clock, "drain", st.job.job_id,
                               self.fleet[d].label(d)))
        if d in self._revoked:
            # a dying device's survivors are doomed or evacuating — never
            # upsize or rebalance onto it
            return True
        if not self.static_union:
            if self.partition is not None:
                if self.partition_uniform:
                    # uniform baseline mirrors the legacy drain: strugglers
                    # MAY upsize to the new 1/k — paying a migration round
                    k = max(len(self.residents[d]), 1)
                    share = self._legal_share(1.0 / k)
                    for j in list(self.residents[d]):
                        if self._struggling(self.states[j]) and \
                                share > self._grant.get(j, 0.0) + 1e-9:
                            self._charge_resize(j, d, share, at=st.clock,
                                                kind="migrate",
                                                tenant_change=True)
                else:
                    # freed share goes to struggling residents via cheap
                    # resizes; the interference term relaxes for everyone
                    self._partition_upsize(d, at=st.clock)
                self._refresh_slices(d)
            else:
                self._reshare(d, at=st.clock, optional=True)
                self._rebalance(st.clock)
        return True

    # -- spot capacity: revocation, evacuation, forced kill -------------------
    def _process_due_events(self, sim_time_limit: float,
                            nxt_fn: Callable[[], float]) -> None:
        """Fire pending admissions AND capacity (spot revoke/restore)
        events due before the next step event, merged in timestamp order
        (a revocation at the same instant as an admission fires first, so
        the packer never lands the newcomer on capacity that just left).
        With no capacity events this reduces verbatim to the legacy
        admission loop — same order, same RNG draws."""
        while True:
            nxt = nxt_fn()
            ta = (self._pending[self._pending_i].admit_s
                  if self._pending_i < len(self._pending) else float("inf"))
            tc = (self._cap_events[self._cap_i][0]
                  if self._cap_i < len(self._cap_events) else float("inf"))
            t = min(ta, tc)
            if not (t <= min(nxt, sim_time_limit) and t < sim_time_limit):
                return
            if tc <= ta:
                ev = self._cap_events[self._cap_i]
                self._cap_i += 1
                self._fire_capacity_event(ev)
            else:
                i = self._admit(self._pending[self._pending_i])
                self._pending_i += 1
                if self._heap is not None:
                    st = self.states[i]
                    heapq.heappush(self._heap, (st.clock, i, st.epoch))

    def _fire_capacity_event(self, ev: tuple) -> None:
        """One capacity edge.  Revoke: the device leaves the placement
        pool and every resident is evacuated to surviving capacity (one
        migration round each); a resident with nowhere to go serves
        through the grace window on the doomed device and is force-killed
        at the deadline.  Restore: the device simply rejoins the pool."""
        t, kind, p = ev
        d = p.device
        if kind == 1:
            self._revoked.discard(d)
            self.churn_log.append((t, "restore", None,
                                   self.fleet[d].label(d)))
            return
        self._revoked.add(d)
        self.preemptions_fired += 1
        self.churn_log.append((t, "revoke", None, self.fleet[d].label(d)))
        deadline = t + p.grace_s
        for j in list(self.residents[d]):
            st = self.states[j]
            if not st.active:
                continue
            if self.partition is not None:
                self._evacuate_partition(j, d, at=t, deadline=deadline)
                continue
            dest = self._choose_device(st.job, st.arrival_rate,
                                       self._resident_info(), at=t)
            if dest < 0:
                self._doom(j, deadline)
            else:
                self._move(j, dest, at=t, reshare_origin=False,
                           kind="evict")
                self.preempt_evacuated += 1

    def _evacuate_partition(self, j: int, d: int, *, at: float,
                            deadline: float) -> None:
        """Partition-mode evacuation: re-run the partition packer for the
        displaced tenant (shrinking the destination's residents if it
        must), charge ONE migration round at the new slice."""
        st = self.states[j]
        pick = self._partition_pick(st.job, at)
        if pick is None:
            self._doom(j, deadline)
            return
        d2, prospect, needs_shrink = pick
        prospect = self._partition_reserve(d2, prospect, needs_shrink, at)
        self.residents[d].remove(j)
        self._grant.pop(j, None)
        self._note_residency(d, at)
        self.residents[d2].append(j)
        self.placement[j] = d2
        self._note_residency(d2, at)
        self._grant[j] = prospect
        self._charge_migration(j, d2, len(self.residents[d2]), at=at,
                               kind="evict", part_share=prospect)
        if hasattr(st.controller, "note_share_grant"):
            st.controller.note_share_grant(prospect)
        self._refresh_slices(d2)
        self.preempt_evacuated += 1

    def _doom(self, j: int, deadline: float) -> None:
        """No surviving device can host j: it keeps serving on the
        revoked device through the grace window — arrivals clipped at the
        deadline — and is force-killed when its clock reaches it (unless
        it drains its backlog first)."""
        cur = self._sim.depart_s[j]
        self._sim.depart_s[j] = min(float(cur), deadline)
        self._kill_at[j] = deadline

    def _force_kill(self, j: int, *, at: float) -> None:
        """Grace expired with backlog still outstanding: sample arrivals
        up to the clipped departure (so every request is COUNTED), reject
        the stranded queue wholesale, and retire the job.  Conservation —
        submitted == completed + rejected + backlog — survives the kill."""
        st = self.states[j]
        kill_t = max(at, st.clock)
        if st.oq is not None:
            st.oq.step(st.arrival_mark, kill_t, 0, arrival_end=st.depart_s)
            st.oq.rejected += len(st.oq.queue)
            st.oq.queue = []
        st.clock = kill_t
        st.arrival_mark = kill_t
        st.preempted = 1
        st.active = False
        st.drained_at = kill_t
        st.epoch += 1
        d = self.placement[j]
        self._persist_job_surface(j, d)
        if j in self.residents[d]:
            self.residents[d].remove(j)
        self._note_residency(d, kill_t)
        self._grant.pop(j, None)
        self._kill_at.pop(j, None)
        self.preempt_killed += 1
        self.churn_log.append((kill_t, "revoke-kill", st.job.job_id,
                               self.fleet[d].label(d)))

    # -- cross-run persistence ----------------------------------------------
    def _persist_job_surface(self, i: int, d: int) -> bool:
        """Persist state i's shared-surface row to the profile store under
        its (architecture-signature, device-class) key."""
        if self.profile_store is None or self.surface_library is None:
            return False
        st = self.states[i]
        key = getattr(st.controller, "surface_key", None)
        if key is None:
            return False
        # only wall-clock latencies depend on the tuned tiles; simulated
        # rows are exempt from the generation staleness gate on reload
        dc = self.fleet[d].device.name
        wrote = self.profile_store.persist_surface(
            self.surface_library, key,
            signature=f"{st.job.dnn}/{st.job.dataset}",
            device_class=dc,
            autotune_generation=autotune.generation(),
            tile_dependent=hasattr(st.executor, "cache_stats"))
        if wrote:
            self._fresh_rows[dc] = self._fresh_rows.get(dc, 0) + 1
            self._maybe_retrain(dc)
        return wrote

    def _maybe_retrain(self, dc: str) -> None:
        """Online cost-model retraining: once `retrain_every_rows` fresh
        surface rows accrued for a device class since its last fit, refit
        the class's learned HLO model from the store right here at drain
        time.  `train_cost_model` keeps its own minimum-row floor, so a
        refit never fires on thinner history than a cold fit would accept;
        a fit that comes back None (rows persisted but too few usable)
        leaves the fresh-row counter alone and retries at the next drain."""
        if self._fresh_rows.get(dc, 0) < self.retrain_every_rows:
            return
        device = next((spec.device for spec in self.fleet
                       if spec.device.name == dc), None)
        model = cost_model_mod.train_cost_model(
            self.profile_store, dc, device=device,
            autotune_generation=autotune.generation())
        if model is None:
            return
        cost_model_mod.save_cost_model(self.profile_store, model)
        self.cost_models[dc] = model
        self._fresh_rows[dc] = 0
        self.retrains[dc] = self.retrains.get(dc, 0) + 1
        if self.surface_library is not None:
            # same election as boot: the shared library serves the model
            # of the fleet's most common device class that has one
            counts: dict = {}
            for spec in self.fleet:
                counts[spec.device.name] = counts.get(spec.device.name,
                                                      0) + 1
            primary = max(self.cost_models,
                          key=lambda c: counts.get(c, 0))
            self.surface_library.set_cost_model(self.cost_models[primary])

    def _persist_profiles(self) -> None:
        """End of run: every still-resident tenancy's surface row joins the
        store (drained ones were persisted at drain time), then one atomic
        save writes surfaces + migration calibrations together."""
        if self.profile_store is None:
            return
        for i, (st, d) in enumerate(zip(self.states, self.placement)):
            if st.active:
                self._persist_job_surface(i, d)
        self.profile_store.save()

    # -- one serving step for one job ---------------------------------------
    def _step(self, st: _JobState, i: Optional[int] = None) -> None:
        if i is None:
            i = self.states.index(st)
        ctrl = st.controller
        if hasattr(ctrl, "set_slo"):
            ctrl.set_slo(st.job.slo_s)
        if self.partition is not None and hasattr(ctrl, "note_share_cap"):
            # the scaler's third axis may only request up to the device's
            # current headroom on top of its own grant
            d = self.placement[i]
            ctrl.note_share_cap(min(1.0, self._grant.get(i, 1.0)
                                    + self._headroom(d)))
        act = ctrl.action()
        if (self.partition is not None and act.share is not None
                and abs(act.share - self._grant.get(i, 1.0)) > 1e-9):
            self._maybe_grant_resize(i, float(act.share), at=st.clock)
            act = ctrl.action()          # re-read the grant-aligned action
        win_start = st.arrival_mark  # arrivals keep coming during any stall
        cost = reconfig_stall(st.prev, act, self.instance_launch_s,
                              self.instance_kill_s)
        if cost:
            charged = self._capped(cost)
            st.clock += charged
            st.stall_time += charged
            self.stall_time += charged
            st.acc.total_time += charged
        if (act.bs, act.mtl) != (st.prev.bs, st.prev.mtl):
            st.window.reset()            # re-measure the tail at the new knobs

        res = st.executor.run_step(act.bs, act.mtl)
        comp = res.get("compile_time", 0.0)
        if comp:                         # AOT compile = stall, like a launch
            comp = self._capped(comp)
            st.clock += comp
            st.acc.total_time += comp
            st.acc.compile_stall_s += comp
            self.compile_stall_s += comp
        if (self.profile_store is not None
                and res.get("partition_slowdown", 1.0) != 1.0
                and res.get("wall_step_time")):
            # real-executor capped-batch proxy: the measured interference
            # (raw wall vs slice-inflated step) feeds the store
            self.profile_store.record_interference(
                self._calibration_key(st, self.fleet[self.placement[i]]),
                self._grant.get(i, 1.0), res["wall_step_time"],
                res["step_time"])
        # per-device dynamic energy (the idle floor is charged per powered
        # interval in report(), never per co-resident step)
        dyn_j = res.get("dynamic_power_w", res["power_w"]) * res["step_time"]
        self._dev_dynamic_j[self.placement[i]] += dyn_j
        if self.power_price_fn is not None:
            self._dynamic_cost_usd += self._power_price(st.clock) * dyn_j
        t1 = st.clock + res["step_time"]
        slo = st.job.slo_s
        if st.oq is not None:            # open loop: queue + conservation
            # the arrival window spans the launch/kill/compile/migration
            # stall too — the outside world does not pause while instances
            # restart, and served latencies (t1 - ts) must include that
            # wait; a draining job's window is clipped at its departure
            served, lats = st.oq.step(win_start, t1, act.bs * act.mtl,
                                      arrival_end=st.depart_s)
            st.completed += len(served)
            st.acc.record_step(
                items=len(served), step_time=res["step_time"],
                power_w=res["power_w"], request_latencies=lats, slo=slo)
        else:                            # closed loop: every item completes
            st.submitted += res["items"]
            st.completed += res["items"]
            st.acc.record_step(
                items=res["items"], step_time=res["step_time"],
                power_w=res["power_w"],
                request_latencies=res["request_latencies"], slo=slo)
        # controllers observe SERVICE latency (see OpenLoopEngine's note)
        st.window.add_many(res["request_latencies"])
        st.acc.trace.append((t1, act.bs, act.mtl, st.window.p95,
                             res["throughput"], slo))
        ctrl.observe(st.window.p95, res)
        st.clock = t1
        st.arrival_mark = t1
        st.prev = act
        # snapshot SLO feasibility AT SERVE TIME: report() must describe
        # the share this job actually served under, not whoever lives on
        # its device at the horizon
        self._sim.feasible_at_serve[i] = 1 if self._feasible_now(i) else 0

    def _feasible_now(self, i: int) -> bool:
        """SLO feasibility of state i's CURRENT slice — the same (bs=1,
        mtl=1) pricing `report()` uses — memoized on (device, resident
        count, grant), which fully determines it."""
        d = self.placement[i]
        k = max(len(self.residents[d]) + (0 if i in self.residents[d]
                                          else 1), 1)
        st = self.states[i]
        if self.partition is not None and self._grant.get(i):
            ck = (i, d, k, self._grant[i], d in self._timeshared)
            v = self._feas_cache.get(ck)
            if v is None:
                ts = self._tenant_slice(self._grant[i], k, d)
                base = dm.part_latency(self.fleet[d].device,
                                       st.job.profile(), 1, 1,
                                       inv_share=ts.inv_share,
                                       tenants=ts.tenants,
                                       isolation=ts.isolation)
                v = bool(base <= st.job.slo_s)
                self._feas_cache[ck] = v
            return v
        ck = (i, d, k)
        v = self._feas_cache.get(ck)
        if v is None:
            base = _base_latency(self.fleet[d], st.job.profile(), k)
            v = bool(base <= st.job.slo_s)
            self._feas_cache[ck] = v
        return v

    def _admissions_due(self, nxt: float, sim_time_limit: float) -> bool:
        """Pending arrivals due before the next step event (cursor-based:
        the pending list is consumed in admit order, never popped)."""
        if self._pending_i >= len(self._pending):
            return False
        due = self._pending[self._pending_i].admit_s
        return due <= min(nxt, sim_time_limit) and due < sim_time_limit

    def _note_skew(self, st: _JobState, i: int) -> None:
        """Lockstep divergence: how far this job's clock ran ahead of the
        slowest active peer (a stall-inflated clock starves in the
        lockstep loop until everyone catches up — `stall_cap_s` bounds
        it).  Only a stall moves the clock by more than one serving step,
        so this runs only then; the min is one vectorized reduction over
        the state arrays, not a Python list rebuild."""
        other = self._sim.min_other_active_clock(i)
        if np.isfinite(other):
            self.max_clock_skew_s = max(self.max_clock_skew_s,
                                        st.clock - other)

    def _work_remaining(self, sim_time_limit: float) -> bool:
        """Any active job still short of the horizon, or any unadmitted
        arrival due before it — the condition that turns a max_steps exit
        into a TRUNCATED (silently partial) run."""
        n = len(self._sim)
        clocks = self._sim.clock[:n]
        if bool(np.any(self._sim.active[:n] & (clocks < sim_time_limit))):
            return True
        return (self._pending_i < len(self._pending)
                and self._pending[self._pending_i].admit_s < sim_time_limit)

    def run(self, *, sim_time_limit: float = 120.0,
            max_steps: int = 500_000) -> dict:
        self._horizon = sim_time_limit
        self._heap = [(st.clock, i, st.epoch)
                      for i, st in enumerate(self.states) if st.active]
        heapq.heapify(self._heap)
        heap = self._heap
        steps = 0
        while steps < max_steps:
            # admissions and capacity events due before the next step
            # event re-run the packer / fire the revocation
            self._process_due_events(
                sim_time_limit, lambda: heap[0][0] if heap else float("inf"))
            if not heap:
                break
            t, i, ep = heapq.heappop(heap)
            st = self.states[i]
            if not st.active or ep != st.epoch or t != st.clock:
                continue                 # stale entry (migrated or drained)
            if t >= sim_time_limit:
                continue                 # this job reached the horizon
            if i in self._kill_at and t >= self._kill_at[i] - 1e-12:
                self._force_kill(i, at=self._kill_at[i])
                continue                 # grace expired on the doomed job
            self.event_log.append((t, st.job.job_id))
            stalls_before = st.stall_time + st.acc.compile_stall_s
            self._step(st, i)
            steps += 1
            if st.stall_time + st.acc.compile_stall_s > stalls_before:
                self._note_skew(st, i)
            if self._maybe_drain(i):
                continue
            heapq.heappush(heap, (st.clock, i, st.epoch))
        self._heap = None
        self.steps_run = steps
        self.truncated = bool(steps >= max_steps
                              and self._work_remaining(sim_time_limit))
        self._persist_profiles()
        rep = self.report()
        self._record_run(rep, sim_time_limit=sim_time_limit,
                         max_steps=max_steps)
        return rep

    def _record_run(self, rep: dict, *, sim_time_limit: float,
                    max_steps: int) -> None:
        """Trace recording: persist the construction inputs, the
        admission/migration/resize/drain event stream, and the achieved
        aggregate into the profile store (serving/replay.py re-drives
        them under counterfactual policies)."""
        if self.record is None:
            return
        from repro.serving import replay as _replay
        store = self._record_store or self.profile_store
        if store is None:
            from repro.perf.profile_store import store_for
            store = store_for()
        trace = _replay.trace_from_engine(self, rep,
                                          sim_time_limit=sim_time_limit,
                                          max_steps=max_steps)
        _replay.save_trace(store, self.record, trace)

    def report(self) -> dict:
        per_job = []
        goodput_items = 0.0
        for i, (st, d) in enumerate(zip(self.states, self.placement)):
            s = st.acc.summary()
            # a job is SLO-feasible on its slice iff even (bs=1, mtl=1)
            # fits under the SLO there; infeasible jobs are served
            # best-effort and flagged, not hidden.  The flag is the
            # snapshot taken at the job's LAST SERVE — the share it
            # actually ran under — not a recomputation from whoever lives
            # on the device at the horizon; only a job that never served
            # falls back to the current-slice computation.
            snap = int(self._sim.feasible_at_serve[i])
            feasible_flag = bool(snap) if snap >= 0 else \
                self._feasible_now(i)
            goodput_items += st.completed * s["slo_attainment"]
            per_job.append({
                "job_id": st.job.job_id,
                "dnn": f"{st.job.dnn}/{st.job.dataset}",
                "device": self.fleet[d].label(d),
                "approach": getattr(st.controller, "approach",
                                    getattr(st.controller, "name", "?")),
                "bs": st.prev.bs, "mtl": st.prev.mtl,
                "slo_ms": float(st.job.slo_ms),
                "p95_ms": float(s["p95_s"]) * 1e3,
                "tail_p95_ms": float(st.acc.tail_p95()) * 1e3,
                "feasible": feasible_flag,
                "slo_attainment": float(s["slo_attainment"]),
                "throughput": float(s["throughput"]),
                "stall_s": float(st.stall_time),
                "active": bool(st.active),
                "admit_s": float(st.admit_s),
                "depart_s": (float(st.depart_s)
                             if st.depart_s is not None else None),
                "drained_at": (float(st.drained_at)
                               if st.drained_at is not None else None),
                "migrations": int(st.migrations),
                "migration_stall_s": float(st.migration_stall_s),
                "migration_modeled_s": float(st.migration_modeled_s),
                "share": (float(self._grant[i]) if i in self._grant
                          else None),
                "resizes": int(st.resizes),
                "resize_stall_s": float(st.resize_stall_s),
                "submitted": (st.oq.submitted if st.oq is not None
                              else st.submitted),
                "completed": st.completed,
                "rejected": st.oq.rejected if st.oq is not None else 0,
                "backlog": st.oq.backlog if st.oq is not None else 0,
                "preempted": int(st.preempted),
            })
        makespan = float(max((st.clock for st in self.states), default=0.0))
        completed = sum(st.completed for st in self.states)
        feasible = [r for r in per_job if r["feasible"]]
        conserved = all(r["submitted"] == r["completed"] + r["rejected"]
                        + r["backlog"] for r in per_job)
        # energy: dynamic joules accumulated per step + the idle floor over
        # each device's powered interval (intervals still open at the
        # makespan are closed HERE, without mutating engine state)
        powered_s = []
        for d in range(len(self.fleet)):
            s = self._dev_powered_s[d]
            on = self._dev_on_since[d]
            if on is not None:
                s += max(makespan - on, 0.0)
            powered_s.append(s)
        idle_j = sum(self.fleet[d].device.idle_w * powered_s[d]
                     for d in range(len(self.fleet)))
        dynamic_j = float(sum(self._dev_dynamic_j))
        energy_j = idle_j + dynamic_j
        # carbon-aware power cost: integrate the $/J signal over every
        # powered interval at each device's idle floor (trapezoid over the
        # closed intervals plus any still open at the makespan), and add
        # the dynamic-cost ledger accrued at each step's own clock
        power_cost = None
        if self.power_price_fn is not None:
            idle_cost = 0.0
            for d in range(len(self.fleet)):
                ivs = list(self._dev_intervals[d])
                on = self._dev_on_since[d]
                if on is not None:
                    ivs.append((on, max(makespan, on)))
                for t0, t1 in ivs:
                    if t1 <= t0:
                        continue
                    ts = np.linspace(t0, t1, 65)
                    ps = np.asarray([self._power_price(t) for t in ts])
                    trapezoid = getattr(np, "trapezoid", np.trapz)
                    idle_cost += float(trapezoid(ps, ts)) \
                        * self.fleet[d].device.idle_w
            power_cost = idle_cost + self._dynamic_cost_usd
        return {
            "per_job": per_job,
            "aggregate": {
                "jobs": len(self.states),
                "devices": len(self.fleet),
                "makespan_s": makespan,
                "aggregate_throughput":
                    completed / makespan if makespan else 0.0,
                "goodput":
                    goodput_items / makespan if makespan else 0.0,
                "total_stall_s": float(self.stall_time),
                "compile_stall_s": float(self.compile_stall_s),
                "migration_stall_s": float(self.migration_stall_s),
                "migration_modeled_stall_s": float(self.migration_modeled_s),
                "admissions": int(self.admissions),
                "drains": int(self.drains),
                "migrations": int(self.migrations),
                "partition": self.partition,
                "resizes": int(self.resizes),
                "resize_stall_s": float(self.resize_stall_s),
                "resize_equiv_migration_stall_s":
                    float(self.resize_equiv_migration_s),
                "stall_capped_s": float(self.stall_capped_s),
                "max_clock_skew_s": float(self.max_clock_skew_s),
                "power_policy": self.power_policy,
                "energy_j": float(energy_j),
                "idle_energy_j": float(idle_j),
                "dynamic_energy_j": dynamic_j,
                "device_powered_s": float(sum(powered_s)),
                "devices_powered":
                    int(sum(1 for s in powered_s if s > 0.0)),
                "joules_per_good_request":
                    (float(energy_j / goodput_items)
                     if goodput_items > 0 else None),
                "power_cost_usd": (float(power_cost)
                                   if power_cost is not None else None),
                "cost_per_good_request":
                    (float(power_cost / goodput_items)
                     if power_cost is not None and goodput_items > 0
                     else None),
                "cost_model_retrains": dict(self.retrains),
                "preemptions": int(self.preemptions_fired),
                "preempt_evacuated": int(self.preempt_evacuated),
                "preempt_killed": int(self.preempt_killed),
                "truncated": bool(self.truncated),
                "conserved": bool(conserved),
                "min_attainment":
                    min((r["slo_attainment"] for r in per_job), default=1.0),
                "feasible_jobs": len(feasible),
                "jobs_meeting_slo":
                    int(sum(r["tail_p95_ms"] <= r["slo_ms"]
                            for r in feasible)),
            },
        }


class VectorClusterEngine(ClusterEngine):
    """`ClusterEngine` whose event loop runs over the `SimState` arrays.

    Two regimes, chosen per run:

    * **exact** (default; any adaptive controller, churn, open loop,
      partitioning, or store coupling): the next event is the argmin over
      the active-clock array instead of a heap pop.  Ties break toward
      the lowest index — the same order the reference heap's
      ``(clock, idx, epoch)`` tuples give — and stale heap entries in the
      reference only ever delay admissions to a later loop iteration
      *within* the same event round, so the two loops produce the same
      event sequence, the same RNG draws, and bit-identical reports (the
      conformance tests pin this on the BENCH_cluster and BENCH_churn
      scenarios).
    * **bulk** (static-knob, mtl=1, closed-loop `SimExecutor` fleets with
      no churn/partition/store coupling — the 1000x1000 scale scenario):
      jobs never interact (no stalls, no migrations, no shared surface),
      so each advances to the horizon in chunked vectorized draws, with
      the WHOLE fleet priced in one `fleet_step_latency` call up front.
      Statistically equivalent to the reference (same latency law per
      step), not bit-identical (one RNG call per chunk instead of two per
      step); per-event artifacts nobody aggregates (`event_log`, per-step
      traces, tail windows) are skipped.
    """

    def run(self, *, sim_time_limit: float = 120.0,
            max_steps: int = 500_000) -> dict:
        self._horizon = sim_time_limit
        self._heap = None       # _charge_* heap pushes are no-ops: the
        #                         clock arrays are always current
        if self._bulk_eligible():
            rep = self._run_bulk(sim_time_limit=sim_time_limit,
                                 max_steps=max_steps)
            if rep is not None:
                return rep
        return self._run_exact(sim_time_limit=sim_time_limit,
                               max_steps=max_steps)

    # -- exact mode: the reference event order, argmin-driven ----------------
    def _run_exact(self, *, sim_time_limit: float, max_steps: int) -> dict:
        sim = self._sim
        steps = 0
        while steps < max_steps:
            self._process_due_events(sim_time_limit, sim.next_event_clock)
            i = sim.frontier()
            if i < 0:
                break
            st = self.states[i]
            t = st.clock
            if t >= sim_time_limit:
                # every remaining active clock is at the horizon, and any
                # pending arrival before it was admitted above — the
                # reference loop reaches the same state by draining its
                # heap entry by entry
                break
            if i in self._kill_at and t >= self._kill_at[i] - 1e-12:
                self._force_kill(i, at=self._kill_at[i])
                continue                 # grace expired on the doomed job
            self.event_log.append((t, st.job.job_id))
            stalls_before = st.stall_time + st.acc.compile_stall_s
            self._step(st, i)
            steps += 1
            if st.stall_time + st.acc.compile_stall_s > stalls_before:
                self._note_skew(st, i)
            self._maybe_drain(i)
        self.steps_run = steps
        self.truncated = bool(steps >= max_steps
                              and self._work_remaining(sim_time_limit))
        self._persist_profiles()
        rep = self.report()
        self._record_run(rep, sim_time_limit=sim_time_limit,
                         max_steps=max_steps)
        return rep

    # -- bulk mode: independent static jobs advance in chunks ----------------
    def _bulk_eligible(self) -> bool:
        """Bulk needs provably independent jobs: static knobs at mtl=1
        (no launch stalls, so clocks never couple through the skew/stall
        paths), closed loop, simulated executors on whole-device shares,
        no churn, no partitioning, and no store/surface coupling."""
        if (self.partition is not None
                or self._pending_i < len(self._pending)
                or self._cap_events
                or self.profile_store is not None
                or self.surface_library is not None
                or self.stall_cap_s is not None
                or not self.states):
            return False
        for st in self.states:
            ctrl = st.controller
            if getattr(ctrl, "name", "") != "static":
                return False
            if int(getattr(ctrl, "mtl", 0)) != 1:
                return False
            if st.oq is not None or st.depart_s is not None:
                return False
            ex = st.executor
            if (hasattr(ex, "cache_stats")      # wall-clock executor
                    or getattr(ex, "mesh_shape", None) is not None
                    or getattr(ex, "partition", None) is not None):
                return False
            if not st.active:
                return False
        return True

    # legacy per-job chunk loop kept as the reference implementation the
    # fleet-vectorized path is validated against (and as an escape hatch)
    bulk_use_loop = False

    def _run_bulk(self, *, sim_time_limit: float,
                  max_steps: int) -> Optional[dict]:
        sim = self._sim
        n = len(self.states)
        acts = [Action(bs=int(st.controller.bs), mtl=int(st.controller.mtl))
                for st in self.states]
        devices = [st.executor.device for st in self.states]
        profiles = [st.executor.profile for st in self.states]
        bs = np.asarray([a.bs for a in acts], np.float64)
        mtl = np.asarray([a.mtl for a in acts], np.float64)
        # the whole fleet priced in ONE vectorized call per event round
        # (bulk has exactly one round: knobs are static)
        means = dm.fleet_step_latency(devices, profiles, bs, mtl)
        # pre-flight: if the fleet's expected step count cannot fit the
        # budget, bulk would distribute the truncation differently than
        # the reference interleaving — run exact instead, which then
        # raises the `truncated` flag the honest way
        remaining = np.maximum(sim_time_limit - sim.clock[:n], 0.0)
        est = float(np.sum(remaining / np.maximum(means, 1e-12)))
        if not np.isfinite(est) or est > 0.9 * max_steps:
            return None
        if self.bulk_use_loop:
            steps_total = self._bulk_jobloop(acts, means, sim_time_limit,
                                             max_steps)
        else:
            steps_total = self._bulk_vector(acts, means, sim_time_limit,
                                            max_steps)
        self.steps_run = steps_total
        self.truncated = bool(steps_total >= max_steps
                              and self._work_remaining(sim_time_limit))
        self._persist_profiles()
        rep = self.report()
        self._record_run(rep, sim_time_limit=sim_time_limit,
                         max_steps=max_steps)
        return rep

    def _bulk_jobloop(self, acts, means, sim_time_limit: float,
                      max_steps: int) -> int:
        sim = self._sim
        steps_total = 0
        for i, st in enumerate(self.states):
            act, mean = acts[i], float(means[i])
            if hasattr(st.executor, "power_terms"):
                power_w, dyn_w = st.executor.power_terms(act.bs, act.mtl)
            else:
                power_w = dm.power(st.executor.device, st.executor.profile,
                                   act.bs, act.mtl)
                dyn_w = power_w - st.executor.device.idle_w
            items_per_step = act.bs * act.mtl
            r = min(items_per_step, 64)
            sampler = st.executor.sampler
            rng = sampler.rng
            sigma = sampler.sigma
            spike_p, spike_mult = sampler.spike_p, sampler.spike_mult
            clock = float(sim.clock[i])
            slo = st.job.slo_s
            job_steps = 0
            while clock < sim_time_limit and steps_total < max_steps:
                want = (sim_time_limit - clock) / mean
                n_est = min(int(want * 1.05) + 8, max_steps - steps_total)
                # the per-step latency law of LatencySampler.sample,
                # drawn for a whole chunk at once
                lats = mean * np.exp(rng.normal(0.0, sigma, n_est))
                lats[rng.random(n_est) < spike_p] *= spike_mult
                starts = clock + np.concatenate(
                    ([0.0], np.cumsum(lats[:-1])))
                # a step is served iff it STARTS before the horizon —
                # the reference's `t >= sim_time_limit` skip
                n_acc = int(np.searchsorted(starts, sim_time_limit,
                                            side="left"))
                all_accepted = n_acc == n_est
                lats = lats[:n_acc]
                if n_acc:
                    # request latencies: lognormal + spikes around each
                    # accepted step's sampled latency (run_step's law)
                    req = lats[:, None] * np.exp(
                        rng.normal(0.0, sigma, (n_acc, r)))
                    req[rng.random((n_acc, r)) < spike_p] *= spike_mult
                    busy = float(lats.sum())
                    st.acc.record_bulk(items=items_per_step * n_acc,
                                       busy_s=busy,
                                       energy_j=power_w * busy,
                                       request_latencies=req, slo=slo)
                    self._dev_dynamic_j[self.placement[i]] += dyn_w * busy
                    if self.power_price_fn is not None:
                        self._dynamic_cost_usd += \
                            self._power_price(clock) * dyn_w * busy
                    clock += busy
                    st.executor.clock += busy
                    job_steps += n_acc
                    steps_total += n_acc
                if not all_accepted:
                    break
            sim.clock[i] = clock
            sim.arrival_mark[i] = clock
            sim.submitted[i] += items_per_step * job_steps
            sim.completed[i] += items_per_step * job_steps
            st.prev = act
            sim.feasible_at_serve[i] = 1 if self._feasible_now(i) else 0
        return steps_total

    def _bulk_vector(self, acts, means, sim_time_limit: float,
                     max_steps: int) -> int:
        """The whole FLEET advances per round: one (jobs x chunk) draw
        replaces the per-job Python chunk loop (the >10k-device follow-up).
        Same latency law per step as `_bulk_jobloop`; statistically
        equivalent, not bit-identical — per-job sampler streams are
        replaced by one fleet-level stream (one generator call per round
        instead of four per job), and each job's request-latency block is
        a slice of one pooled draw.  The global `max_steps` budget is
        consumed in job order, matching the loop's truncation shape."""
        sim = self._sim
        n = len(self.states)
        means = np.asarray(means, np.float64)
        items_per_step = np.asarray([a.bs * a.mtl for a in acts], np.int64)

        def _terms(i, st):
            if hasattr(st.executor, "power_terms"):
                return st.executor.power_terms(acts[i].bs, acts[i].mtl)
            w = dm.power(st.executor.device, st.executor.profile,
                         acts[i].bs, acts[i].mtl)
            return w, w - st.executor.device.idle_w

        terms = [_terms(i, st) for i, st in enumerate(self.states)]
        power_w = np.asarray([t[0] for t in terms], np.float64)
        dyn_w = np.asarray([t[1] for t in terms], np.float64)
        sigma = np.asarray([st.executor.sampler.sigma
                            for st in self.states], np.float64)
        spike_p = np.asarray([st.executor.sampler.spike_p
                              for st in self.states], np.float64)
        spike_mult = np.asarray([st.executor.sampler.spike_mult
                                 for st in self.states], np.float64)
        slo = np.asarray([st.job.slo_s for st in self.states], np.float64)
        r = np.minimum(items_per_step, 64).astype(np.int64)
        rng = np.random.default_rng(self.seed ^ 0x5BD1E995)
        clock = sim.clock[:n].astype(np.float64).copy()
        job_steps = np.zeros(n, np.int64)
        steps_total = 0
        active = clock < sim_time_limit
        while active.any() and steps_total < max_steps:
            idx = np.flatnonzero(active)
            m = len(idx)
            want = (sim_time_limit - clock[idx]) / means[idx]
            n_est = np.minimum((want * 1.05).astype(np.int64) + 8,
                               max_steps - steps_total)
            k = int(n_est.max())
            lats = means[idx][:, None] * np.exp(
                rng.normal(0.0, 1.0, (m, k)) * sigma[idx][:, None])
            lats = np.where(rng.random((m, k)) < spike_p[idx][:, None],
                            lats * spike_mult[idx][:, None], lats)
            colmask = np.arange(k)[None, :] < n_est[:, None]
            starts = clock[idx][:, None] + np.cumsum(lats, axis=1) - lats
            # a step is served iff it STARTS before the horizon; starts are
            # monotone per row, so acceptance is a per-row prefix
            accept = (starts < sim_time_limit) & colmask
            n_acc = accept.sum(axis=1)
            budget = max_steps - steps_total
            cum = np.cumsum(n_acc)
            if cum[-1] > budget:          # clip in job order, like the loop
                j = int(np.argmax(cum > budget))
                n_acc[j] = budget - (int(cum[j]) - int(n_acc[j]))
                n_acc[j + 1:] = 0
            tot = int(n_acc.sum())
            if tot:
                rmax = int(r[idx].max())
                # one pooled request-latency draw; each job slices its rows
                # and its first r columns (run_step's lognormal + spikes)
                zreq = rng.normal(0.0, 1.0, (tot, rmax))
                ureq = rng.random((tot, rmax))
                row0 = 0
                for pos in range(m):
                    na = int(n_acc[pos])
                    if na == 0:
                        continue
                    i = int(idx[pos])
                    st = self.states[i]
                    li = lats[pos, :na]
                    ri = int(r[i])
                    req = li[:, None] * np.exp(
                        zreq[row0:row0 + na, :ri] * sigma[i])
                    req = np.where(ureq[row0:row0 + na, :ri] < spike_p[i],
                                   req * spike_mult[i], req)
                    busy = float(li.sum())
                    st.acc.record_bulk(items=int(items_per_step[i]) * na,
                                       busy_s=busy,
                                       energy_j=power_w[i] * busy,
                                       request_latencies=req, slo=slo[i])
                    self._dev_dynamic_j[self.placement[i]] += \
                        float(dyn_w[i]) * busy
                    if self.power_price_fn is not None:
                        self._dynamic_cost_usd += self._power_price(
                            float(clock[i])) * float(dyn_w[i]) * busy
                    clock[i] += busy
                    st.executor.clock += busy
                    job_steps[i] += na
                    row0 += na
                steps_total += tot
            # a job whose whole chunk was accepted may still owe steps
            # before the horizon; everyone else is done
            active[idx] = (n_acc == n_est) & (clock[idx] < sim_time_limit)
            if steps_total >= max_steps:
                break
        sim.clock[:n] = clock
        sim.arrival_mark[:n] = clock
        sim.submitted[:n] += items_per_step * job_steps
        sim.completed[:n] += items_per_step * job_steps
        for i, st in enumerate(self.states):
            st.prev = acts[i]
            sim.feasible_at_serve[i] = 1 if self._feasible_now(i) else 0
        return steps_total


# ---------------------------------------------------------------------------
# The first-class scenario: the paper's 30 jobs as one cluster workload.
# ---------------------------------------------------------------------------
def paper_controller_factory(mode: str = "auto", *, max_mtl: int = 10,
                             library_jobs: int = 8, surface=None,
                             share_ladder=None):
    """Factory of per-job controllers for `ClusterEngine`.

    mode: "auto" (the paper's B-or-MT pick), "hybrid", "B", "MT" — all via
    DNNScalerController — or "clipper".  The matrix-completion estimator is
    seeded with a shared library of 'historically profiled' jobs, exactly
    like the single-job launchers do.  `surface` optionally shares one
    `SurfaceLibrary` across every controller the factory makes: each
    controller's probes feed the jobs x knobs matrix (keyed by job_id,
    the convention `ClusterEngine._predicted_steady` queries), and new
    controllers seed their HybridScaler from its completion."""
    from repro.core.controller import ClipperController, DNNScalerController
    from repro.core.matrix_completion import LatencyEstimator
    from repro.serving.workload import PAPER_JOBS

    mtls = list(range(1, max_mtl + 1))
    library = []
    for j in PAPER_JOBS[:library_jobs]:
        # whole MTL curve priced in one vectorized call (mt_latency_grid)
        curve = dm.mt_latency_curve(dm.TESLA_P40, j.profile(), 1, mtls)
        library.append((j.job_id, dict(zip(mtls, curve))))

    def make(job, executor):
        if mode == "clipper":
            return ClipperController(job.slo_s)
        # on a TPU submesh the MTL knob cannot exceed the replica's chip
        # count — an estimate past it would send the scaler into the
        # infeasible (inf-latency) region and poison the job clock
        cap = max_mtl
        if getattr(executor, "mesh_shape", None) is not None:
            cap = max(1, min(cap, tenancy.max_tenancy(executor.mesh_shape)))
        est = LatencyEstimator(max_mtl=cap)
        for jid, row in library:
            if jid != job.job_id:    # never leak the served job's own
                est.add_library_row(row)   # ground-truth curve (held-out,
                                           # like build_library's exclude_id)
        return DNNScalerController(executor, job.slo_s, estimator=est,
                                   max_mtl=cap, mode=mode,
                                   surface_library=surface,
                                   surface_key=job.job_id,
                                   share_ladder=share_ladder)

    return make


def run_paper_cluster(mode: str = "auto", *, jobs: Optional[Sequence] = None,
                      fleet: Optional[Sequence[DeviceSpec]] = None,
                      n_devices: int = 12, sim_time_limit: float = 90.0,
                      arrival_rates: Optional[dict] = None,
                      seed: int = 0, vectorized: bool = False,
                      record: Optional[str] = None,
                      record_store=None) -> dict:
    """Serve the Table-4 jobs on a simulated fleet under one policy."""
    from repro.serving.workload import PAPER_JOBS
    jobs = list(jobs) if jobs is not None else list(PAPER_JOBS)
    fleet = list(fleet) if fleet is not None else gpu_fleet(n_devices)
    cls = VectorClusterEngine if vectorized else ClusterEngine
    eng = cls(jobs, fleet,
              controller_factory=paper_controller_factory(mode),
              arrival_rates=arrival_rates, seed=seed,
              record=record, record_store=record_store,
              record_meta={"entry": "paper", "mode": mode})
    rep = eng.run(sim_time_limit=sim_time_limit)
    rep["aggregate"]["mode"] = mode
    return rep


CHURN_POLICIES = ("union", "dynamic", "surface")


def run_churn_cluster(policy: str = "surface", *,
                      trace: Optional[Sequence[ChurnJob]] = None,
                      fleet: Optional[Sequence[DeviceSpec]] = None,
                      n_devices: int = 5, horizon_s: float = 150.0,
                      mode: str = "hybrid", seed: int = 0,
                      trace_kwargs: Optional[dict] = None,
                      profile_store=None, vectorized: bool = False,
                      power_policy: Optional[str] = None,
                      preemptions: Optional[Sequence] = None,
                      record: Optional[str] = None,
                      record_store=None) -> dict:
    """The churn scenario under one placement policy.

    policy: "union"   — static placement over the union of every tenancy
                        that ever appears (the over-provisioned baseline);
            "dynamic" — online admission/draining with migration-aware
                        re-placement anticipating the analytic steady state;
            "surface" — dynamic plus the cross-job SurfaceLibrary (probed
                        points pooled across jobs; new admissions seed from
                        the soft-impute completion).

    `profile_store` (surface policy) reloads prior runs' persisted surface
    rows at construction and persists this run's rows at the end — the
    cross-run warm start."""
    if policy not in CHURN_POLICIES:
        raise ValueError(f"unknown churn policy {policy!r}")
    from repro.core.matrix_completion import SurfaceLibrary
    from repro.serving.workload import churn_trace
    if trace is None:
        trace = churn_trace(horizon_s=horizon_s, seed=seed,
                            **(trace_kwargs or {}))
    fleet = list(fleet) if fleet is not None else gpu_fleet(n_devices)
    lib = SurfaceLibrary() if policy == "surface" else None
    cls = VectorClusterEngine if vectorized else ClusterEngine
    eng = cls(
        [], fleet, churn=trace,
        controller_factory=paper_controller_factory(mode, surface=lib),
        static_union=(policy == "union"),
        anticipate=(policy != "union"),
        surface_library=lib, seed=seed,
        profile_store=(profile_store if policy == "surface" else None),
        power_policy=power_policy, preemptions=preemptions,
        record=record, record_store=record_store,
        record_meta={"entry": "churn", "policy": policy, "mode": mode})
    rep = eng.run(sim_time_limit=horizon_s)
    rep["aggregate"]["policy"] = policy
    rep["aggregate"]["mode"] = mode
    if eng.store_report is not None:
        rep["aggregate"]["store_rows_loaded"] = len(
            eng.store_report["loaded"])
        rep["aggregate"]["store_rows_evicted"] = len(
            eng.store_report["evicted"])
    return rep


PARTITION_POLICIES = ("uniform", "het", "het-mig")


def run_partition_cluster(policy: str = "het", *,
                          trace: Optional[Sequence[ChurnJob]] = None,
                          fleet: Optional[Sequence[DeviceSpec]] = None,
                          n_devices: int = 3, horizon_s: float = 120.0,
                          mode: str = "hybrid", seed: int = 0,
                          trace_kwargs: Optional[dict] = None,
                          profile_store=None, vectorized: bool = False,
                          power_policy: Optional[str] = None,
                          preemptions: Optional[Sequence] = None,
                          record: Optional[str] = None,
                          record_store=None) -> dict:
    """The spatial-partitioning scenario on a mixed small/large-DNN trace.

    policy: "uniform" — the existing dynamic churn engine: co-residents
                        each time-share an equal 1/k slice and every share
                        change is a kill+relaunch migration (the uniform
                        MTL baseline);
            "het"     — MPS-style spatial partitions: heterogeneous shares
                        per tenant, the HybridScaler's third (share) axis
                        active, and churn handled by cheap partition
                        RESIZES instead of migrations;
            "het-mig" — the same with MIG-grid discrete shares (hardware
                        isolation, shares snapped onto the profile grid).
    """
    if policy not in PARTITION_POLICIES:
        raise ValueError(f"unknown partition policy {policy!r}")
    from repro.serving.workload import mixed_partition_trace
    if trace is None:
        trace = mixed_partition_trace(horizon_s=horizon_s, seed=seed,
                                      **(trace_kwargs or {}))
    fleet = list(fleet) if fleet is not None else gpu_fleet(n_devices)
    kind = {"uniform": "mps", "het": "mps", "het-mig": "mig"}[policy]
    uniform = policy == "uniform"
    ladder = None if uniform else pt.share_ladder(kind)
    cls = VectorClusterEngine if vectorized else ClusterEngine
    eng = cls(
        [], fleet, churn=trace,
        controller_factory=paper_controller_factory(mode,
                                                    share_ladder=ladder),
        partition=kind, partition_uniform=uniform, seed=seed,
        profile_store=profile_store,
        power_policy=power_policy, preemptions=preemptions,
        record=record, record_store=record_store,
        record_meta={"entry": "partition", "policy": policy, "mode": mode})
    rep = eng.run(sim_time_limit=horizon_s)
    rep["aggregate"]["policy"] = policy
    rep["aggregate"]["mode"] = mode
    return rep


SCENARIO_TRAFFICS = ("steady", "diurnal", "flash")


def spot_fleet(n: int, n_spot: int,
               device: dm.Device = dm.TESLA_P40) -> List[DeviceSpec]:
    """A fleet whose LAST `n_spot` devices are preemptible spot capacity
    (`workload.spot_revocation_trace` targets the spot-flagged members)."""
    out = []
    for i in range(n):
        dev = (dataclasses.replace(device, spot=True)
               if i >= n - n_spot else device)
        out.append(DeviceSpec(device=dev, name=f"{device.name}/{i}"))
    return out


def run_scenario_cluster(traffic: str = "steady", *,
                         spot: bool = False,
                         power_policy: Optional[str] = None,
                         fleet: Optional[Sequence[DeviceSpec]] = None,
                         n_devices: int = 4, n_spot: int = 1,
                         horizon_s: float = 150.0, max_mtl: int = 2,
                         mode: str = "hybrid", seed: int = 0,
                         vectorized: bool = False,
                         trace: Optional[Sequence[ChurnJob]] = None,
                         preemptions: Optional[Sequence] = None,
                         trace_kwargs: Optional[dict] = None,
                         record: Optional[str] = None,
                         record_store=None,
                         power_price_fn: Optional[Callable] = None) -> dict:
    """One cell of the scenario matrix: {steady, diurnal, flash-crowd}
    traffic x {fixed, spot} capacity x {None, pack, spread} packing —
    served by the MPS partition planner with the HybridScaler's share
    axis active.  Spot cells revoke each spot device once mid-run (with
    a restore), exercising evacuation under the traffic shape; the
    report's `energy_j` / `joules_per_good_request` expose what the
    packing objective buys at the diurnal trough.

    `power_price_fn` (time -> $/J) arms carbon-aware pricing: the report
    gains `power_cost_usd` / `cost_per_good_request` (the signal
    integrated over each device's powered intervals plus per-step dynamic
    joules), and a `pack` fleet defers power-gating consolidation while
    the price sits at or below half the signal's mean."""
    from repro.serving.workload import (scenario_trace,
                                        spot_revocation_trace)
    if traffic not in SCENARIO_TRAFFICS:
        raise ValueError(f"unknown scenario traffic {traffic!r}")
    if fleet is None:
        fleet = (spot_fleet(n_devices, n_spot) if spot
                 else gpu_fleet(n_devices))
    else:
        fleet = list(fleet)
    if trace is None:
        trace = scenario_trace(traffic=traffic, horizon_s=horizon_s,
                               seed=seed, **(trace_kwargs or {}))
    if spot and preemptions is None:
        preemptions = spot_revocation_trace(fleet, horizon_s=horizon_s,
                                            seed=seed)
    cls = VectorClusterEngine if vectorized else ClusterEngine
    # max_mtl is capped well below the paper's 10: on a fractional MPS
    # slice the share axis replaces deep MTL climbs, and every avoided
    # instance launch is 2 s of adaptation stall the attainment gate
    # would otherwise charge to queued requests
    eng = cls(
        [], fleet, churn=trace,
        controller_factory=paper_controller_factory(
            mode, max_mtl=max_mtl, share_ladder=pt.share_ladder("mps")),
        partition="mps", seed=seed,
        power_policy=power_policy, preemptions=preemptions,
        power_price_fn=power_price_fn,
        record=record, record_store=record_store,
        record_meta={"entry": "scenario", "traffic": traffic,
                     "spot": bool(spot), "power_policy": power_policy,
                     "max_mtl": int(max_mtl), "mode": mode})
    rep = eng.run(sim_time_limit=horizon_s)
    agg = rep["aggregate"]
    agg["mode"] = mode
    agg["traffic"] = traffic
    agg["spot"] = bool(spot)
    return rep
