"""TPU tenancy planner — the pod-scale translation of GPU multi-tenancy.

A TPU core runs one program at a time, so "co-locating MTL instances" maps to
partitioning the pod slice into MTL disjoint submeshes, each hosting one
replica (DESIGN.md §2).  The planner chooses balanced submesh shapes and the
SimExecutor prices each replica at its fractional device share.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class TenancyPlan:
    mtl: int
    total: tuple            # full mesh shape, e.g. (16, 16)
    replica_shape: tuple    # submesh per replica
    replicas: int

    @property
    def share(self) -> float:
        full = 1
        for s in self.total:
            full *= s
        per = 1
        for s in self.replica_shape:
            per *= s
        return per / full


def plan(mesh_shape: tuple, mtl: int) -> Optional[TenancyPlan]:
    """Split (data, model) into `mtl` balanced submeshes.

    Prefers splitting the data axis (keeps per-replica TP intact), then the
    model axis.  Returns None when mtl doesn't divide the mesh.
    """
    data, model = mesh_shape[-2], mesh_shape[-1]
    d, m, rem = data, model, mtl
    # peel factors off the data axis first
    for axis in range(2):
        cur = d if axis == 0 else m
        f = _gcd_factor(cur, rem)
        if axis == 0:
            d //= f
        else:
            m //= f
        rem //= f
    if rem != 1:
        return None
    return TenancyPlan(mtl=mtl, total=(data, model),
                       replica_shape=(d, m), replicas=mtl)


def plan_at_least(mesh_shape: tuple, mtl: int) -> Optional[TenancyPlan]:
    """Smallest feasible split into >= mtl submeshes.

    A non-divisor MTL over-partitions: the slice is cut into the next
    feasible number of equal submeshes and the surplus ones sit idle —
    you cannot carve 256 chips into 3 equal submeshes, so you take the
    4-way split and run 3 replicas.  Returns None only when mtl exceeds
    the chip count."""
    total = mesh_shape[-2] * mesh_shape[-1]
    for k in range(mtl, total + 1):
        p = plan(mesh_shape, k)
        if p is not None:
            return dataclasses.replace(p, mtl=mtl)
    return None


def _gcd_factor(n: int, k: int) -> int:
    """Largest divisor of n that also divides k."""
    best = 1
    for f in range(1, min(n, k) + 1):
        if n % f == 0 and k % f == 0:
            best = f
    return best


def max_tenancy(mesh_shape: tuple) -> int:
    data, model = mesh_shape[-2], mesh_shape[-1]
    return data * model
