"""Analytical accelerator model used by SimExecutor.

This container is CPU-only, so the paper's wall-clock measurements are
replaced by a first-principles *pipeline* model.  The mechanisms are the ones
the paper itself identifies (§2): per-image host work (decode / resize /
HtoD copy / redzone checks) that does NOT amortize with batch size and gets
*worse* superlinearly ("share ... becomes even more when increasing the batch
size"), vs. GPU kernel time that amortizes with batch only for nets with
large dense kernels (weight reuse), and is time-shared across co-located
instances while host pipelines run in parallel processes.

Per job profile (all per-image, milliseconds):
    host    — serial host-side time; parallel across instances
    gpu1    — GPU time at BS=1 (launch floor + under-filled kernels)
    amort   — batch amortization exponent of GPU time
    steady  — flops / (0.75 * peak): the roofline floor per image

Latency laws:
    rho(BS)          = 1 + BS/256                      (copy-pressure)
    gpu_img(BS)      = max(steady, gpu1 * BS^-amort)
    lat_B(BS)        = BS * (host * rho(BS) + gpu_img(BS))
    lat_MT(m) (inst) = host * (1 + chi*(m-1)) + m * gpu1 * (1 + eps*(m-1))
                        (GPU serialized; hosts parallel with contention chi)

Throughput_B = BS / lat_B;  Throughput_MT = m / lat_MT.

Calibration: where the paper's Table 5 reports (base, MTL=8, BS=32)
throughputs, (host, gpu1, amort) are grid-fit to those three numbers — i.e.
the simulator is calibrated against the paper's own measurements, exactly as
one would calibrate against profiling runs on the real GPU.  Every other
behavior (Profiler decisions, Scaler dynamics, Clipper comparison) emerges
from the model; nothing about the paper's *conclusions* is hard-coded.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import numpy as np

EPS_MT = 0.02      # GPU time-sharing interference per extra instance
CHI_HOST = 0.06    # host contention per extra instance
STEADY_EFF = 0.75  # MXU/SM efficiency at large batch


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    peak_flops: float
    hbm_bw: float
    hbm_bytes: float
    idle_w: float
    peak_w: float
    # preemptible (spot) capacity: the provider may revoke the device with a
    # grace-window deadline mid-run (workload.Preemption drives the event)
    spot: bool = False

    def share(self, frac: float) -> "Device":
        return dataclasses.replace(
            self, peak_flops=self.peak_flops * frac, hbm_bw=self.hbm_bw * frac,
            hbm_bytes=self.hbm_bytes * frac)


TESLA_P40 = Device("tesla-p40", 11.76e12, 346e9, 24e9, 50.0, 250.0)
TPU_V5E = Device("tpu-v5e", 197e12, 819e9, 16e9, 60.0, 220.0)


# ---------------------------------------------------------------------------
# Interconnect model (the KV-transfer fabric's link classes).
#
# Disaggregated prefill/decode serving moves finished KV caches between
# devices; each link class is a bandwidth plus a per-transfer latency floor
# (setup, routing, the first-byte cost a tiny transfer cannot amortize):
#
#     transfer_s(bytes) = latency_s + bytes / bw_bps
#
# DCN reuses the 8 GB/s TPU checkpoint-transfer constant the cluster engine
# already charges for submesh checkpoint moves (cluster.CKPT_TRANSFER_BPS) —
# the same wire carries both.
# ---------------------------------------------------------------------------
DCN_BPS = 8e9   # == cluster.CKPT_TRANSFER_BPS (checkpoint moves share the wire)


@dataclasses.dataclass(frozen=True)
class Interconnect:
    name: str
    bw_bps: float       # sustained link bandwidth
    latency_s: float    # per-transfer latency floor

    def transfer_s(self, nbytes: float) -> float:
        """Seconds to move `nbytes` over this link (the analytic fabric
        formula the KVTransferFabric accounting is pinned against)."""
        return self.latency_s + nbytes / self.bw_bps


NVLINK = Interconnect("nvlink", 300e9, 5e-6)
PCIE_4 = Interconnect("pcie4", 32e9, 20e-6)
ICI = Interconnect("ici", 100e9, 10e-6)          # TPU inter-chip interconnect
DCN = Interconnect("dcn", DCN_BPS, 1e-3)         # cross-host data-center net

INTERCONNECTS = {ic.name: ic for ic in (NVLINK, PCIE_4, ICI, DCN)}

# per-device-class link used for same-pool KV handoff (P40 boards have no
# NVLink; v5e pods move KV over ICI); unknown classes fall back to DCN
_DEVICE_INTERCONNECT = {
    "tesla-p40": "pcie4",
    "tpu-v5e": "ici",
}


def interconnect_for(device_name: str) -> Interconnect:
    """The KV-handoff link for one device class (DCN when unknown)."""
    return INTERCONNECTS[_DEVICE_INTERCONNECT.get(device_name, "dcn")]


def kv_transfer_time(ic: Interconnect, nbytes: float) -> float:
    """Module-level alias of `Interconnect.transfer_s` (test surface)."""
    return ic.transfer_s(nbytes)


@dataclasses.dataclass(frozen=True)
class JobProfile:
    name: str
    host_ms: float            # per-image serial host time
    gpu1_ms: float            # per-image GPU time at BS=1
    amort: float              # GPU batch-amortization exponent
    flops: float              # per-image FLOPs (sets the steady floor)
    param_bytes: float
    input_bytes: float = 600e3
    # token-engine decode jobs only (0.0 = classic whole-request batching):
    kv_bytes_per_item: float = 0.0   # paged-KV reservation per live slot
    prefill_ms: float = 0.0          # prompt-processing time (the TTFT term)

    def steady_ms(self, dev: Device) -> float:
        comp = self.flops / (dev.peak_flops * STEADY_EFF)
        mem = self.param_bytes / dev.hbm_bw / 32.0   # weights amortized
        return max(comp, mem) * 1e3

    @property
    def occupancy(self) -> float:
        """GPU-busy fraction of a single instance at BS=1."""
        return self.gpu1_ms / (self.host_ms + self.gpu1_ms)


def rho(bs):
    """Copy-pressure factor; polymorphic over scalars and np arrays."""
    return 1.0 + bs / 128.0


def gpu_img_ms(prof: JobProfile, bs: int, dev: Device) -> float:
    return float(gpu_img_ms_grid(prof, bs, dev))


def batch_latency(dev: Device, prof: JobProfile, bs: int,
                  share: float = 1.0) -> float:
    """Seconds for one batch of `bs` on one instance (MTL=1).  `share` < 1
    prices a fractional device slice (TPU submesh tenancy)."""
    return float(batch_latency_grid(dev, prof, bs, share=share))


def step_latency(dev: Device, prof: JobProfile, bs: int,
                 share: float = 1.0) -> dict:
    """Latency breakdown for one batch on a (possibly fractional) device.

    `share` < 1 prices a submesh / device slice (TPU tenancy, cluster
    co-location).  `t_step` equals batch_latency(dev, prof, bs, share)."""
    g = step_latency_grid(dev, prof, bs, share=share)
    return {"t_step": float(g["t_step"]), "t_host": float(g["t_host"]),
            "t_gpu": float(g["t_gpu"]), "share": share}


def mt_latency(dev: Device, prof: JobProfile, bs: int, mtl: int) -> float:
    """Per-instance step latency (seconds) with mtl co-located instances."""
    if mtl <= 1:                 # no co-residents: identical to one batch
        return batch_latency(dev, prof, bs)
    return float(mt_latency_grid(dev, prof, [bs], [mtl])[0, 0])


def mt_throughput(dev: Device, prof: JobProfile, bs: int, mtl: int) -> float:
    return mtl * bs / mt_latency(dev, prof, bs, mtl)


# ---------------------------------------------------------------------------
# Batched pricing: whole (bs, mtl) grids in one vectorized call — used by
# HybridScaler surface seeding, matrix-completion library seeding, and the
# Table-5 profile fit, instead of Python double loops.  These ARE the
# pricing formulas; the scalar functions above are size-1 views of them.
# ---------------------------------------------------------------------------
def gpu_img_ms_grid(prof: JobProfile, bs, dev: Device) -> np.ndarray:
    bs = np.asarray(bs, np.float64)
    return np.maximum(prof.steady_ms(dev), prof.gpu1_ms * bs ** (-prof.amort))


def batch_latency_grid(dev: Device, prof: JobProfile, bs,
                       share: float = 1.0) -> np.ndarray:
    """`batch_latency` over an array of batch sizes (seconds)."""
    d = dev if share == 1.0 else dev.share(share)
    bs = np.asarray(bs, np.float64)
    return bs * (prof.host_ms * rho(bs) + gpu_img_ms_grid(prof, bs, d)) / 1e3


def step_latency_grid(dev: Device, prof: JobProfile, bs,
                      share: float = 1.0) -> dict:
    """`step_latency` over an array of batch sizes (dict of arrays)."""
    d = dev if share == 1.0 else dev.share(share)
    bs = np.asarray(bs, np.float64)
    t_host = bs * prof.host_ms * rho(bs) / 1e3
    t_gpu = bs * gpu_img_ms_grid(prof, bs, d) / 1e3
    return {"t_step": t_host + t_gpu, "t_host": t_host, "t_gpu": t_gpu,
            "share": share}


def mt_latency_grid(dev: Device, prof: JobProfile, bs, mtl) -> np.ndarray:
    """Per-instance step latency (seconds) over the full outer grid —
    shape (len(bs), len(mtl)); row i, column j prices (bs[i], mtl[j]).
    The mtl=1 column equals `batch_latency_grid` term for term."""
    bs = np.asarray(bs, np.float64)[:, None]
    m = np.asarray(mtl, np.float64)[None, :]
    host = prof.host_ms * rho(bs) * (1.0 + CHI_HOST * (m - 1.0))
    gpu = gpu_img_ms_grid(prof, bs, dev) * m * (1.0 + EPS_MT * (m - 1.0))
    return bs * (host + gpu) / 1e3


def mt_latency_curve(dev: Device, prof: JobProfile, bs: int, mtls) -> np.ndarray:
    """1-D convenience: latency at one batch size over an array of MTLs."""
    return mt_latency_grid(dev, prof, [bs], mtls)[0]


def fleet_step_latency(devices, profiles, bs, mtl) -> np.ndarray:
    """Per-instance step latency for a whole FLEET in one call: job i runs
    (bs[i], mtl[i]) with profiles[i] on devices[i] (each job's OWN
    share-adjusted device), shape (n_jobs,).  This is `mt_latency`
    broadcast over jobs instead of over knobs — the one pricing round the
    vectorized cluster path makes per event round, in place of n_jobs
    scalar calls.  The expressions are term-for-term the grid formulas
    above (steady_ms, gpu_img, rho, the MT host/GPU interference), so at
    mtl=1 the result equals `batch_latency` up to exact IEEE identities
    (x * 1.0 == x)."""
    bs = np.asarray(bs, np.float64)
    m = np.asarray(mtl, np.float64)
    peak = np.asarray([d.peak_flops for d in devices], np.float64)
    bw = np.asarray([d.hbm_bw for d in devices], np.float64)
    host_ms = np.asarray([p.host_ms for p in profiles], np.float64)
    gpu1_ms = np.asarray([p.gpu1_ms for p in profiles], np.float64)
    amort = np.asarray([p.amort for p in profiles], np.float64)
    flops = np.asarray([p.flops for p in profiles], np.float64)
    pbytes = np.asarray([p.param_bytes for p in profiles], np.float64)
    steady_ms = np.maximum(flops / (peak * STEADY_EFF),
                           pbytes / bw / 32.0) * 1e3
    gpu_img = np.maximum(steady_ms, gpu1_ms * bs ** (-amort))
    host = host_ms * rho(bs) * (1.0 + CHI_HOST * (m - 1.0))
    gpu = gpu_img * m * (1.0 + EPS_MT * (m - 1.0))
    return bs * (host + gpu) / 1e3


# ---------------------------------------------------------------------------
# Spatial-partition pricing (serving/partition.py's third knob).
#
# A tenant holds a spatial slice of the device — an MPS compute percentage
# or a MIG/submesh hardware partition — instead of time-sharing the whole
# GPU.  Its kernels run `inv_share` (= 1/share) times longer on the smaller
# slice, and MPS-style sharing adds the SAME per-co-resident interference
# the paper's MTL curves measure for time-slicing (shared HBM/L2 and host
# contention), while isolated backends (MIG slices, disjoint TPU submeshes)
# suppress the cross-tenant terms.
#
# Calibration anchor: with `tenants` uniform tenants at share = 1/tenants
# (mtl = 1, isolation = 0) the formula reproduces `mt_latency_grid` at
# MTL = tenants BIT-IDENTICALLY — spatial multiplexing at equal aggregate
# share is pinned to the paper's measured multi-tenancy curves, and the
# partition model only diverges where it has something new to say
# (heterogeneous shares, hardware isolation).  The within-tenant `mtl`
# knob co-locates the tenant's own instances inside its slice, composing
# the same way MTL composes on a whole device.
# ---------------------------------------------------------------------------
def part_latency_grid(dev: Device, prof: JobProfile, bs, mtl, *,
                      inv_share: float = 1.0, tenants: int = 1,
                      isolation: float = 0.0) -> np.ndarray:
    """Per-instance step latency (seconds) over the (bs, mtl) grid for one
    tenant holding a 1/inv_share compute slice among `tenants` co-resident
    spatial tenants.  `isolation` in [0, 1] scales away the cross-tenant
    interference terms (0 = MPS shared paths, 1 = MIG/submesh isolation).
    inv_share=1, tenants=1 equals `mt_latency_grid` term for term."""
    bs = np.asarray(bs, np.float64)[:, None]
    m = np.asarray(mtl, np.float64)[None, :]
    x = (m - 1.0) + (1.0 - isolation) * (tenants - 1.0)
    host = prof.host_ms * rho(bs) * (1.0 + CHI_HOST * x)
    gpu = gpu_img_ms_grid(prof, bs, dev) * (inv_share * m) * (1.0 + EPS_MT * x)
    return bs * (host + gpu) / 1e3


def part_latency(dev: Device, prof: JobProfile, bs: int, mtl: int, *,
                 inv_share: float = 1.0, tenants: int = 1,
                 isolation: float = 0.0) -> float:
    return float(part_latency_grid(dev, prof, [bs], [mtl],
                                   inv_share=inv_share, tenants=tenants,
                                   isolation=isolation)[0, 0])


def part_throughput_grid(dev: Device, prof: JobProfile, bs, mtl, *,
                         inv_share: float = 1.0, tenants: int = 1,
                         isolation: float = 0.0) -> np.ndarray:
    bs_ = np.asarray(bs, np.float64)[:, None]
    m_ = np.asarray(mtl, np.float64)[None, :]
    return (m_ * bs_) / part_latency_grid(dev, prof, bs, mtl,
                                          inv_share=inv_share,
                                          tenants=tenants,
                                          isolation=isolation)


def part_throughput(dev: Device, prof: JobProfile, bs: int, mtl: int, *,
                    inv_share: float = 1.0, tenants: int = 1,
                    isolation: float = 0.0) -> float:
    return mtl * bs / part_latency(dev, prof, bs, mtl, inv_share=inv_share,
                                   tenants=tenants, isolation=isolation)


def token_latency_grid(dev: Device, prof: JobProfile, slots, mtl, *,
                       inv_share: float = 1.0, tenants: int = 1,
                       isolation: float = 0.0) -> np.ndarray:
    """Decode-STEP latency (seconds) over the (live_slots, mtl) grid for a
    continuous-batching tenant holding a 1/inv_share slice among `tenants`
    co-residents (e.g. a co-scheduled prefill tenant).

    A decode step with s live slots is a batch of s single-token requests —
    same weight stream, same per-item host dispatch — so the step is priced
    by the SAME calibrated law as a bs=s batch: every Table-5 / llm_profile
    anchor carries over, and `bs` reinterpreted as max-live-slots rides the
    existing scaler machinery unchanged.  TPOT at s slots is
    token_latency_grid(...)[s]/1 per token per slot; TTFT adds
    `prof.prefill_ms` and queue wait on top (the token engine's split)."""
    return part_latency_grid(dev, prof, slots, mtl, inv_share=inv_share,
                             tenants=tenants, isolation=isolation)


def mt_throughput_grid(dev: Device, prof: JobProfile, bs, mtl) -> np.ndarray:
    bs_ = np.asarray(bs, np.float64)[:, None]
    m_ = np.asarray(mtl, np.float64)[None, :]
    return (m_ * bs_) / mt_latency_grid(dev, prof, bs, mtl)


def best_feasible_point(latency_s, bs_values, mtl_values,
                        limit_s: float) -> Optional[tuple]:
    """Throughput-optimal grid point under a latency limit.

    `latency_s[i, j]` prices (bs_values[i], mtl_values[j]); returns
    (throughput, bs, mtl) for the feasible point maximizing bs*mtl/lat,
    or None when nothing fits — the one selection shared by steady-state
    anticipation (cluster placement), arrival-rate calibration
    (workload.steady_capacity), and the HybridScaler's surface jump."""
    lat = np.asarray(latency_s, np.float64)
    bs_values = np.asarray(bs_values)
    mtl_values = np.asarray(mtl_values)
    ok = lat <= limit_s
    if not ok.any():
        return None
    thr = np.where(ok, (bs_values[:, None] * mtl_values[None, :]) / lat,
                   0.0)
    i, j = np.unravel_index(int(np.argmax(thr)), thr.shape)
    return float(thr[i, j]), int(bs_values[i]), int(mtl_values[j])


def slice_power(dev: Device, prof: JobProfile, bs: int, mtl: int, *,
                share: float = 1.0, inv_share: Optional[float] = None,
                tenants: int = 1, isolation: float = 0.0) -> float:
    """Power draw (watts) attributed to ONE tenant slice of `dev`.

    The slice owns `share` of the device, so it draws `share` of the idle
    floor plus `share` of the dynamic range scaled by its own GPU-busy
    fraction — a co-resident's draw is its co-resident's business, so
    summing slice_power across tenants no longer multi-counts the device.
    `inv_share`/`tenants`/`isolation` price the busy fraction on the
    partitioned latency law (part_latency); with the defaults this is the
    whole-device formula bit-for-bit (share=1 multiplies by exactly 1.0).

    Invariant (pinned in tests): k uniform tenants at share=1/k, mtl=1,
    isolation=0 sum to power(dev, prof, bs, k) — spatial multiplexing at
    equal aggregate share burns what the paper's MTL curves burn.
    """
    if inv_share is not None and (inv_share != 1.0 or tenants > 1):
        lat = part_latency(dev, prof, bs, mtl, inv_share=inv_share,
                           tenants=tenants, isolation=isolation)
        gpu_busy = bs * gpu_img_ms(prof, bs, dev) * inv_share * mtl / 1e3
    else:
        lat = mt_latency(dev, prof, bs, mtl)
        gpu_busy = bs * gpu_img_ms(prof, bs, dev) * mtl / 1e3
    util = min(1.0, gpu_busy / max(lat, 1e-9))
    return share * (dev.idle_w + (dev.peak_w - dev.idle_w) * util)


def power(dev: Device, prof: JobProfile, bs: int, mtl: int) -> float:
    """Whole-device power draw (watts) — slice_power at full share."""
    return slice_power(dev, prof, bs, mtl)


def fits_memory(dev: Device, prof: JobProfile, bs: int, mtl: int) -> bool:
    # kv_bytes_per_item charges the paged-KV budget of `bs` live decode
    # slots; it defaults to 0.0 so classic profiles price identically
    per_inst = (prof.param_bytes * 1.3 + bs * prof.input_bytes * 8
                + bs * prof.kv_bytes_per_item + 0.4e9)
    return mtl * per_inst <= dev.hbm_bytes


class LatencySampler:
    """Lognormal measurement noise + rare spikes so p95 != mean (OS jitter,
    thermal variation — the tail the paper's Scaler reacts to)."""

    def __init__(self, seed: int = 0, sigma: float = 0.05,
                 spike_p: float = 0.005, spike_mult: float = 2.0):
        self.rng = np.random.default_rng(seed)
        self.sigma = sigma
        self.spike_p = spike_p
        self.spike_mult = spike_mult

    def sample(self, mean_latency: float, n: int = 1) -> np.ndarray:
        base = mean_latency * np.exp(self.rng.normal(0.0, self.sigma, size=n))
        spikes = self.rng.random(n) < self.spike_p
        base[spikes] *= self.spike_mult
        return base


# ---------------------------------------------------------------------------
# Calibration against the paper's own Table 5 (base, MTL=8, BS=32 img/s).
# ---------------------------------------------------------------------------
TABLE5 = {
    # (dnn, dataset): (thr_base, thr_mtl8, thr_bs32)
    ("inception_v1", "imagenet"): (118.66, 237.28, 125.67),
    ("inception_v2", "imagenet"): (104.46, 169.85, 125.33),
    ("inception_v4", "imagenet"): (36.81, 39.61, 116.41),
    ("pnasnet_mobile", "imagenet"): (48.49, 148.28, 125.44),
    ("resnet_v2_50", "imagenet"): (103.62, 137.43, 126.55),
    ("resnet_v2_101", "imagenet"): (62.75, 78.63, 125.99),
    ("inception_v2", "caltech"): (102.82, 169.31, 235.05),
    ("mobilenet_v1_05", "caltech"): (241.14, 1050.58, 267.84),
    ("textclassif", "sentiment140"): (492.00, 2163.80, 7145.89),
    ("deepvs", "ledov"): (15.46, 41.27, 19.82),
}

# (params_M, GFLOPs) public numbers; family defaults (host_ms, gpu1_frac,
# amort) used when a row has no Table-5 calibration point.
NET_SPECS = {
    "inception_v1":    (6.6, 3.0,  4.5, 0.45, 0.10),
    "inception_v2":    (11.2, 4.0, 4.5, 0.50, 0.15),
    "inception_v3":    (23.8, 11.4, 4.5, 0.60, 0.45),
    "inception_v4":    (42.7, 24.6, 5.0, 0.82, 0.58),
    "mobilenet_v1_1":  (4.2, 1.15, 3.3, 0.30, 0.25),
    "mobilenet_v1_05": (1.3, 0.30, 3.3, 0.22, 0.25),
    "mobilenet_v1_025": (0.5, 0.08, 3.3, 0.15, 0.25),
    "mobilenet_v2_1":  (3.5, 0.60, 3.6, 0.28, 0.25),
    "mobilenet_v2_14": (6.1, 1.16, 3.6, 0.32, 0.25),
    "nasnet_large":    (88.9, 47.8, 9.0, 0.75, 0.55),
    "nasnet_mobile":   (5.3, 1.13, 16.0, 0.25, 0.10),
    "pnasnet_large":   (86.1, 50.0, 9.0, 0.75, 0.55),
    "pnasnet_mobile":  (5.1, 1.18, 16.0, 0.25, 0.10),
    "resnet_v2_50":    (25.6, 8.2, 3.3, 0.66, 0.12),
    "resnet_v2_101":   (44.5, 15.6, 4.7, 0.70, 0.42),
    "resnet_v2_152":   (60.2, 22.6, 5.5, 0.72, 0.48),
    "textclassif":     (12.0, 0.06, 1.6, 0.20, 0.60),
    "deepvs":          (55.0, 90.0, 42.0, 0.33, 0.75),
    "deepspeech2":     (120.0, 60.0, 18.0, 0.68, 0.60),
}


def _model_thr(host, gpu1, amort, flops, dev) -> tuple:
    prof = JobProfile("fit", host, gpu1, amort, flops, 1e8)
    base = 1e3 / (host + gpu1)
    mt8 = mt_throughput(dev, prof, 1, 8)
    b32 = 32.0 / (batch_latency(dev, prof, 32) * 1e3) * 1e3
    return base, mt8, b32


@functools.lru_cache(maxsize=None)
def _fit_profile(dnn: str, dataset: str) -> tuple:
    """Grid-fit (host, gpu1, amort) to the Table-5 triple (log-space MSE).

    The whole (host_frac x amort) grid is priced in one vectorized shot
    (the formulas of `_model_thr` element for element); argmin over the
    row-major error surface keeps the first minimum, matching the original
    sequential scan's tie-breaking."""
    params_m, gflops, h0, g0frac, a0 = NET_SPECS[dnn]
    target = TABLE5.get((dnn, dataset))
    if target is None:
        gpu1 = h0 * g0frac / (1 - g0frac)
        return h0, gpu1, a0
    t = np.array(target)
    base_ms = 1e3 / t[0]
    dev = TESLA_P40
    flops = gflops * 1e9
    steady = max(flops / (dev.peak_flops * STEADY_EFF),
                 1e8 / dev.hbm_bw / 32.0) * 1e3
    host = base_ms * np.linspace(0.05, 0.95, 46)[:, None]    # (46, 1)
    gpu1 = base_ms - host
    amort = np.linspace(0.0, 0.95, 39)[None, :]              # (1, 39)
    base = 1e3 / (host + gpu1)
    lat8 = (host * (1.0 + 1 / 128.0) * (1.0 + CHI_HOST * 7)
            + np.maximum(steady, gpu1) * 8 * (1.0 + EPS_MT * 7)) / 1e3
    mt8 = 8 * 1 / lat8
    lat32 = 32 * (host * (1.0 + 32 / 128.0)
                  + np.maximum(steady, gpu1 * 32.0 ** (-amort))) / 1e3
    b32 = 32.0 / (lat32 * 1e3) * 1e3
    err = (np.log(base / t[0]) ** 2 + np.log(mt8 / t[1]) ** 2
           + np.log(b32 / t[2]) ** 2)
    i, j = np.unravel_index(np.argmin(err), err.shape)
    return float(host[i, 0]), float(gpu1[i, 0]), float(amort[0, j])


def paper_profile(name: str, dataset: str = "imagenet") -> JobProfile:
    if name not in NET_SPECS:
        raise KeyError(name)
    params_m, gflops, h0, g0frac, a0 = NET_SPECS[name]
    host, gpu1, amort = _fit_profile(name, dataset)
    if TABLE5.get((name, dataset)) is None and dataset == "caltech":
        # Caltech-256 source images are smaller on average than ImageNet's
        # (cheaper decode+resize); the effect dominates for the cell-based
        # mobile NAS nets whose host share is largest (paper §4.2 observes
        # the same net flipping B<->MT across the two datasets).
        host *= 0.45 if name in ("nasnet_mobile", "pnasnet_mobile") else 0.92
        gpu1 *= 1.02
    if dataset == "imdb":
        # IMDB reviews are ~6x longer than Sentiment140 tweets (paper §4.2:
        # "longer sentences ... take more time to be processed").
        gpu1 *= 6.0
        host *= 1.4
        gflops *= 6.0
    px = 331 if "nasnet" in name or "pnasnet" in name else (
        299 if "v3" in name or "v4" in name else 224)
    return JobProfile(name=f"{name}/{dataset}", host_ms=host, gpu1_ms=gpu1,
                      amort=amort, flops=gflops * 1e9,
                      param_bytes=params_m * 1e6 * 4,
                      input_bytes=px * px * 3 * 4.0)


def kv_cache_bytes(cfg, seq_budget: int, dtype_bytes: int = 2) -> float:
    """Paged-KV bytes one decode slot reserves at its full sequence budget:
    layers x kv_heads x head_dim x 2 (K and V) x seq x dtype."""
    return float(cfg.num_layers * cfg.num_kv_heads * cfg.head_dim
                 * 2 * seq_budget * dtype_bytes)


def llm_profile(cfg, mode: str = "decode", seq: int = 1024,
                dtype_bytes: int = 2, dev: Device = TPU_V5E,
                kv_seq_budget: Optional[int] = None) -> JobProfile:
    """Profile for an assigned architecture served on one TPU v5e chip-group.

    decode is weight-streaming bound (gpu1 ~ param_bytes/BW, amortizes fully
    with batch — the classic 'batching wins' regime); the host side is token
    dispatch (tiny).

    `kv_seq_budget` (token-engine decode jobs only) sets the per-slot paged
    KV reservation charged by `fits_memory` / executor admission, and prices
    prompt processing at that budget as `prefill_ms` (the compute-bound
    prefill law below) — the TTFT term the token engine adds on top of
    decode steps.  Left None, the profile is bit-identical to before."""
    n_active = cfg.active_param_count()
    if mode == "decode":
        flops = 2.0 * n_active
        gpu1 = (cfg.param_count() * dtype_bytes / dev.hbm_bw) * 1e3
        host = 0.15
        amort = 0.95
        inp = 4.0
    else:
        flops = 2.0 * n_active * seq
        gpu1 = (flops / (dev.peak_flops * 0.5)) * 1e3
        host = 0.4
        amort = 0.3
        inp = 4.0 * seq
    kv_item = 0.0
    prefill_ms = 0.0
    if kv_seq_budget is not None and mode == "decode":
        kv_item = kv_cache_bytes(cfg, kv_seq_budget, dtype_bytes)
        prefill_ms = (2.0 * n_active * kv_seq_budget
                      / (dev.peak_flops * 0.5)) * 1e3 + 0.4
    return JobProfile(name=f"{cfg.name}/{mode}", host_ms=host, gpu1_ms=gpu1,
                      amort=amort, flops=flops,
                      param_bytes=cfg.param_count() * dtype_bytes,
                      input_bytes=inp, kv_bytes_per_item=kv_item,
                      prefill_ms=prefill_ms)
