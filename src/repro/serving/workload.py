"""Workloads: the paper's 30-job table (Table 4) plus LLM serving jobs built
from the assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving import device_model as dm


@dataclasses.dataclass(frozen=True)
class Job:
    job_id: int
    dnn: str
    dataset: str
    slo_ms: float
    paper_method: Optional[str] = None   # what the paper's Table 4 chose
    paper_steady: Optional[int] = None   # steady BS or MTL in Table 4

    @property
    def slo_s(self) -> float:
        return self.slo_ms / 1e3

    def profile(self) -> dm.JobProfile:
        return dm.paper_profile(self.dnn, self.dataset)


# Paper Table 4 — job #, DNN, dataset, SLO(ms), DNNScaler method, steady knob.
PAPER_JOBS = [
    Job(1,  "inception_v1",    "imagenet",     35,   "MT", 8),
    Job(2,  "inception_v2",    "imagenet",     53,   "MT", 9),
    Job(3,  "inception_v4",    "imagenet",     419,  "B",  28),
    Job(4,  "mobilenet_v1_05", "imagenet",     199,  "MT", 10),
    Job(5,  "mobilenet_v1_025", "imagenet",    186,  "MT", 10),
    Job(6,  "mobilenet_v2_1",  "imagenet",     81,   "MT", 10),
    Job(7,  "nasnet_large",    "imagenet",     417,  "B",  13),
    Job(8,  "nasnet_mobile",   "imagenet",     85,   "MT", 10),
    Job(9,  "pnasnet_mobile",  "imagenet",     82,   "MT", 10),
    Job(10, "resnet_v2_50",    "imagenet",     45,   "MT", 6),
    Job(11, "resnet_v2_101",   "imagenet",     72,   "B",  4),
    Job(12, "resnet_v2_152",   "imagenet",     206,  "B",  14),
    Job(13, "resnet_v2_101",   "imagenet",     107,  "B",  7),
    Job(14, "inception_v1",    "caltech",      48,   "MT", 10),
    Job(15, "inception_v2",    "caltech",      116,  "B",  16),
    Job(16, "inception_v3",    "caltech",      322,  "B",  37),
    Job(17, "inception_v4",    "caltech",      139,  "B",  10),
    Job(18, "mobilenet_v1_1",  "caltech",      89,   "MT", 10),
    Job(19, "mobilenet_v1_05", "caltech",      60,   "MT", 10),
    Job(20, "mobilenet_v1_025", "caltech",     104,  "MT", 10),
    Job(21, "mobilenet_v2_1",  "caltech",      129,  "MT", 10),
    Job(22, "pnasnet_large",   "caltech",      524,  "B",  19),
    Job(23, "pnasnet_mobile",  "caltech",      321,  "B",  50),
    Job(24, "resnet_v2_50",    "caltech",      31,   "B",  1),
    Job(25, "resnet_v2_101",   "caltech",      107,  "B",  10),
    Job(26, "textclassif",     "sentiment140", 3.5,  "B",  102),
    Job(27, "textclassif",     "imdb",         3,    "B",  76),
    Job(28, "deepspeech2",     "librispeech",  1250, "B",  28),
    Job(29, "deepvs",          "ledov",        3000, "MT", 6),
    Job(30, "deepvs",          "dhf1k",        5000, "MT", 8),
]


def llm_jobs(slo_scale: float = 4.0):
    """LLM serving jobs from the assigned architectures (decode mode)."""
    from repro.configs.base import ARCH_IDS, get_config
    from repro.serving.device_model import TPU_V5E, llm_profile, step_latency
    jobs = []
    for i, arch in enumerate(ARCH_IDS):
        cfg = get_config(arch)
        prof = llm_profile(cfg, mode="decode")
        base = step_latency(TPU_V5E, prof, 1)["t_step"]
        jobs.append((arch, prof, base * slo_scale))
    return jobs
