"""Workloads: the paper's 30-job table (Table 4), LLM serving jobs built
from the assigned architectures, and online churn traces (jobs that arrive
and depart mid-run — the regime ClusterEngine's dynamic mode serves)."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving import device_model as dm


@dataclasses.dataclass(frozen=True)
class Job:
    job_id: int
    dnn: str
    dataset: str
    slo_ms: float
    paper_method: Optional[str] = None   # what the paper's Table 4 chose
    paper_steady: Optional[int] = None   # steady BS or MTL in Table 4
    # LLM / synthetic jobs carry their profile directly instead of the
    # Table-5 calibration lookup
    profile_override: Optional[dm.JobProfile] = None

    @property
    def slo_s(self) -> float:
        return self.slo_ms / 1e3

    def profile(self) -> dm.JobProfile:
        if self.profile_override is not None:
            return self.profile_override
        return dm.paper_profile(self.dnn, self.dataset)


# Paper Table 4 — job #, DNN, dataset, SLO(ms), DNNScaler method, steady knob.
PAPER_JOBS = [
    Job(1,  "inception_v1",    "imagenet",     35,   "MT", 8),
    Job(2,  "inception_v2",    "imagenet",     53,   "MT", 9),
    Job(3,  "inception_v4",    "imagenet",     419,  "B",  28),
    Job(4,  "mobilenet_v1_05", "imagenet",     199,  "MT", 10),
    Job(5,  "mobilenet_v1_025", "imagenet",    186,  "MT", 10),
    Job(6,  "mobilenet_v2_1",  "imagenet",     81,   "MT", 10),
    Job(7,  "nasnet_large",    "imagenet",     417,  "B",  13),
    Job(8,  "nasnet_mobile",   "imagenet",     85,   "MT", 10),
    Job(9,  "pnasnet_mobile",  "imagenet",     82,   "MT", 10),
    Job(10, "resnet_v2_50",    "imagenet",     45,   "MT", 6),
    Job(11, "resnet_v2_101",   "imagenet",     72,   "B",  4),
    Job(12, "resnet_v2_152",   "imagenet",     206,  "B",  14),
    Job(13, "resnet_v2_101",   "imagenet",     107,  "B",  7),
    Job(14, "inception_v1",    "caltech",      48,   "MT", 10),
    Job(15, "inception_v2",    "caltech",      116,  "B",  16),
    Job(16, "inception_v3",    "caltech",      322,  "B",  37),
    Job(17, "inception_v4",    "caltech",      139,  "B",  10),
    Job(18, "mobilenet_v1_1",  "caltech",      89,   "MT", 10),
    Job(19, "mobilenet_v1_05", "caltech",      60,   "MT", 10),
    Job(20, "mobilenet_v1_025", "caltech",     104,  "MT", 10),
    Job(21, "mobilenet_v2_1",  "caltech",      129,  "MT", 10),
    Job(22, "pnasnet_large",   "caltech",      524,  "B",  19),
    Job(23, "pnasnet_mobile",  "caltech",      321,  "B",  50),
    Job(24, "resnet_v2_50",    "caltech",      31,   "B",  1),
    Job(25, "resnet_v2_101",   "caltech",      107,  "B",  10),
    Job(26, "textclassif",     "sentiment140", 3.5,  "B",  102),
    Job(27, "textclassif",     "imdb",         3,    "B",  76),
    Job(28, "deepspeech2",     "librispeech",  1250, "B",  28),
    Job(29, "deepvs",          "ledov",        3000, "MT", 6),
    Job(30, "deepvs",          "dhf1k",        5000, "MT", 8),
]


def llm_jobs(slo_scale: float = 4.0):
    """LLM serving jobs from the assigned architectures (decode mode)."""
    from repro.configs.base import ARCH_IDS, get_config
    from repro.serving.device_model import TPU_V5E, llm_profile, step_latency
    jobs = []
    for i, arch in enumerate(ARCH_IDS):
        cfg = get_config(arch)
        prof = llm_profile(cfg, mode="decode")
        base = step_latency(TPU_V5E, prof, 1)["t_step"]
        jobs.append((arch, prof, base * slo_scale))
    return jobs


def llm_serving_jobs(slo_scale: float = 4.0, *, job_id_base: int = 900,
                     archs: Optional[Sequence[str]] = None) -> List[Job]:
    """The assigned-architecture decode jobs as first-class `Job`s, so churn
    traces can mix them into the Table-4 pool.  The SLO is `slo_scale` x the
    single-stream decode step on a whole TPU v5e — generous enough that the
    job stays feasible on a fractional slice."""
    from repro.configs.base import get_config
    picked = list(archs) if archs is not None else \
        ["smollm-360m", "gemma2-2b", "mamba2-1p3b"]
    jobs = []
    for i, arch in enumerate(picked):
        cfg = get_config(arch)
        prof = dm.llm_profile(cfg, mode="decode")
        base = dm.step_latency(dm.TPU_V5E, prof, 1)["t_step"]
        jobs.append(Job(job_id=job_id_base + i, dnn=cfg.name, dataset="decode",
                        slo_ms=base * slo_scale * 1e3, profile_override=prof))
    return jobs


# ---------------------------------------------------------------------------
# Online churn traces: per-job admit/depart times over a horizon.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChurnJob:
    """One serving tenancy in a churn trace: a job that arrives at
    `admit_s`, departs at `depart_s` (None = stays to the horizon), and —
    in open-loop mode — receives Poisson arrivals at `arrival_rate`/s
    strictly inside its [admit_s, depart_s) lifetime."""

    job: Job
    admit_s: float = 0.0
    depart_s: Optional[float] = None
    arrival_rate: Optional[float] = None


def steady_capacity(job: Job, *, share: float = 1.0,
                    alpha: float = 0.85) -> float:
    """SLO-feasible steady throughput of `job` on a `share`-sized slice of
    its natural device: the best (bs, mtl) grid point whose analytic
    latency fits under alpha*SLO.  Falls back to the single-stream rate
    when even (1, 1) violates (the job is served best-effort anyway)."""
    prof = job.profile()
    dev = dm.TPU_V5E if job.profile_override is not None else dm.TESLA_P40
    if share < 1.0:
        dev = dev.share(share)
    bs = np.array([1, 2, 4, 8, 16, 32, 64, 128])
    mtl = np.arange(1, 11)
    lat = dm.mt_latency_grid(dev, prof, bs, mtl)
    best = dm.best_feasible_point(lat, bs, mtl, alpha * job.slo_s)
    if best is None:
        return 1.0 / dm.batch_latency(dev, prof, 1)
    return best[0]


def mixed_partition_trace(*, horizon_s: float = 120.0, n_light: int = 4,
                          heavy_load: float = 0.7, light_load: float = 0.6,
                          seed: int = 0) -> List[ChurnJob]:
    """A mixed small/large-DNN trace — the regime where heterogeneous
    spatial shares beat uniform multi-tenancy.

    Two HEAVY jobs (large dense nets whose GPU time dominates) are present
    for the whole horizon with arrival rates sized to their SLO-feasible
    capacity on a ~3/4 device slice: a uniform 1/k time-share physically
    cannot serve them once a couple of light tenants land on the device.
    `n_light` LIGHT jobs (tiny mobile/text nets that keep up on an eighth
    of a device) churn in and out, forcing the placement layer to
    repeatedly re-divide each device — resizes in partition mode, full
    kill+relaunch migrations under uniform sharing."""
    rng = np.random.default_rng(seed)
    heavy_pool = [j for j in PAPER_JOBS
                  if j.dnn in ("inception_v4", "resnet_v2_152",
                               "nasnet_large")]
    light_pool = [j for j in PAPER_JOBS
                  if j.dnn in ("mobilenet_v1_025", "mobilenet_v1_05",
                               "textclassif")]
    trace: List[ChurnJob] = []
    for k in range(2):
        base = heavy_pool[int(rng.integers(len(heavy_pool)))]
        job = dataclasses.replace(base, job_id=2000 + k)
        trace.append(ChurnJob(
            job=job, admit_s=0.0, depart_s=None,
            arrival_rate=heavy_load * steady_capacity(job, share=0.75)))
    for k in range(n_light):
        base = light_pool[int(rng.integers(len(light_pool)))]
        job = dataclasses.replace(base, job_id=2100 + k)
        admit = 0.0 if k == 0 else float(rng.uniform(0.0, 0.6 * horizon_s))
        life = float(rng.exponential(0.35 * horizon_s))
        depart = admit + life if admit + life < horizon_s else None
        trace.append(ChurnJob(
            job=job, admit_s=admit, depart_s=depart,
            arrival_rate=light_load * steady_capacity(job, share=0.125)))
    trace.sort(key=lambda e: e.admit_s)
    return trace


def churn_trace(*, horizon_s: float = 150.0, n_initial: int = 4,
                n_churn: int = 12, mean_lifetime_s: float = 30.0,
                load: float = 0.6, include_llm: bool = True,
                pool: Optional[Sequence[Job]] = None,
                seed: int = 0) -> List[ChurnJob]:
    """Sample a churn trace from the Table-4 pool (plus the LLM decode jobs).

    `n_initial` jobs are present at t=0; `n_churn` more arrive uniformly
    over the first 70% of the horizon.  Lifetimes are exponential with mean
    `mean_lifetime_s`; a lifetime reaching past the horizon means the job
    never departs.  Every sampled tenancy gets a fresh unique job_id so
    re-picks of the same Table-4 row are distinct tenants.

    Each tenancy's Poisson arrival rate is `load` x its SLO-feasible
    steady capacity on a FULL device (`steady_capacity`).  At load ~0.6 a
    job needs well over half a device to keep up — a static union
    placement that thins every share to 1/k is physically unable to serve
    the demand, which is exactly the slack online re-placement harvests."""
    rng = np.random.default_rng(seed)
    candidates = list(pool) if pool is not None else list(PAPER_JOBS)
    if include_llm and pool is None:
        candidates = candidates + llm_serving_jobs()
    trace: List[ChurnJob] = []
    for k in range(n_initial + n_churn):
        base = candidates[int(rng.integers(len(candidates)))]
        job = dataclasses.replace(base, job_id=1000 + k)
        admit = 0.0 if k < n_initial else \
            float(rng.uniform(0.0, 0.7 * horizon_s))
        life = float(rng.exponential(mean_lifetime_s))
        depart = admit + life if admit + life < horizon_s else None
        trace.append(ChurnJob(job=job, admit_s=admit, depart_s=depart,
                              arrival_rate=load * steady_capacity(job)))
    trace.sort(key=lambda e: e.admit_s)
    return trace
