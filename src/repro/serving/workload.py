"""Workloads: the paper's 30-job table (Table 4), LLM serving jobs built
from the assigned architectures, and online churn traces (jobs that arrive
and depart mid-run — the regime ClusterEngine's dynamic mode serves)."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving import device_model as dm


@dataclasses.dataclass(frozen=True)
class Job:
    job_id: int
    dnn: str
    dataset: str
    slo_ms: float
    paper_method: Optional[str] = None   # what the paper's Table 4 chose
    paper_steady: Optional[int] = None   # steady BS or MTL in Table 4
    # LLM / synthetic jobs carry their profile directly instead of the
    # Table-5 calibration lookup
    profile_override: Optional[dm.JobProfile] = None

    @property
    def slo_s(self) -> float:
        return self.slo_ms / 1e3

    def profile(self) -> dm.JobProfile:
        if self.profile_override is not None:
            return self.profile_override
        return dm.paper_profile(self.dnn, self.dataset)


# Paper Table 4 — job #, DNN, dataset, SLO(ms), DNNScaler method, steady knob.
PAPER_JOBS = [
    Job(1,  "inception_v1",    "imagenet",     35,   "MT", 8),
    Job(2,  "inception_v2",    "imagenet",     53,   "MT", 9),
    Job(3,  "inception_v4",    "imagenet",     419,  "B",  28),
    Job(4,  "mobilenet_v1_05", "imagenet",     199,  "MT", 10),
    Job(5,  "mobilenet_v1_025", "imagenet",    186,  "MT", 10),
    Job(6,  "mobilenet_v2_1",  "imagenet",     81,   "MT", 10),
    Job(7,  "nasnet_large",    "imagenet",     417,  "B",  13),
    Job(8,  "nasnet_mobile",   "imagenet",     85,   "MT", 10),
    Job(9,  "pnasnet_mobile",  "imagenet",     82,   "MT", 10),
    Job(10, "resnet_v2_50",    "imagenet",     45,   "MT", 6),
    Job(11, "resnet_v2_101",   "imagenet",     72,   "B",  4),
    Job(12, "resnet_v2_152",   "imagenet",     206,  "B",  14),
    Job(13, "resnet_v2_101",   "imagenet",     107,  "B",  7),
    Job(14, "inception_v1",    "caltech",      48,   "MT", 10),
    Job(15, "inception_v2",    "caltech",      116,  "B",  16),
    Job(16, "inception_v3",    "caltech",      322,  "B",  37),
    Job(17, "inception_v4",    "caltech",      139,  "B",  10),
    Job(18, "mobilenet_v1_1",  "caltech",      89,   "MT", 10),
    Job(19, "mobilenet_v1_05", "caltech",      60,   "MT", 10),
    Job(20, "mobilenet_v1_025", "caltech",     104,  "MT", 10),
    Job(21, "mobilenet_v2_1",  "caltech",      129,  "MT", 10),
    Job(22, "pnasnet_large",   "caltech",      524,  "B",  19),
    Job(23, "pnasnet_mobile",  "caltech",      321,  "B",  50),
    Job(24, "resnet_v2_50",    "caltech",      31,   "B",  1),
    Job(25, "resnet_v2_101",   "caltech",      107,  "B",  10),
    Job(26, "textclassif",     "sentiment140", 3.5,  "B",  102),
    Job(27, "textclassif",     "imdb",         3,    "B",  76),
    Job(28, "deepspeech2",     "librispeech",  1250, "B",  28),
    Job(29, "deepvs",          "ledov",        3000, "MT", 6),
    Job(30, "deepvs",          "dhf1k",        5000, "MT", 8),
]


def llm_jobs(slo_scale: float = 4.0):
    """LLM serving jobs from the assigned architectures (decode mode)."""
    from repro.configs.base import ARCH_IDS, get_config
    from repro.serving.device_model import TPU_V5E, llm_profile, step_latency
    jobs = []
    for i, arch in enumerate(ARCH_IDS):
        cfg = get_config(arch)
        prof = llm_profile(cfg, mode="decode")
        base = step_latency(TPU_V5E, prof, 1)["t_step"]
        jobs.append((arch, prof, base * slo_scale))
    return jobs


def llm_serving_jobs(slo_scale: float = 4.0, *, job_id_base: int = 900,
                     archs: Optional[Sequence[str]] = None) -> List[Job]:
    """The assigned-architecture decode jobs as first-class `Job`s, so churn
    traces can mix them into the Table-4 pool.  The SLO is `slo_scale` x the
    single-stream decode step on a whole TPU v5e — generous enough that the
    job stays feasible on a fractional slice."""
    from repro.configs.base import get_config
    picked = list(archs) if archs is not None else \
        ["smollm-360m", "gemma2-2b", "mamba2-1p3b"]
    jobs = []
    for i, arch in enumerate(picked):
        cfg = get_config(arch)
        prof = dm.llm_profile(cfg, mode="decode")
        base = dm.step_latency(dm.TPU_V5E, prof, 1)["t_step"]
        jobs.append(Job(job_id=job_id_base + i, dnn=cfg.name, dataset="decode",
                        slo_ms=base * slo_scale * 1e3, profile_override=prof))
    return jobs


def long_prefill_trace(n_requests: int = 300, seed: int = 0, *,
                       rate_rps: float = 12.0, prefill_mean: int = 2048,
                       decode_mean: int = 96, decode_sigma: float = 0.8):
    """Long-prompt ragged decode trace (summarization / RAG style):
    prompts average `prefill_mean` >= 2048 tokens while outputs stay
    short — the regime where prompt processing, not decode, owns the
    device and prefill/decode disaggregation pays (serving/disagg.py,
    benchmarks/disagg_benches.py)."""
    from repro.serving.token_engine import ragged_decode_trace
    if prefill_mean < 2048:
        raise ValueError("long_prefill_trace is the long-prompt regime: "
                         "prefill_mean >= 2048")
    return ragged_decode_trace(n_requests, seed, rate_rps=rate_rps,
                               prefill_mean=prefill_mean,
                               decode_mean=decode_mean,
                               decode_sigma=decode_sigma)


# ---------------------------------------------------------------------------
# Online churn traces: per-job admit/depart times over a horizon.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChurnJob:
    """One serving tenancy in a churn trace: a job that arrives at
    `admit_s`, departs at `depart_s` (None = stays to the horizon), and —
    in open-loop mode — receives Poisson arrivals at `arrival_rate`/s
    strictly inside its [admit_s, depart_s) lifetime."""

    job: Job
    admit_s: float = 0.0
    depart_s: Optional[float] = None
    arrival_rate: Optional[float] = None
    # declarative time-varying traffic over the nominal arrival_rate (which
    # stays the mean-rate the packer scores against): a plain dict so churn
    # traces remain JSON-serializable for replay.  See `make_rate_fn` for
    # the supported kinds ("diurnal", "flash"); None = constant rate.
    traffic: Optional[dict] = None


def make_rate_fn(base_rate: Optional[float], traffic: Optional[dict]):
    """Compile a ChurnJob's declarative `traffic` spec into the arrival
    machinery: returns ``(rate_fn, piecewise_s, step_breaks)`` for
    `OpenLoopQueue`.

    - None / {"kind": "steady"}: constant `base_rate` — the exact
      single-point integral, bit-identical to the legacy constant path.
    - {"kind": "diurnal", "period_s", "peak_mult", "trough_mult",
      "phase_s"}: smooth cosine day/night swing between trough_mult and
      peak_mult x base_rate (trough at phase_s, peak half a period later);
      integrated by trapezoid over period/16 knots.
    - {"kind": "flash", "at_s", "duration_s", "mult"}: flash crowd — a
      step to mult x base_rate over [at_s, at_s + duration_s); the jump
      points are REGISTERED so the integral is exact left-Riemann (the
      trapezoid would smear the spike edges; see OpenLoopQueue).
    """
    if base_rate is None or traffic is None:
        return (lambda t, r=base_rate: r), None, None
    kind = traffic.get("kind", "steady")
    if kind == "steady":
        return (lambda t, r=base_rate: r), None, None
    if kind == "diurnal":
        period = float(traffic.get("period_s", 86_400.0))
        peak = float(traffic.get("peak_mult", 2.0))
        trough = float(traffic.get("trough_mult", 0.5))
        phase = float(traffic.get("phase_s", 0.0))

        def rate_fn(t, r=base_rate):
            u = 0.5 * (1.0 - np.cos(2.0 * np.pi * (t - phase) / period))
            return r * (trough + (peak - trough) * float(u))

        return rate_fn, period / 16.0, None
    if kind == "flash":
        at = float(traffic.get("at_s", 0.0))
        dur = float(traffic.get("duration_s", 10.0))
        mult = float(traffic.get("mult", 4.0))

        def rate_fn(t, r=base_rate):
            return r * (mult if at <= t < at + dur else 1.0)

        def step_breaks(a, b):
            return [x for x in (at, at + dur) if a < x < b]

        return rate_fn, None, step_breaks
    raise ValueError(f"unknown traffic kind {kind!r}")


# ---------------------------------------------------------------------------
# Preemptible (spot) capacity: revocation events over the fleet.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Preemption:
    """One spot-capacity revocation: device index `device` is revoked at
    `at_s`; residents get a `grace_s` evacuation window (migrate out, or
    serve down until the deadline and lose the remaining backlog).
    `restore_s` optionally returns the device to the placement pool."""

    device: int
    at_s: float
    grace_s: float = 10.0
    restore_s: Optional[float] = None


def spot_revocation_trace(fleet: Sequence, *, horizon_s: float,
                          grace_s: float = 10.0, restore: bool = True,
                          seed: int = 0) -> List[Preemption]:
    """One revocation per spot-flagged device, at a time sampled from the
    middle 60% of the horizon; restored (if `restore`) after ~15% of the
    horizon off — the churn shape of a preemptible capacity pool."""
    rng = np.random.default_rng(seed)
    out: List[Preemption] = []
    for d, spec in enumerate(fleet):
        dev = getattr(spec, "device", spec)
        if not getattr(dev, "spot", False):
            continue
        at = float(rng.uniform(0.2 * horizon_s, 0.8 * horizon_s))
        back = at + grace_s + 0.15 * horizon_s
        out.append(Preemption(
            device=d, at_s=at, grace_s=grace_s,
            restore_s=(back if restore and back < horizon_s else None)))
    out.sort(key=lambda p: p.at_s)
    return out


def steady_capacity(job: Job, *, share: float = 1.0,
                    alpha: float = 0.85) -> float:
    """SLO-feasible steady throughput of `job` on a `share`-sized slice of
    its natural device: the best (bs, mtl) grid point whose analytic
    latency fits under alpha*SLO.  Falls back to the single-stream rate
    when even (1, 1) violates (the job is served best-effort anyway)."""
    prof = job.profile()
    dev = dm.TPU_V5E if job.profile_override is not None else dm.TESLA_P40
    if share < 1.0:
        dev = dev.share(share)
    bs = np.array([1, 2, 4, 8, 16, 32, 64, 128])
    mtl = np.arange(1, 11)
    lat = dm.mt_latency_grid(dev, prof, bs, mtl)
    best = dm.best_feasible_point(lat, bs, mtl, alpha * job.slo_s)
    if best is None:
        return 1.0 / dm.batch_latency(dev, prof, 1)
    return best[0]


def mixed_partition_trace(*, horizon_s: float = 120.0, n_light: int = 4,
                          heavy_load: float = 0.7, light_load: float = 0.6,
                          seed: int = 0) -> List[ChurnJob]:
    """A mixed small/large-DNN trace — the regime where heterogeneous
    spatial shares beat uniform multi-tenancy.

    Two HEAVY jobs (large dense nets whose GPU time dominates) are present
    for the whole horizon with arrival rates sized to their SLO-feasible
    capacity on a ~3/4 device slice: a uniform 1/k time-share physically
    cannot serve them once a couple of light tenants land on the device.
    `n_light` LIGHT jobs (tiny mobile/text nets that keep up on an eighth
    of a device) churn in and out, forcing the placement layer to
    repeatedly re-divide each device — resizes in partition mode, full
    kill+relaunch migrations under uniform sharing."""
    rng = np.random.default_rng(seed)
    heavy_pool = [j for j in PAPER_JOBS
                  if j.dnn in ("inception_v4", "resnet_v2_152",
                               "nasnet_large")]
    light_pool = [j for j in PAPER_JOBS
                  if j.dnn in ("mobilenet_v1_025", "mobilenet_v1_05",
                               "textclassif")]
    trace: List[ChurnJob] = []
    for k in range(2):
        base = heavy_pool[int(rng.integers(len(heavy_pool)))]
        job = dataclasses.replace(base, job_id=2000 + k)
        trace.append(ChurnJob(
            job=job, admit_s=0.0, depart_s=None,
            arrival_rate=heavy_load * steady_capacity(job, share=0.75)))
    for k in range(n_light):
        base = light_pool[int(rng.integers(len(light_pool)))]
        job = dataclasses.replace(base, job_id=2100 + k)
        admit = 0.0 if k == 0 else float(rng.uniform(0.0, 0.6 * horizon_s))
        life = float(rng.exponential(0.35 * horizon_s))
        depart = admit + life if admit + life < horizon_s else None
        trace.append(ChurnJob(
            job=job, admit_s=admit, depart_s=depart,
            arrival_rate=light_load * steady_capacity(job, share=0.125)))
    trace.sort(key=lambda e: e.admit_s)
    return trace


def churn_trace(*, horizon_s: float = 150.0, n_initial: int = 4,
                n_churn: int = 12, mean_lifetime_s: float = 30.0,
                load: float = 0.6, include_llm: bool = True,
                pool: Optional[Sequence[Job]] = None,
                seed: int = 0) -> List[ChurnJob]:
    """Sample a churn trace from the Table-4 pool (plus the LLM decode jobs).

    `n_initial` jobs are present at t=0; `n_churn` more arrive uniformly
    over the first 70% of the horizon.  Lifetimes are exponential with mean
    `mean_lifetime_s`; a lifetime reaching past the horizon means the job
    never departs.  Every sampled tenancy gets a fresh unique job_id so
    re-picks of the same Table-4 row are distinct tenants.

    Each tenancy's Poisson arrival rate is `load` x its SLO-feasible
    steady capacity on a FULL device (`steady_capacity`).  At load ~0.6 a
    job needs well over half a device to keep up — a static union
    placement that thins every share to 1/k is physically unable to serve
    the demand, which is exactly the slack online re-placement harvests."""
    rng = np.random.default_rng(seed)
    candidates = list(pool) if pool is not None else list(PAPER_JOBS)
    if include_llm and pool is None:
        candidates = candidates + llm_serving_jobs()
    trace: List[ChurnJob] = []
    for k in range(n_initial + n_churn):
        base = candidates[int(rng.integers(len(candidates)))]
        job = dataclasses.replace(base, job_id=1000 + k)
        admit = 0.0 if k < n_initial else \
            float(rng.uniform(0.0, 0.7 * horizon_s))
        life = float(rng.exponential(mean_lifetime_s))
        depart = admit + life if admit + life < horizon_s else None
        trace.append(ChurnJob(job=job, admit_s=admit, depart_s=depart,
                              arrival_rate=load * steady_capacity(job)))
    trace.sort(key=lambda e: e.admit_s)
    return trace


# ---------------------------------------------------------------------------
# Scenario matrix traces: {steady, diurnal, flash} x {fixed, spot} cells.
# ---------------------------------------------------------------------------
def scenario_traffic_spec(traffic: str, *, horizon_s: float) -> Optional[dict]:
    """The per-kind traffic dict used by `scenario_trace`: one diurnal
    "day" is compressed onto the horizon (trough at t=0, peak mid-run);
    the flash crowd is a 3x step over ~7% of the horizon just past the
    midpoint.  Steady returns None (constant rate)."""
    if traffic == "steady":
        return None
    if traffic == "diurnal":
        return {"kind": "diurnal", "period_s": horizon_s,
                "peak_mult": 1.5, "trough_mult": 0.45, "phase_s": 0.0}
    if traffic == "flash":
        return {"kind": "flash", "at_s": 0.55 * horizon_s,
                "duration_s": 0.07 * horizon_s, "mult": 3.0}
    raise ValueError(f"unknown scenario traffic {traffic!r}")


def scenario_trace(traffic: str = "steady", *, horizon_s: float = 90.0,
                   n_jobs: int = 6, load: float = 0.05,
                   seed: int = 0) -> List[ChurnJob]:
    """One cell-trace of the scenario matrix: `n_jobs` light tenants (the
    mobile-net pool — textclassif/imdb is excluded because its base
    latency exceeds its own SLO, so no placement could ever attain it)
    whose Poisson rates follow the `traffic` kind.

    Most tenants span the whole horizon; one departs early and one arrives
    late, so the consolidate-vs-spread packing objective has empty devices
    to power-gate at trough and fresh admissions to place at peak.  Rates
    are `load` x the SLO-feasible capacity on a quarter slice —
    `steady_capacity` prices a LONE tenant, so `load` must also absorb
    the co-tenant interference of a packed device plus the flash-crowd
    3x peak while keeping >= 0.95 attainment (the BENCH_scenarios gate);
    0.05 holds that with margin on a 4-way packed P40."""
    rng = np.random.default_rng(seed)
    light_pool = [j for j in PAPER_JOBS
                  if j.dnn in ("mobilenet_v1_025", "mobilenet_v1_05")]
    spec = scenario_traffic_spec(traffic, horizon_s=horizon_s)
    trace: List[ChurnJob] = []
    for k in range(n_jobs):
        base = light_pool[int(rng.integers(len(light_pool)))]
        job = dataclasses.replace(base, job_id=3000 + k)
        admit, depart = 0.0, None
        if k == n_jobs - 2:
            depart = 0.40 * horizon_s     # frees capacity mid-run ...
        elif k == n_jobs - 1:
            admit = 0.50 * horizon_s      # ... which the late arrival can
            #                               take whole (under "spread") just
            #                               before the flash crowd lands
        trace.append(ChurnJob(
            job=job, admit_s=admit, depart_s=depart,
            arrival_rate=load * steady_capacity(job, share=0.25),
            traffic=spec))
    trace.sort(key=lambda e: e.admit_s)
    return trace
