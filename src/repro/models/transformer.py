"""Decoder-only transformer assembly (dense / MoE / SSM / hybrid / VLM).

A model is a sequence of layer *groups* (``cfg.layer_groups``); each group's
parameters are stacked on a leading axis and executed with ``lax.scan`` so
that 80-layer models lower to a compact HLO.  Three modes:

  train   — full-sequence forward, chunked cross-entropy loss
  prefill — full-sequence forward, returns last-position logits + KV cache
  decode  — one token against the cache (the serving hot path)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ATTN, SWA, MAMBA
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def _init_dense_layer(rng, cfg, window_kind: str) -> dict:
    k1, k2 = jax.random.split(rng)
    p = {"attn": L.init_attn_block(k1, cfg)}
    if cfg.num_experts:
        p["moe"] = MOE.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def _stack_init(init_fn, rng, count: int):
    keys = jax.random.split(rng, count)
    return jax.vmap(init_fn)(keys)


def init_group(rng, cfg, kind: str, count: int):
    if kind in (ATTN, SWA):
        return _stack_init(lambda k: _init_dense_layer(k, cfg, kind), rng, count)
    if kind == MAMBA:
        return _stack_init(lambda k: M.init_mamba_block(k, cfg), rng, count)
    if kind == "local_global":
        k1, k2 = jax.random.split(rng)
        return {
            "local": _stack_init(lambda k: _init_dense_layer(k, cfg, SWA), k1, count),
            "global": _stack_init(lambda k: _init_dense_layer(k, cfg, ATTN), k2, count),
        }
    if kind == "hybrid_super":
        k1, k2, k3 = jax.random.split(rng, 3)
        inner = cfg.hybrid_attn_every
        mamba = _stack_init(
            lambda k: _stack_init(lambda kk: M.init_mamba_block(kk, cfg), k, inner),
            k1, count)
        shared = {"attn": L.init_attn_block(k2, cfg), "mlp": L.init_mlp(k3, cfg)}
        return {"mamba": mamba, "shared": shared}
    raise ValueError(kind)


def init_params(rng, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(rng, len(cfg.layer_groups) + 3)
    params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02
                  ).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "groups": [init_group(k, cfg, kind, count)
                   for k, (kind, count) in zip(keys[1:], cfg.layer_groups)],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size))
                             * 0.02).astype(dt)
    if cfg.frontend == "vision_stub":
        params["vis_proj"] = (jax.random.normal(keys[-1], (cfg.d_model, cfg.d_model))
                              * 0.02).astype(dt)
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------
def dense_layer_apply(lp, x, cfg, *, window, mode, kv=None, cache_pos=None,
                      positions=None, ring=False, seq_axis=None):
    x, new_kv = L.attn_block_apply(
        lp["attn"], x, cfg, window=window, mode=mode, cache=kv,
        cache_pos=cache_pos, positions=positions, ring=ring,
        seq_axis=seq_axis)
    if "moe" in lp:
        x, aux = MOE.moe_block_apply(lp["moe"], x, cfg)
    else:
        x = L.mlp_apply(lp["mlp"], x, cfg)
        aux = jnp.zeros((), jnp.float32)
    return x, new_kv, aux


def _window(cfg, kind):
    if kind == SWA:
        return cfg.sliding_window
    return None


# ---------------------------------------------------------------------------
# Cache allocation (works under jax.eval_shape for the dry-run)
# ---------------------------------------------------------------------------
def _stacked_mamba_state(cfg, shape_prefix: tuple, batch: int, dt) -> dict:
    d_in, H, P, N = M.dims(cfg)
    conv_dim = d_in + 2 * N
    return {
        "ssm": jnp.zeros((*shape_prefix, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((*shape_prefix, batch, cfg.ssm_conv_width - 1, conv_dim), dt),
    }


def init_cache(cfg, batch: int, capacity: int, windowed: bool = False) -> list:
    """windowed=True (beyond-paper §Perf): sliding-window layers allocate
    only ``window`` slots (ring buffer) instead of the full context."""
    dt = jnp.dtype(cfg.dtype)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    wcap = capacity
    if windowed and cfg.sliding_window:
        wcap = min(capacity, cfg.sliding_window)
    caches = []
    for kind, count in cfg.layer_groups:
        if kind in (ATTN, SWA):
            cap = wcap if kind == SWA else capacity
            caches.append({
                "k": jnp.zeros((count, batch, KV, cap, hd), dt),
                "v": jnp.zeros((count, batch, KV, cap, hd), dt),
            })
        elif kind == "local_global":
            caches.append({
                "local": {"k": jnp.zeros((count, batch, KV, wcap, hd), dt),
                          "v": jnp.zeros((count, batch, KV, wcap, hd), dt)},
                "global": {"k": jnp.zeros((count, batch, KV, capacity, hd), dt),
                           "v": jnp.zeros((count, batch, KV, capacity, hd), dt)},
            })
        elif kind == MAMBA:
            caches.append(_stacked_mamba_state(cfg, (count,), batch, dt))
        elif kind == "hybrid_super":
            inner = cfg.hybrid_attn_every
            caches.append({
                "mamba": _stacked_mamba_state(cfg, (count, inner), batch, dt),
                "k": jnp.zeros((count, batch, KV, wcap, hd), dt),
                "v": jnp.zeros((count, batch, KV, wcap, hd), dt),
            })
        else:
            raise ValueError(kind)
    return caches


# ---------------------------------------------------------------------------
# Group execution — one function per mode to keep scan signatures simple.
# ---------------------------------------------------------------------------
def run_group_train(gp, x, cfg, kind, *, positions, remat=False, bspec=None):
    window = cfg.sliding_window

    if kind in (ATTN, SWA):
        def body(carry, lp):
            carry = L.constrain_batch(carry, bspec)
            y, _, aux = dense_layer_apply(lp, carry, cfg, window=_window(cfg, kind),
                                          mode="train", positions=positions)
            return y, aux
        if remat:
            body = jax.checkpoint(body)
        x, auxs = lax.scan(body, x, gp)
        return x, auxs.sum()

    if kind == "local_global":
        def body(carry, lp):
            carry = L.constrain_batch(carry, bspec)
            y, _, a1 = dense_layer_apply(lp["local"], carry, cfg, window=window,
                                         mode="train", positions=positions)
            y, _, a2 = dense_layer_apply(lp["global"], y, cfg, window=None,
                                         mode="train", positions=positions)
            return y, a1 + a2
        if remat:
            body = jax.checkpoint(body)
        x, auxs = lax.scan(body, x, gp)
        return x, auxs.sum()

    if kind == MAMBA:
        def body(carry, lp):
            carry = L.constrain_batch(carry, bspec)
            y, _ = M.mamba_block_apply(lp, carry, cfg, mode="train")
            return y, jnp.zeros((), jnp.float32)
        if remat:
            body = jax.checkpoint(body)
        x, auxs = lax.scan(body, x, gp)
        return x, auxs.sum()

    if kind == "hybrid_super":
        shared = gp["shared"]

        def body(carry, mp_stack):
            y = L.constrain_batch(carry, bspec)
            def inner(c, mp):
                out, _ = M.mamba_block_apply(mp, c, cfg, mode="train")
                return out, None
            y, _ = lax.scan(inner, y, mp_stack)
            y, _, _ = dense_layer_apply(shared, y, cfg, window=window,
                                        mode="train", positions=positions)
            return y, jnp.zeros((), jnp.float32)
        if remat:
            body = jax.checkpoint(body)
        x, auxs = lax.scan(body, x, gp["mamba"])
        return x, auxs.sum()

    raise ValueError(kind)


def run_group_prefill(gp, x, cfg, kind, cache, *, positions, cache_pos=0,
                      seq_axis=None):
    """Forward with cache write-back at [cache_pos, cache_pos+T)."""
    window = cfg.sliding_window
    T = x.shape[1]

    def put(buf, kv):  # buf (count,B,KV,cap,hd); kv (count,B,T,KV,hd)
        kv = kv.transpose(0, 1, 3, 2, 4)         # -> (count,B,KV,T,hd)
        return lax.dynamic_update_slice_in_dim(buf, kv.astype(buf.dtype),
                                               cache_pos, axis=3)

    if kind in (ATTN, SWA):
        def body(carry, lp):
            y, kv, aux = dense_layer_apply(lp, carry, cfg, window=_window(cfg, kind),
                                           mode="prefill", positions=positions,
                                           seq_axis=seq_axis)
            return y, (kv["k"], kv["v"], aux)
        x, (ks, vs, auxs) = lax.scan(body, x, gp)
        new_cache = {"k": put(cache["k"], ks), "v": put(cache["v"], vs)}
        return x, new_cache, auxs.sum()

    if kind == "local_global":
        def body(carry, lp):
            y, kv_l, a1 = dense_layer_apply(lp["local"], carry, cfg, window=window,
                                            mode="prefill", positions=positions,
                                            seq_axis=seq_axis)
            y, kv_g, a2 = dense_layer_apply(lp["global"], y, cfg, window=None,
                                            mode="prefill", positions=positions,
                                            seq_axis=seq_axis)
            return y, (kv_l["k"], kv_l["v"], kv_g["k"], kv_g["v"], a1 + a2)
        x, (kl, vl, kg, vg, auxs) = lax.scan(body, x, gp)
        new_cache = {
            "local": {"k": put(cache["local"]["k"], kl),
                      "v": put(cache["local"]["v"], vl)},
            "global": {"k": put(cache["global"]["k"], kg),
                       "v": put(cache["global"]["v"], vg)},
        }
        return x, new_cache, auxs.sum()

    if kind == MAMBA:
        def body(carry, inp):
            lp, st = inp
            y, new_st = M.mamba_block_apply(lp, carry, cfg, state=st, mode="prefill")
            return y, new_st
        x, new_states = lax.scan(body, x, (gp, cache))
        return x, new_states, jnp.zeros((), jnp.float32)

    if kind == "hybrid_super":
        shared = gp["shared"]

        def body(carry, inp):
            mp_stack, mstates = inp
            y = carry
            def inner(c, si):
                mp, st = si
                out, new_st = M.mamba_block_apply(mp, c, cfg, state=st, mode="prefill")
                return out, new_st
            y, new_mstates = lax.scan(inner, y, (mp_stack, mstates))
            y, kv, _ = dense_layer_apply(shared, y, cfg, window=window,
                                         mode="prefill", positions=positions)
            return y, (new_mstates, kv["k"], kv["v"])
        x, (new_m, ks, vs) = lax.scan(body, x, (gp["mamba"], cache["mamba"]))
        new_cache = {"mamba": new_m, "k": put(cache["k"], ks),
                     "v": put(cache["v"], vs)}
        return x, new_cache, jnp.zeros((), jnp.float32)

    raise ValueError(kind)


def run_group_decode(gp, x, cfg, kind, cache, *, pos, windowed=False,
                     return_deltas=False):
    """One-token step.  pos: scalar int32 — index where the new token lands.
    windowed=True: sliding-window layers use ring-buffer caches.

    Attention bodies read the cache and emit (k_new, v_new) deltas; the cache
    is written back with ONE stacked dynamic-update-slice per group after the
    layer scan (append-outside-scan, §Perf — a per-layer in-scan update
    rewrites the full per-layer cache every layer)."""
    window = cfg.sliding_window
    positions = pos[None] if pos.ndim == 0 else pos

    def put(buf, delta, ring):
        # buf (count,B,KV,cap,hd); delta (count,B,KV,1,hd)
        if return_deltas:
            return delta        # caller applies a sharded append (§Perf)
        cap = buf.shape[3]
        slot = (pos % cap) if ring else pos
        return lax.dynamic_update_slice_in_dim(buf, delta.astype(buf.dtype),
                                               slot, axis=3)

    if kind in (ATTN, SWA):
        ring = windowed and kind == SWA
        def body(carry, inp):
            lp, k_l, v_l = inp
            y, kv, _ = dense_layer_apply(lp, carry, cfg, window=_window(cfg, kind),
                                         mode="decode", kv={"k": k_l, "v": v_l},
                                         cache_pos=pos, positions=positions,
                                         ring=ring)
            return y, (kv["k"], kv["v"])
        x, (dk, dv) = lax.scan(body, x, (gp, cache["k"], cache["v"]))
        return x, {"k": put(cache["k"], dk, ring), "v": put(cache["v"], dv, ring)}

    if kind == "local_global":
        def body(carry, inp):
            lp, kl, vl, kg, vg = inp
            y, kv_l, _ = dense_layer_apply(lp["local"], carry, cfg, window=window,
                                           mode="decode", kv={"k": kl, "v": vl},
                                           cache_pos=pos, positions=positions,
                                           ring=windowed)
            y, kv_g, _ = dense_layer_apply(lp["global"], y, cfg, window=None,
                                           mode="decode", kv={"k": kg, "v": vg},
                                           cache_pos=pos, positions=positions)
            return y, (kv_l["k"], kv_l["v"], kv_g["k"], kv_g["v"])
        x, (dkl, dvl, dkg, dvg) = lax.scan(
            body, x, (gp, cache["local"]["k"], cache["local"]["v"],
                      cache["global"]["k"], cache["global"]["v"]))
        return x, {
            "local": {"k": put(cache["local"]["k"], dkl, windowed),
                      "v": put(cache["local"]["v"], dvl, windowed)},
            "global": {"k": put(cache["global"]["k"], dkg, False),
                       "v": put(cache["global"]["v"], dvg, False)},
        }

    if kind == MAMBA:
        def body(carry, inp):
            lp, st = inp
            y, new_st = M.mamba_block_apply(lp, carry, cfg, state=st, mode="decode")
            return y, new_st
        x, new_states = lax.scan(body, x, (gp, cache))
        return x, new_states

    if kind == "hybrid_super":
        shared = gp["shared"]

        def body(carry, inp):
            mp_stack, mstates, k_l, v_l = inp
            y = carry
            def inner(c, si):
                mp, st = si
                out, new_st = M.mamba_block_apply(mp, c, cfg, state=st, mode="decode")
                return out, new_st
            y, new_mstates = lax.scan(inner, y, (mp_stack, mstates))
            y, kv, _ = dense_layer_apply(shared, y, cfg, window=window,
                                         mode="decode", kv={"k": k_l, "v": v_l},
                                         cache_pos=pos, positions=positions,
                                         ring=windowed)
            return y, (new_mstates, kv["k"], kv["v"])
        x, (new_m, dk, dv) = lax.scan(body, x, (gp["mamba"], cache["mamba"],
                                                cache["k"], cache["v"]))
        return x, {"mamba": new_m, "k": put(cache["k"], dk, windowed),
                   "v": put(cache["v"], dv, windowed)}

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------
def embed_tokens(params, tokens, cfg, patch_embeds=None):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype)
        if "vis_proj" in params:
            pe = pe @ params["vis_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    return x


def head_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T            # (d, V)
    return params["lm_head"]


def logits_last(params, h_last, cfg):
    """h_last: (B, d) -> (B, V) float32 logits (with final softcap)."""
    w = head_matrix(params, cfg)
    out = jnp.einsum("bd,dv->bv", h_last, w, preferred_element_type=jnp.float32)
    return L.softcap(out, cfg.final_logit_softcap)


def chunked_ce_loss(params, h, labels, mask, cfg, chunk: int = 512):
    """Cross-entropy over (B,T) without materializing (B,T,V) logits."""
    B, T, d = h.shape
    w = head_matrix(params, cfg)
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nch = h.shape[1] // chunk
    hc = h.reshape(B, nch, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nch, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nch, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute per-chunk logits in the backward pass
    def per_chunk(args):
        hh, ll, mm = args
        logits = jnp.einsum("btd,dv->btv", hh, w,
                            preferred_element_type=jnp.float32)
        logits = L.softcap(logits, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mm)

    losses = lax.map(per_chunk, (hc, lc, mc))
    return losses.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------
def forward_full(params, x, cfg, *, mode, positions, remat=False, bspec=None):
    """Train-mode trunk: embeddings -> groups -> final norm."""
    aux_total = jnp.zeros((), jnp.float32)
    for gp, (kind, count) in zip(params["groups"], cfg.layer_groups):
        x, aux = run_group_train(gp, x, cfg, kind, positions=positions,
                                 remat=remat, bspec=bspec)
        aux_total = aux_total + aux
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def train_loss(params, batch, cfg, *, remat=True, bspec=None):
    """batch: {'tokens': (B,T) int32, optional 'patch_embeds': (B,P,d)}.

    Loss over next-token prediction on the text region.
    """
    tokens = batch["tokens"]
    patches = batch.get("patch_embeds")
    x = L.constrain_batch(embed_tokens(params, tokens, cfg, patch_embeds=patches),
                          bspec)
    B, T = x.shape[0], x.shape[1]
    positions = jnp.arange(T)
    h, aux = forward_full(params, x, cfg, mode="train", positions=positions,
                          remat=remat, bspec=bspec)
    n_text = tokens.shape[1]
    h_text = L.constrain_batch(h[:, T - n_text:], bspec)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    ce = chunked_ce_loss(params, h_text, labels, mask, cfg)
    loss = ce + cfg.router_aux_loss_coef * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(params, batch, cfg, capacity: int, bspec=None, seq_axis=None):
    """Returns (last_logits (B,V) f32, cache) with cache capacity ``capacity``."""
    tokens = batch["tokens"]
    patches = batch.get("patch_embeds")
    x = L.constrain_batch(embed_tokens(params, tokens, cfg, patch_embeds=patches),
                          bspec)
    B, T = x.shape[0], x.shape[1]
    positions = jnp.arange(T)
    cache = init_cache(cfg, B, capacity)
    new_cache = []
    for gp, c, (kind, count) in zip(params["groups"], cache, cfg.layer_groups):
        x, nc, _ = run_group_prefill(gp, x, cfg, kind, c, positions=positions,
                                     seq_axis=seq_axis)
        new_cache.append(nc)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_last(params, x[:, -1], cfg), new_cache


def decode_step(params, cache, tokens, pos, cfg, bspec=None, windowed=False,
                return_deltas=False):
    """tokens: (B,) int32 new token ids; pos: scalar int32 slot index.

    Returns (logits (B,V) f32, new_cache) — or, with return_deltas, the
    per-group K/V deltas for a sharded append (distributed.cache_update)."""
    x = L.constrain_batch(embed_tokens(params, tokens[:, None], cfg), bspec)
    new_cache = []
    for gp, c, (kind, count) in zip(params["groups"], cache, cfg.layer_groups):
        x, nc = run_group_decode(gp, x, cfg, kind, c, pos=pos, windowed=windowed,
                                 return_deltas=return_deltas)
        new_cache.append(nc)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_last(params, x[:, 0], cfg), new_cache
