"""Mixture-of-Experts FFN with GShard-style capacity-based einsum dispatch.

Tokens are routed per *group* (``ROUTE_GROUP`` tokens during train/prefill,
the whole local batch during decode) so capacity is a static shape and the
dispatch tensor stays O(group * E * C).  Because top-k indices for a token are
distinct, the K routing slots are reduced away *before* the capacity one-hot:
``dispatch`` is (g, n, E, C) — never (g, n, K, E, C).

Sharding: the group axis follows the batch ('data') axis; the expert axis
follows 'model' when divisible (expert parallelism, e.g. qwen3's 128 experts
over 16), otherwise the per-expert hidden dim is sharded (TP inside each
expert, e.g. mixtral's 8 experts).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

ROUTE_GROUP = 256  # tokens per routing group (static capacity)


def init_moe(rng, cfg) -> dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    ks = jax.random.split(rng, 4)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    return {
        "router": (jax.random.normal(ks[0], (d, E)) * std).astype(dt),
        "wi": (jax.random.normal(ks[1], (E, d, f)) * std).astype(dt),
        "wg": (jax.random.normal(ks[2], (E, d, f)) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, f, d)) * std).astype(dt),
        "norm": jnp.ones((d,), dt),
    }


def capacity(tokens_per_group: int, num_experts: int, k: int,
             factor: float = 1.25) -> int:
    c = int(tokens_per_group * k / num_experts * factor)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _route(hg: Array, p: dict, cfg, C: int):
    """hg: (g, n, d) -> dispatch (g,n,E,C), combine (g,n,E,C), aux scalar."""
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    logits = jnp.einsum("gnd,de->gne", hg, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                        # (g, n, E)
    gate_vals, gate_idx = lax.top_k(probs, K)                      # (g, n, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Slot-major cumulative position inside each expert's capacity buffer
    # (slot 0 has priority, GShard semantics).
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)        # (g, n, K, E)
    g, n = hg.shape[0], hg.shape[1]
    slot_major = onehot.transpose(0, 2, 1, 3).reshape(g, K * n, E)
    pos_sm = jnp.cumsum(slot_major, axis=1) - 1.0
    pos = (pos_sm.reshape(g, K, n, E).transpose(0, 2, 1, 3))       # (g, n, K, E)

    # A token takes at most one slot per expert -> reduce K away first.
    active = onehot > 0
    pos_r = jnp.max(jnp.where(active, pos, -1.0), axis=2)          # (g, n, E)
    gate_r = jnp.sum(jnp.where(active, gate_vals[..., None], 0.0), axis=2)

    dispatch = jax.nn.one_hot(pos_r, C, dtype=jnp.float32)         # 0 if pos<0 or >=C
    combine = dispatch * gate_r[..., None]

    # Switch-transformer load-balance aux loss.
    frac_tokens = onehot.sum(axis=2).mean(axis=1) / K              # (g, E)
    frac_probs = probs.mean(axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return dispatch, combine, aux.astype(jnp.float32)


def moe_apply(p: dict, h: Array, cfg) -> tuple[Array, Array]:
    """h: (B, T, d) normalized input -> (y, aux_loss)."""
    B, T, d = h.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok

    if T > 1:
        n = ROUTE_GROUP if T % ROUTE_GROUP == 0 else T
        hg = h.reshape(B * T // n, n, d)
    else:
        n = B
        hg = h.reshape(1, B, d)
    C = capacity(n, E, K)

    dispatch, combine, aux = _route(hg, p, cfg, C)

    xin = jnp.einsum("gnec,gnd->gecd", dispatch.astype(h.dtype), hg)
    a = jnp.einsum("gecd,edf->gecf", xin, p["wg"])
    b = jnp.einsum("gecd,edf->gecf", xin, p["wi"])
    out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(a) * b, p["wo"])
    y = jnp.einsum("gnec,gecd->gnd", combine.astype(out.dtype), out)

    return y.reshape(B, T, d), aux


def moe_block_apply(p: dict, x: Array, cfg) -> tuple[Array, Array]:
    from repro.models.layers import rmsnorm
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    y, aux = moe_apply(p, h, cfg)
    return x + y, aux
