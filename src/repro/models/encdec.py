"""Encoder-decoder transformer (Whisper-style speech backbone).

The mel-spectrogram + conv feature extractor is stubbed per the brief:
``input_specs`` supplies precomputed frame embeddings (B, encoder_seq_len,
d_model).  The encoder is bidirectional; the decoder has causal self-attention
(RoPE, cached at decode) plus cross-attention over per-layer encoder K/V that
are computed once at prefill and stored in the cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

Array = jax.Array


def init_params(rng, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_enc, k_dec, k_head = jax.random.split(rng, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"attn": L.init_attn_block(k1, cfg), "mlp": L.init_mlp(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"attn": L.init_attn_block(k1, cfg),
                "cross": L.init_attn_block(k2, cfg, cross=True),
                "mlp": L.init_mlp(k3, cfg)}

    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02
                  ).astype(dt),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size))
                             * 0.02).astype(dt)
    return params


def encode(params, audio_embeds: Array, cfg, bspec=None) -> Array:
    """audio_embeds: (B, S_enc, d) stubbed frontend output -> encoder states."""
    x = L.constrain_batch(audio_embeds.astype(jnp.dtype(cfg.dtype)), bspec)
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        y, _ = L.attn_block_apply(lp["attn"], carry, cfg, causal=False,
                                  positions=positions, mode="train")
        y = L.mlp_apply(lp["mlp"], y, cfg)
        return y, None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_trunk(params, x, cfg, enc_out, *, mode, cache=None, pos=None,
                   positions=None, remat=False, bspec=None,
                   return_deltas=False):
    """Runs decoder layers.  For prefill/decode the cache is
    {'k','v': (L,B,cap,KV,hd), 'ck','cv': (L,B,S_enc,KV,hd)}."""
    if mode == "train":
        def body(carry, lp):
            carry = L.constrain_batch(carry, bspec)
            y, _ = L.attn_block_apply(lp["attn"], carry, cfg, mode="train",
                                      positions=positions)
            enc_kv = L.encode_kv(lp["cross"], enc_out, cfg)
            y = L.cross_attn_apply(lp["cross"], y, enc_kv, cfg)
            y = L.mlp_apply(lp["mlp"], y, cfg)
            return y, None
        if remat:
            body = jax.checkpoint(body)
        x, _ = lax.scan(body, x, params["dec_layers"])
        return x, None

    if mode == "prefill":
        def body(carry, lp):
            y, kv = L.attn_block_apply(lp["attn"], carry, cfg, mode="prefill",
                                       positions=positions)
            enc_kv = L.encode_kv(lp["cross"], enc_out, cfg)
            y = L.cross_attn_apply(lp["cross"], y, enc_kv, cfg)
            y = L.mlp_apply(lp["mlp"], y, cfg)
            return y, (kv["k"], kv["v"], enc_kv["k"], enc_kv["v"])
        x, (ks, vs, cks, cvs) = lax.scan(body, x, params["dec_layers"])
        return x, (ks, vs, cks, cvs)

    # decode (append-outside-scan: bodies emit K/V deltas)
    def body(carry, inp):
        lp, k_l, v_l, ck, cv = inp
        y, kv = L.attn_block_apply(lp["attn"], carry, cfg, mode="decode",
                                   cache={"k": k_l, "v": v_l}, cache_pos=pos,
                                   positions=pos[None])
        y = L.cross_attn_apply(lp["cross"], y, {"k": ck, "v": cv}, cfg)
        y = L.mlp_apply(lp["mlp"], y, cfg)
        return y, (kv["k"], kv["v"])

    x, (dk, dv) = lax.scan(body, x, (params["dec_layers"], cache["k"], cache["v"],
                                     cache["ck"], cache["cv"]))
    if return_deltas:
        return x, (dk, dv)
    ks = lax.dynamic_update_slice_in_dim(cache["k"], dk.astype(cache["k"].dtype),
                                         pos, axis=3)
    vs = lax.dynamic_update_slice_in_dim(cache["v"], dv.astype(cache["v"].dtype),
                                         pos, axis=3)
    return x, (ks, vs)


def train_loss(params, batch, cfg, *, remat=True, bspec=None):
    """batch: {'tokens': (B,T), 'audio_embeds': (B,S_enc,d)}."""
    from repro.models.transformer import chunked_ce_loss
    tokens = batch["tokens"]
    enc_out = encode(params, batch["audio_embeds"], cfg, bspec)
    x = L.constrain_batch(params["embed"][tokens].astype(jnp.dtype(cfg.dtype)),
                          bspec)
    positions = jnp.arange(tokens.shape[1])
    h, _ = _decoder_trunk(params, x, cfg, enc_out, mode="train",
                          positions=positions, remat=remat, bspec=bspec)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    ce = chunked_ce_loss(params, h, labels, mask, cfg)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def prefill(params, batch, cfg, capacity: int, bspec=None):
    from repro.models.transformer import logits_last
    tokens = batch["tokens"]
    enc_out = encode(params, batch["audio_embeds"], cfg, bspec)
    x = L.constrain_batch(params["embed"][tokens].astype(jnp.dtype(cfg.dtype)),
                          bspec)
    B, T = tokens.shape
    positions = jnp.arange(T)
    h, (ks, vs, cks, cvs) = _decoder_trunk(params, x, cfg, enc_out,
                                           mode="prefill", positions=positions)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    Ld = cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k_buf = jnp.zeros((Ld, B, KV, capacity, hd), dt).at[:, :, :, :T].set(
        ks.astype(dt).transpose(0, 1, 3, 2, 4))
    v_buf = jnp.zeros((Ld, B, KV, capacity, hd), dt).at[:, :, :, :T].set(
        vs.astype(dt).transpose(0, 1, 3, 2, 4))
    cache = {"k": k_buf, "v": v_buf, "ck": cks.astype(dt), "cv": cvs.astype(dt)}
    return logits_last(params, h[:, -1], cfg), cache


def init_cache(cfg, batch: int, capacity: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    Ld, Se = cfg.num_layers, cfg.encoder_seq_len
    return {
        "k": jnp.zeros((Ld, batch, KV, capacity, hd), dt),
        "v": jnp.zeros((Ld, batch, KV, capacity, hd), dt),
        "ck": jnp.zeros((Ld, batch, Se, KV, hd), dt),
        "cv": jnp.zeros((Ld, batch, Se, KV, hd), dt),
    }


def decode_step(params, cache, tokens, pos, cfg, bspec=None,
                return_deltas=False):
    from repro.models.transformer import logits_last
    x = L.constrain_batch(params["embed"][tokens[:, None]].astype(jnp.dtype(cfg.dtype)),
                          bspec)
    h, (ks, vs) = _decoder_trunk(params, x, cfg, None, mode="decode",
                                 cache=cache, pos=pos,
                                 return_deltas=return_deltas)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    new_cache = {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"]}
    return logits_last(params, h[:, 0], cfg), new_cache
