"""Shared neural-net layers: norms, RoPE, attention (flash + decode), MLP.

Everything is a pure function over explicit parameter pytrees.  Attention is
implemented blockwise (online softmax over KV blocks inside a ``lax.scan``,
query blocks via ``lax.map``) so that 32k-token prefill lowers with bounded
live memory — this is the pure-JAX oracle mirrored by the Pallas kernel in
``repro.kernels.flash_attention``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

NEG_INF = -2.0 ** 30  # large-negative that survives bf16 softmax math in f32


def constrain_batch(x: Array, bspec) -> Array:
    """Pin the leading (batch) axis of an activation to the given mesh axes
    (None = leave to GSPMD).  Without this, propagation through the embedding
    gather can replicate the batch and shard d_model instead — 16x waste."""
    if bspec is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(bspec, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (..., T, H, hd); positions broadcastable to (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs          # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]                                # (..., T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise flash attention (pure-JAX) — training / prefill path.
# ---------------------------------------------------------------------------
def _pad_axis(x: Array, axis: int, multiple: int) -> Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mask_for(qpos, kpos, causal, window, kv_len):
    mask = (kpos[None, :] < kv_len)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask


def _scores(qblk, kblk, logit_cap, qpos, kpos, causal, window, kv_len):
    """qblk pre-scaled (B,bq,KV,G,hd); kblk (B,bk,KV,hd) ->
    (s_capped, raw) both (B,KV,G,bq,bk) f32, masked with NEG_INF."""
    raw = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                     preferred_element_type=jnp.float32)
    s = softcap(raw, logit_cap)
    mask = _mask_for(qpos, kpos, causal, window, kv_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s, raw


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(static, q, k, v):
    out, _ = _flash_fwd_res(static, q, k, v)
    return out


def _flash_fwd_res(static, q, k, v):
    """q: (B, nq, bq, KV, G, hd); k/v: (B, nk, bk, KV, hd).
    Returns (out (B,nq,bq,KV,G,hd), lse (B,KV,G,nq,bq)).

    parallel_q (last static field): process q blocks with vmap instead of a
    sequential lax.map — under GSPMD this lets the nq axis shard over the
    'model' mesh axis (sequence-parallel prefill for archs whose head counts
    don't divide it; a lax.map over a sharded axis would gather per step)."""
    causal, window, logit_cap, q_offset, kv_len, parallel_q = static
    B, nq, bq, KV, G, hd = q.shape
    nk, bk = k.shape[1], k.shape[2]
    scale = hd ** -0.5

    def q_block_body(qblk_raw, qi):
        qblk = qblk_raw * scale
        qpos = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, ki):
            m, l, acc = carry
            kpos = ki * bk + jnp.arange(bk)
            s, _ = _scores(qblk, k[:, ki], logit_cap, qpos, kpos,
                           causal, window, kv_len)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v[:, ki],
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, bq, KV, G, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l.transpose(0, 3, 1, 2)[..., None]).astype(q.dtype)
        lse = m + jnp.log(l)                       # (B, KV, G, bq)
        return out, lse

    if parallel_q:
        outs, lses = jax.vmap(q_block_body, in_axes=(1, 0))(
            q, jnp.arange(nq))
    else:
        outs, lses = lax.map(lambda qi: q_block_body(q[:, qi], qi),
                             jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5)             # (B,nq,bq,KV,G,hd)
    lse = lses.transpose(1, 2, 3, 0, 4)                # (B,KV,G,nq,bq)
    return out, lse


def _flash_vjp_fwd(static, q, k, v):
    out, lse = _flash_fwd_res(static, q, k, v)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(static, res, dout):
    """Flash-attention backward: recompute scores blockwise (no (bq x bk)
    probability tensors are ever saved — this is why it exists; naive AD of
    the forward scan saves p per block per layer per microbatch).

    Note: parallel_q (sequence-parallel prefill) is forward-only — the
    backward keeps the sequential q-block loop (prefill takes no grads)."""
    causal, window, logit_cap, q_offset, kv_len, _parallel_q = static
    q, k, v, out, lse = res
    B, nq, bq, KV, G, hd = q.shape
    nk, bk = k.shape[1], k.shape[2]
    scale = hd ** -0.5

    # D_i = rowsum(dO * O): (B, KV, G, nq, bq)
    delta = jnp.einsum("bnqkgd,bnqkgd->bkgnq", dout.astype(jnp.float32),
                       out.astype(jnp.float32))

    def ds_block(qblk_scaled, qpos, ki, lse_q, delta_q, dout_q):
        """Recompute p and ds for one (q-block, kv-block) pair.
        Returns (p, ds) both (B,KV,G,bq,bk) f32."""
        kpos = ki * bk + jnp.arange(bk)
        s, raw = _scores(qblk_scaled, k[:, ki], logit_cap, qpos, kpos,
                         causal, window, kv_len)
        p = jnp.exp(s - lse_q[..., None])
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dout_q.astype(jnp.float32),
                        v[:, ki].astype(jnp.float32))
        ds = p * (dp - delta_q[..., None])
        if logit_cap is not None:
            ds = ds * (1.0 - jnp.square(jnp.tanh(raw / logit_cap)))
        return p, ds

    # ---- pass A: dq (q-block major, scan kv blocks) ----
    def dq_block(qi):
        qblk = q[:, qi] * scale
        qpos = q_offset + qi * bq + jnp.arange(bq)
        lse_q, delta_q, dout_q = lse[:, :, :, qi], delta[:, :, :, qi], dout[:, qi]

        def kv_step(dq_acc, ki):
            p, ds = ds_block(qblk, qpos, ki, lse_q, delta_q, dout_q)
            dq_acc = dq_acc + jnp.einsum(
                "bkgqs,bskd->bqkgd", ds, k[:, ki].astype(jnp.float32))
            return dq_acc, None

        dq0 = jnp.zeros((B, bq, KV, G, hd), jnp.float32)
        dq, _ = lax.scan(kv_step, dq0, jnp.arange(nk))
        return dq * scale

    dq = lax.map(dq_block, jnp.arange(nq)).transpose(1, 0, 2, 3, 4, 5)

    # ---- pass B: dk, dv (kv-block major, scan q blocks) ----
    def dkv_block(ki):
        def q_step(carry, qi):
            dk_acc, dv_acc = carry
            qblk = q[:, qi] * scale
            qpos = q_offset + qi * bq + jnp.arange(bq)
            p, ds = ds_block(qblk, qpos, ki, lse[:, :, :, qi],
                             delta[:, :, :, qi], dout[:, qi])
            dv_acc = dv_acc + jnp.einsum(
                "bkgqs,bqkgd->bskd", p, dout[:, qi].astype(jnp.float32))
            dk_acc = dk_acc + jnp.einsum(
                "bkgqs,bqkgd->bskd", ds, q[:, qi].astype(jnp.float32) * scale)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, bk, KV, hd), jnp.float32)
        (dk, dv), _ = lax.scan(q_step, (z, z), jnp.arange(nq))
        return dk, dv

    dks, dvs = lax.map(dkv_block, jnp.arange(nk))
    dk = dks.transpose(1, 0, 2, 3, 4)
    dv = dvs.transpose(1, 0, 2, 3, 4)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(
    q: Array,                    # (B, Tq, H, hd)
    k: Array,                    # (B, Tk, KV, hd)
    v: Array,                    # (B, Tk, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,           # absolute position of q[0] (prefill continuation)
    kv_valid_len: Optional[int] = None,    # mask k positions >= this
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    seq_axis: Optional[str] = None,  # shard q blocks over this mesh axis
) -> Array:
    """Online-softmax attention, O(block_q * Tk) live memory per step,
    custom VJP with blockwise recomputation (differentiable; seq_axis is a
    forward-only sequence-parallel mode for prefill).

    Block sizes left None defer to the autotune cache (the same
    per-(shape-class, dtype, backend) lookup the Pallas wrappers use);
    explicit kwargs always win, and an empty cache falls back to the
    historical 256/512 defaults."""
    B, Tq, H, hd = q.shape
    _, Tk, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV

    if block_q is None or block_k is None:
        from repro.perf import autotune
        cfg = autotune.lookup("flash_attention", q.dtype, BKV=B * KV, G=G,
                              hd=hd, Tq=max(Tq, 1), Tk=max(Tk, 1),
                              causal=causal)
        if block_q is None:
            block_q = cfg["block_q"] if cfg else 256
        if block_k is None:
            block_k = cfg["block_k"] if cfg else 512

    block_q = min(block_q, max(Tq, 1))
    block_k = min(block_k, max(Tk, 1))

    qp = _pad_axis(q, 1, block_q)
    kp = _pad_axis(k, 1, block_k)
    vp = _pad_axis(v, 1, block_k)
    nq = qp.shape[1] // block_q
    nk = kp.shape[1] // block_k

    qp = qp.reshape(B, nq, block_q, KV, G, hd)
    kp = kp.reshape(B, nk, block_k, KV, hd)
    vp = vp.reshape(B, nk, block_k, KV, hd)

    kv_len = Tk if kv_valid_len is None else kv_valid_len
    if seq_axis is not None:
        # sequence-parallel prefill (§Perf): shard the q-block axis over the
        # given mesh axis; K/V stay replicated (gathered once per layer).
        from jax.sharding import PartitionSpec as P
        qp = jax.lax.with_sharding_constraint(
            qp, P(None, seq_axis, None, None, None, None))
    static = (causal, window, logit_cap, q_offset, kv_len,
              seq_axis is not None)
    out = _flash(static, qp, kp, vp)                   # (B,nq,bq,KV,G,hd)
    out = out.reshape(B, nq * block_q, H, hd)
    return out[:, :Tq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Single-token decode attention against a KV cache (pure-JAX oracle; the
# Pallas kernel in repro.kernels.decode_attention mirrors this).
# ---------------------------------------------------------------------------
def decode_attention(
    q: Array,        # (B, H, hd)  — one new token per sequence
    k_cache: Array,  # (B, KV, S, hd) — attention-native layout (§Perf: the
    v_cache: Array,  #                  (B,S,KV,hd) layout forced a full cache
    pos: Array,      #                  transpose per layer per step)
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    k_new: Optional[Array] = None,   # (B, KV, 1, hd) — the new token's K/V,
    v_new: Optional[Array] = None,   # attended separately (append-outside-scan
    exclude_slot: Optional[Array] = None,  # ring buffers: stale slot to mask
) -> Array:                          # decode, §Perf: cache stays read-only)
    B, H, hd = q.shape
    _, KV, S, _ = k_cache.shape
    G = H // KV
    scale = hd ** -0.5
    qh = q.reshape(B, KV, G, hd) * scale
    s = jnp.einsum("bkgd,bksd->bkgs", qh, k_cache,
                   preferred_element_type=jnp.float32)
    s = softcap(s, logit_cap)
    kpos = jnp.arange(S)
    # with k_new provided, the cache holds positions < pos (slot pos stale)
    mask = (kpos < pos) if k_new is not None else (kpos <= pos)
    if window is not None:
        mask = mask & (kpos > pos - window)
    if exclude_slot is not None:
        mask = mask & (kpos != exclude_slot)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    if k_new is None:
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bksd->bkgd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, H, hd).astype(q.dtype)

    # two-part softmax: combine cache scores (sequence axis may be sharded —
    # a concat would make GSPMD gather the score matrix) with the new token's
    # self-score via explicit max/denominator merging.  Reductions over the
    # sharded S become small (B,KV,G) all-reduces.
    s_self = softcap(jnp.einsum("bkgd,bkxd->bkgx", qh, k_new,
                                preferred_element_type=jnp.float32), logit_cap)
    m = jnp.maximum(s.max(axis=-1, keepdims=True), s_self)     # (B,KV,G,1)
    p_cache = jnp.exp(s - m)
    p_self = jnp.exp(s_self - m)
    denom = p_cache.sum(axis=-1, keepdims=True) + p_self       # (B,KV,G,1)
    out = jnp.einsum("bkgs,bksd->bkgd", p_cache.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out + jnp.einsum("bkgx,bkxd->bkgd", p_self.astype(v_new.dtype),
                           v_new, preferred_element_type=jnp.float32)
    out = out / denom
    return out.reshape(B, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (pre-norm [+ optional post-norm], GQA, RoPE, residual)
# ---------------------------------------------------------------------------
def init_attn_block(rng, cfg, *, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 8)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    # Head axes kept explicit (d, H, hd) so TP sharding on the head axis never
    # crosses a reshape (GSPMD propagates cleanly through the einsums).
    p = {
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, KV, hd)) * std).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, KV, hd)) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * std).astype(dt),
        "norm": jnp.ones((d,), dt),
    }
    if cfg.attention_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
    if cfg.post_block_norm:
        p["post_norm"] = jnp.ones((d,), dt)
    if cross:
        p["cross_norm"] = jnp.ones((d,), dt)
    return p


def qkv_proj(p: dict, x: Array, cfg) -> tuple[Array, Array, Array]:
    q = jnp.einsum("btd,dhx->bthx", x, p["wq"])
    k = jnp.einsum("btd,dkx->btkx", x, p["wk"])
    v = jnp.einsum("btd,dkx->btkx", x, p["wv"])
    if cfg.attention_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def attn_block_apply(
    p: dict,
    x: Array,                   # (B, T, d)
    cfg,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    positions: Optional[Array] = None,   # (T,) absolute positions
    cache: Optional[dict] = None,        # {'k','v'}: (B, S, KV, hd) — decode only
    cache_pos: Optional[Array] = None,   # scalar int32
    mode: str = "train",                 # train | prefill | decode
    ring: bool = False,                  # windowed ring-buffer cache (decode)
    seq_axis: Optional[str] = None,      # sequence-parallel attention (prefill)
):
    """Returns (y, new_kv) where new_kv is (k, v) for prefill, updated cache for
    decode, and None for train.

    ring=True (sliding-window archs, §Perf): the cache holds only the last
    ``window`` positions; the write slot is ``pos % capacity`` and attention
    reads the whole (unmasked) ring — valid once pos >= capacity-1, which the
    serving engine guarantees by prefilling ≥ window tokens.  Keys carry
    absolute RoPE so ring order does not matter."""
    B, T, d = x.shape
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = qkv_proj(p, h, cfg)
    if positions is None:
        positions = jnp.arange(T)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        assert cache is not None and T == 1
        capacity = cache["k"].shape[2]          # (B, KV, S, hd)
        k_new = k.transpose(0, 2, 1, 3)          # (B, KV, 1, hd)
        v_new = v.transpose(0, 2, 1, 3)
        if getattr(cfg, "kernel_impl", "xla") == "pallas" and not ring:
            # Pallas decode kernel (cache-only variant): fold the new token in
            # with a DUS, then run the blocked online-softmax kernel.
            from repro.kernels.decode_attention.ops import (
                decode_attention_kvmajor)
            kc = lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), cache_pos, axis=2)
            vc = lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), cache_pos, axis=2)
            o = decode_attention_kvmajor(q[:, 0], kc, vc, cache_pos,
                                         window=window,
                                         logit_cap=cfg.attn_logit_softcap)
            o = o[:, None]
            new_kv = {"k": k_new.astype(cache["k"].dtype),
                      "v": v_new.astype(cache["v"].dtype)}
            y = jnp.einsum("bthx,hxd->btd", o, p["wo"])
            if cfg.post_block_norm:
                y = rmsnorm(y, p["post_norm"], cfg.norm_eps)
            return x + y, new_kv
        # append-outside-scan: the cache is read-only here; the caller writes
        # the returned (k_new, v_new) delta once per step (one stacked DUS
        # outside the layer scan instead of a full cache rewrite per layer).
        o = decode_attention(q[:, 0], cache["k"], cache["v"],
                             jnp.asarray(capacity, jnp.int32) if ring
                             else cache_pos,
                             window=None if ring else window,
                             logit_cap=cfg.attn_logit_softcap,
                             k_new=k_new.astype(cache["k"].dtype),
                             v_new=v_new.astype(cache["v"].dtype),
                             exclude_slot=(cache_pos % capacity) if ring
                             else None)
        o = o[:, None]                            # (B, 1, H, hd)
        new_kv = {"k": k_new.astype(cache["k"].dtype),
                  "v": v_new.astype(cache["v"].dtype)}
    elif (mode == "prefill" and getattr(cfg, "kernel_impl", "xla") == "pallas"
          and causal):
        # Pallas flash-attention kernel (interpret mode on CPU; TPU target)
        from repro.kernels.flash_attention.ops import flash_attention as pl_flash
        o = pl_flash(q, k, v, causal=True, window=window,
                     logit_cap=cfg.attn_logit_softcap)
        new_kv = {"k": k, "v": v}
    else:
        o = flash_attention(q, k, v, causal=causal, window=window,
                            logit_cap=cfg.attn_logit_softcap,
                            seq_axis=seq_axis if mode == "prefill" else None)
        new_kv = {"k": k, "v": v} if mode == "prefill" else None

    y = jnp.einsum("bthx,hxd->btd", o, p["wo"])
    if cfg.post_block_norm:
        y = rmsnorm(y, p["post_norm"], cfg.norm_eps)
    return x + y, new_kv


def cross_attn_apply(p: dict, x: Array, enc_kv: dict, cfg) -> Array:
    """Cross-attention over precomputed encoder K/V (no positions)."""
    h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
    q = jnp.einsum("btd,dhx->bthx", h, p["wq"])
    if cfg.attention_bias:
        q = q + p["bq"]
    o = flash_attention(q, enc_kv["k"], enc_kv["v"], causal=False,
                        logit_cap=cfg.attn_logit_softcap)
    return x + jnp.einsum("bthx,hxd->btd", o, p["wo"])


def encode_kv(p: dict, enc_out: Array, cfg) -> dict:
    """Precompute cross-attention K/V from encoder output."""
    k = jnp.einsum("bsd,dkx->bskx", enc_out, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", enc_out, p["wv"])
    if cfg.attention_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(rng, cfg, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wi": (jax.random.normal(ks[0], (d, f)) * std).astype(dt),
        "wg": (jax.random.normal(ks[1], (d, f)) * std).astype(dt),
        "wo": (jax.random.normal(ks[2], (f, d)) * std).astype(dt),
        "norm": jnp.ones((d,), dt),
    }
    if cfg.post_block_norm:
        p["post_norm"] = jnp.ones((d,), dt)
    return p


def mlp_apply(p: dict, x: Array, cfg) -> Array:
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    y = (jax.nn.silu(h @ p["wg"]) * (h @ p["wi"])) @ p["wo"]
    if cfg.post_block_norm:
        y = rmsnorm(y, p["post_norm"], cfg.norm_eps)
    return x + y
