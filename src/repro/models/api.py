"""Unified model API — dispatches on ``cfg.arch_type``.

All entry points are pure functions usable under ``jax.jit``,
``jax.eval_shape`` (dry-run) and ``jax.grad``:

  init_params(rng, cfg)                      -> params pytree
  train_loss(params, batch, cfg)             -> (loss, metrics)
  prefill(params, batch, cfg, capacity)      -> (last_logits, cache)
  decode_step(params, cache, tokens, pos, cfg) -> (logits, cache)
  init_cache(cfg, batch, capacity)           -> cache pytree
  make_batch / batch_specs                   -> concrete / abstract inputs
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec, transformer

Array = jax.Array


def _is_encdec(cfg: ModelConfig) -> bool:
    return cfg.is_encoder_decoder


def init_params(rng, cfg: ModelConfig):
    if _is_encdec(cfg):
        return encdec.init_params(rng, cfg)
    return transformer.init_params(rng, cfg)


def train_loss(params, batch, cfg: ModelConfig, *, remat: bool = True,
               bspec=None):
    if _is_encdec(cfg):
        return encdec.train_loss(params, batch, cfg, remat=remat, bspec=bspec)
    return transformer.train_loss(params, batch, cfg, remat=remat, bspec=bspec)


def prefill(params, batch, cfg: ModelConfig, capacity: int, bspec=None,
            seq_axis=None):
    if _is_encdec(cfg):
        return encdec.prefill(params, batch, cfg, capacity, bspec=bspec)
    return transformer.prefill(params, batch, cfg, capacity, bspec=bspec,
                               seq_axis=seq_axis)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, bspec=None,
                windowed: bool = False, return_deltas: bool = False):
    if _is_encdec(cfg):
        return encdec.decode_step(params, cache, tokens, pos, cfg, bspec=bspec,
                                  return_deltas=return_deltas)
    return transformer.decode_step(params, cache, tokens, pos, cfg, bspec=bspec,
                                   windowed=windowed,
                                   return_deltas=return_deltas)


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               windowed: bool = False):
    if _is_encdec(cfg):
        return encdec.init_cache(cfg, batch, capacity)
    return transformer.init_cache(cfg, batch, capacity, windowed=windowed)


# ---------------------------------------------------------------------------
# Input construction — concrete batches (smoke/bench) and abstract specs
# (dry-run; ShapeDtypeStruct, no allocation).
# ---------------------------------------------------------------------------
def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token length once stub frontend tokens are accounted for."""
    if cfg.frontend == "vision_stub":
        return max(seq_len - cfg.num_frontend_tokens, 1)
    return seq_len


def batch_shapes(cfg: ModelConfig, shape: InputShape) -> dict:
    """{name: (shape, dtype)} for each model input of this (arch, input-shape)."""
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = ((B, _text_len(cfg, S)), jnp.int32)
        if cfg.frontend == "vision_stub":
            out["patch_embeds"] = ((B, cfg.num_frontend_tokens, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio_stub":
            out["audio_embeds"] = ((B, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    else:  # decode: one token against a cache of S
        out["tokens"] = ((B,), jnp.int32)
    return out


def make_batch(rng, cfg: ModelConfig, shape: InputShape) -> dict:
    keys = jax.random.split(rng, 4)
    batch = {}
    for i, (name, (shp, dt)) in enumerate(sorted(batch_shapes(cfg, shape).items())):
        if jnp.issubdtype(dt, jnp.integer):
            batch[name] = jax.random.randint(keys[i], shp, 0, cfg.vocab_size, dt)
        else:
            batch[name] = (jax.random.normal(keys[i], shp) * 0.02).astype(dt)
    return batch


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    return {name: jax.ShapeDtypeStruct(shp, dt)
            for name, (shp, dt) in batch_shapes(cfg, shape).items()}


def cache_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract KV/state cache for decode shapes (capacity = seq_len)."""
    fn = lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    return jax.eval_shape(fn)


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
