"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Pure-JAX chunked SSD for train/prefill (mirrored by the Pallas kernel in
``repro.kernels.ssd_scan``) and a single-step recurrence for decode.

Layout conventions:
  d_inner = ssm_expand * d_model;  H = d_inner // ssm_head_dim heads
  x_ssm: (B, T, H, P)   P = ssm_head_dim
  B/C:   (B, T, N)      N = ssm_state_size  (single "group", shared across heads)
  state: (B, H, P, N)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return d_in, H, cfg.ssm_head_dim, cfg.ssm_state_size


def init_mamba_block(rng, cfg) -> dict:
    d = cfg.d_model
    d_in, H, P, N = dims(cfg)
    conv_dim = d_in + 2 * N
    ks = jax.random.split(rng, 6)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    return {
        "norm": jnp.ones((d,), dt),
        # in_proj -> [z (d_in), xBC (conv_dim), dt (H)]
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_in + 2 * N + H)) * std).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim)) * std).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))).astype(jnp.float32),
        "gate_norm": jnp.ones((d_in,), dt),
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * std).astype(dt),
    }


def _split_proj(proj: Array, cfg):
    d_in, H, P, N = dims(cfg)
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + d_in + 2 * N]
    dt_raw = proj[..., -H:]
    return z, xBC, dt_raw


def _causal_conv(xBC: Array, w: Array, b: Array,
                 conv_state: Optional[Array] = None):
    """Depthwise causal conv along T.  xBC: (B, T, Cdim); w: (W, Cdim).

    Returns (out, new_conv_state) where conv_state holds the last W-1 inputs.
    """
    W = w.shape[0]
    if conv_state is None:
        prev = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]), xBC.dtype)
    else:
        prev = conv_state
    xp = jnp.concatenate([prev, xBC], axis=1)           # (B, T+W-1, C)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1):]
    return jax.nn.silu(out), new_state


def ssd_chunked(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int, init_state: Optional[Array] = None):
    """Chunked SSD, sequential ``lax.scan`` over chunks (bounded live memory:
    the quadratic (chunk x chunk) decay/score tensors exist for one chunk at a
    time — this is the pure-JAX oracle mirrored by kernels/ssd_scan).

    x:  (B, T, H, P) inputs;  dt: (B, T, H) softplus'd step sizes
    A:  (H,) negative reals;  Bm/Cm: (B, T, N)
    Returns (y (B,T,H,P), final_state (B,H,P,N)).
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    nc = max(T // chunk, 1)
    chunk = T // nc
    assert nc * chunk == T, (T, chunk)

    # chunk-major for scan: (nc, B, c, ...)
    xg = x.reshape(Bsz, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtg = dt.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3)
    Bg = Bm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)
    Cg = Cm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inp):
        xc, dtc, Bc, Cc = inp                    # (B,c,H,P) (B,c,H) (B,c,N) (B,c,N)
        dA = dtc * A                             # (B,c,H) log-decays (<=0)
        cum = jnp.cumsum(dA, axis=1)             # inclusive
        # intra-chunk: L[t,s] = exp(cum[t]-cum[s]) for s<=t
        seg = cum[:, :, None, :] - cum[:, None, :, :]       # (B,t,s,H)
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("btn,bsn->bts", Cc, Bc,
                            preferred_element_type=jnp.float32)
        W = scores[..., None] * L                           # (B,t,s,H)
        xdt = (xc * dtc[..., None]).astype(jnp.float32)     # (B,s,H,P)
        y_c = jnp.einsum("btsh,bshp->bthp", W, xdt)
        # contribution of the state entering this chunk
        decay_from_start = jnp.exp(cum)                     # (B,t,H)
        y_c += jnp.einsum("btn,bhpn,bth->bthp",
                          Cc.astype(jnp.float32), state, decay_from_start)
        # update state to chunk end
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)        # (B,s,H)
        S_local = jnp.einsum("bsh,bsn,bshp->bhpn",
                             decay_to_end * dtc, Bc.astype(jnp.float32),
                             xc.astype(jnp.float32))
        chunk_decay = jnp.exp(cum[:, -1, :])                # (B,H)
        new_state = state * chunk_decay[:, :, None, None] + S_local
        return new_state, y_c.astype(x.dtype)

    final_state, ys = lax.scan(step, s0, (xg, dtg, Bg, Cg))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, T, H, P)
    return y, final_state


def ssd_decode_step(state: Array, x: Array, dt: Array, A: Array,
                    Bm: Array, Cm: Array):
    """One-token recurrence.  state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bm/Cm: (B,N).  Returns (y (B,H,P), new_state)."""
    dA = jnp.exp(dt * A)                                  # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def mamba_block_apply(p: dict, x: Array, cfg, *, state: Optional[dict] = None,
                      mode: str = "train"):
    """Residual Mamba2 block.

    state (decode): {'ssm': (B,H,P,N) f32, 'conv': (B, W-1, conv_dim)}
    Returns (y, new_state) — new_state None for train, carried for
    prefill/decode.
    """
    from repro.models.layers import rmsnorm

    B, T, d = x.shape
    d_in, H, P, N = dims(cfg)
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)

    conv_state = state["conv"] if state is not None else None
    xBC_c, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs = xBC_c[..., :d_in].reshape(B, T, H, P)
    Bm = xBC_c[..., d_in:d_in + N]
    Cm = xBC_c[..., d_in + N:]

    if mode == "decode":
        assert T == 1
        y1, new_ssm = ssd_decode_step(state["ssm"], xs[:, 0], dt[:, 0], A,
                                      Bm[:, 0], Cm[:, 0])
        y = y1[:, None]
    elif (mode == "prefill" and getattr(cfg, "kernel_impl", "xla") == "pallas"
          and state is None and T % min(cfg.ssm_chunk_size, T) == 0):
        # Pallas SSD kernel (interpret mode on CPU; TPU target)
        from repro.kernels.ssd_scan.ops import ssd_scan as pl_ssd
        y, new_ssm = pl_ssd(xs, dt, A, Bm, Cm,
                            chunk=min(cfg.ssm_chunk_size, T))
        y = y.astype(x.dtype)
    else:
        init = state["ssm"] if state is not None else None
        y, new_ssm = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk_size, init)

    y = y + xs * p["D"][:, None].astype(x.dtype)
    y = y.reshape(B, T, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]

    new_state = None
    if mode in ("prefill", "decode"):
        new_state = {"ssm": new_ssm, "conv": new_conv}
    return x + out, new_state


def init_mamba_state(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    d_in, H, P, N = dims(cfg)
    conv_dim = d_in + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }
