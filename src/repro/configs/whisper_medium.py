"""Whisper medium — encoder-decoder speech model; conv/mel frontend stubbed.

[arXiv:2212.04356] 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
``input_specs`` supplies precomputed frame embeddings (B, 1500, d_model)
in place of the mel-spectrogram + conv feature extractor (per brief).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_seq_len=1500,
    frontend="audio_stub",
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    name="whisper-medium-tiny",
    num_layers=2,
    encoder_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    encoder_seq_len=64,
)
