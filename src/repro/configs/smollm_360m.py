"""SmolLM 360M — small llama-arch dense model.

[hf:HuggingFaceTB/SmolLM-135M family] 32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    name="smollm-360m-tiny",
    num_layers=2,
    d_model=120,
    num_heads=3,
    num_kv_heads=1,
    head_dim=40,
    d_ff=256,
    vocab_size=512,
)
