"""Qwen2 72B — dense GQA with QKV bias.

[arXiv:2407.10671] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    arch_type="dense",
    source="arXiv:2407.10671",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    attention_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    name="qwen2-72b-tiny",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
)
