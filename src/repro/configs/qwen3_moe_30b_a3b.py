"""Qwen3-MoE 30B-A3B — 128-expert top-8 fine-grained MoE.

[hf:Qwen/Qwen3-30B-A3B] 48L d_model=2048 32H (GQA kv=4) d_ff=768/expert
vocab=151936, MoE 128 experts top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                # per-expert hidden
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=768,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    name="qwen3-moe-30b-a3b-tiny",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=64,
    moe_d_ff=64,
    vocab_size=512,
    num_experts=4,
    num_experts_per_tok=2,
)
