"""InternVL2 2B — VLM: InternViT (stubbed) + InternLM2-1.8B language backbone.

[arXiv:2404.16821] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
``input_specs`` supplies precomputed patch embeddings (B, 256, d_model)
in place of the ViT encoder + MLP projector (per brief).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision_stub",
    num_frontend_tokens=256,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    name="internvl2-2b-tiny",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    num_frontend_tokens=16,
)
