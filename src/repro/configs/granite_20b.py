"""Granite 20B Code — dense llama-arch with MQA (kv=1).

[arXiv:2405.04324] 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    arch_type="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    name="granite-20b-tiny",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
)
