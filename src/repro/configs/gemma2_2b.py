"""Gemma 2 2B — alternating local/global attention, logit softcaps.

[arXiv:2408.00118] 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    source="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    sliding_window=4096,
    local_global_alternating=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norm=True,
    scale_embeddings=True,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    name="gemma2-2b-tiny",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    sliding_window=64,
)
