"""Zamba2 1.2B — Mamba2 backbone with a shared attention block.

[arXiv:2411.15242] 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  The shared transformer block is applied every
``hybrid_attn_every`` mamba blocks with tied parameters (the paper's
per-application LoRA deltas are simplified away; noted in DESIGN.md).
The shared attention uses a sliding window in this config so that
long-context decode stays memory-bounded.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state_size=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=5,     # super-block = 5 mamba + 1 shared attn; 6x6=36 + 2 mamba
    sliding_window=4096,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    name="zamba2-1.2b-tiny",
    num_layers=6,            # one super-block (5 mamba + shared attn)
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    ssm_state_size=16,
    ssm_head_dim=32,
    hybrid_attn_every=5,
    sliding_window=64,
)
