"""Mamba2 1.3B — attention-free SSD (state-space duality).

[arXiv:2405.21060] 48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state_size=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    name="mamba2-1.3b-tiny",
    num_layers=2,
    d_model=128,
    ssm_state_size=16,
    ssm_head_dim=32,
    vocab_size=512,
)
