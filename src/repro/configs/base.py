"""Model / run configuration system.

Every assigned architecture gets one file in this package exporting a
``CONFIG`` (full-scale, exercised only via the dry-run) and a ``TINY``
(reduced same-family variant: <=2 layers, d_model<=512, <=4 experts) used by
smoke tests, examples, and real-execution benchmarks.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence


# ---------------------------------------------------------------------------
# Block kinds used by the layer pattern machinery.  A model is a sequence of
# "groups"; each group is (kind, count) and is executed with lax.scan over its
# stacked parameters so that 80-layer models keep a compact HLO.
# ---------------------------------------------------------------------------
ATTN = "attn"          # full causal self-attention + MLP (or MoE) block
SWA = "swa"            # sliding-window causal attention + MLP/MoE block
MAMBA = "mamba"        # Mamba2 SSD block
SHARED_ATTN = "shared_attn"  # zamba-style shared (tied) attention block
ENC_ATTN = "enc_attn"  # bidirectional encoder self-attention block
DEC_ATTN = "dec_attn"  # decoder block with self- and cross-attention


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                         # citation: arXiv id / model card
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default d_model // num_heads

    # --- attention variants -------------------------------------------------
    attention_bias: bool = False        # qwen2: bias on QKV projections
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None         # SWA width (mixtral/gemma2 local)
    local_global_alternating: bool = False       # gemma2: L,G,L,G,...
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    post_block_norm: bool = False       # gemma2 uses pre+post norms
    scale_embeddings: bool = False      # gemma2 multiplies embeds by sqrt(d)
    tie_embeddings: bool = True

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: Optional[int] = None      # expert hidden size (d_ff used if None)
    router_aux_loss_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state_size: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk_size: int = 256

    # --- hybrid (zamba2) ----------------------------------------------------
    hybrid_attn_every: int = 0          # insert one shared attn block every k mamba blocks

    # --- encoder-decoder (whisper) ------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0            # frames after the (stubbed) conv frontend

    # --- modality frontend stubs --------------------------------------------
    frontend: Optional[str] = None      # 'audio_stub' | 'vision_stub'
    num_frontend_tokens: int = 0        # patch/frame embeddings prepended (vlm)

    # --- numerics / kernels ---------------------------------------------------
    kernel_impl: str = "xla"    # 'xla' | 'pallas' (Pallas TPU kernels; on CPU
                                # they run in interpret mode — inference paths
                                # only, training always uses the custom-VJP XLA
                                # flash implementation)
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # -- derived -------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k tokens is sub-quadratic / memory-bounded.

        SSM and hybrid archs carry O(1) state; archs with a sliding window
        (everywhere or on alternating local layers) keep bounded live cache on
        those layers.  Pure full-attention archs return False and long_500k is
        skipped for them (recorded in DESIGN.md / EXPERIMENTS.md).
        """
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def layer_groups(self) -> Sequence[tuple]:
        """Sequence of (kind, count) groups executed in order.

        Homogeneous groups are scanned; heterogeneous patterns are expressed as
        repeated super-blocks (e.g. gemma2's (local, global) pair scanned 13x).
        """
        if self.arch_type == "ssm":
            return ((MAMBA, self.num_layers),)
        if self.arch_type == "hybrid":
            # zamba2: repeating super-block of k mamba + 1 shared attention.
            k = self.hybrid_attn_every
            n_super = self.num_layers // (k + 1)
            rem = self.num_layers - n_super * (k + 1)
            groups = [("hybrid_super", n_super)]
            if rem:
                groups.append((MAMBA, rem))
            return tuple(groups)
        if self.is_encoder_decoder:
            return ((ENC_ATTN, self.encoder_layers), (DEC_ATTN, self.num_layers))
        if self.local_global_alternating:
            assert self.num_layers % 2 == 0
            return (("local_global", self.num_layers // 2),)
        kind = SWA if self.sliding_window is not None else ATTN
        return ((kind, self.num_layers),)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, h, kv, hd, ff, v = (self.d_model, self.num_heads, self.num_kv_heads,
                               self.head_dim, self.d_ff, self.vocab_size)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.attention_bias:
            attn += (h + 2 * kv) * hd
        mlp = 3 * d * ff  # gate/up/down
        if self.num_experts:
            eff = self.moe_d_ff or ff
            mlp = self.num_experts * 3 * d * eff + d * self.num_experts  # + router
        norm = 2 * d * (2 if self.post_block_norm else 1)

        def mamba_block_params() -> int:
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            # in_proj -> [z, x, B, C, dt], conv, A/D/dt_bias, out_proj, norm
            zxbcdt = d * (2 * d_in + 2 * self.ssm_state_size + nheads)
            conv = (d_in + 2 * self.ssm_state_size) * self.ssm_conv_width
            extra = 3 * nheads + d_in  # A_log, D, dt_bias, gated-norm weight
            out = d_in * d
            return zxbcdt + conv + extra + out + d

        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for kind, count in self.layer_groups:
            if kind in (ATTN, SWA, ENC_ATTN):
                total += count * (attn + mlp + norm)
            elif kind == DEC_ATTN:
                total += count * (2 * attn + mlp + norm + 2 * d)
            elif kind == MAMBA:
                total += count * mamba_block_params()
            elif kind == "hybrid_super":
                total += count * self.hybrid_attn_every * mamba_block_params()
                total += attn + mlp + norm  # shared (tied) attention block, counted once
            elif kind == "local_global":
                total += count * 2 * (attn + mlp + norm)
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts active)."""
        if not self.num_experts:
            return self.param_count()
        eff = self.moe_d_ff or self.d_ff
        inactive = (self.num_experts - self.num_experts_per_tok) * 3 * self.d_model * eff
        per_layer_inactive = inactive
        n_moe_layers = self.num_layers
        return self.param_count() - n_moe_layers * per_layer_inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (public pool).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "mixtral_8x22b",
    "gemma2_2b",
    "qwen2_72b",
    "whisper_medium",
    "smollm_360m",
    "zamba2_1p2b",
    "granite_20b",
    "mamba2_1p3b",
    "qwen3_moe_30b_a3b",
    "internvl2_2b",
)

# CLI ids (dashes) -> module names
_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "mixtral-8x22b": "mixtral_8x22b",
    "gemma2-2b": "gemma2_2b",
    "qwen2-72b": "qwen2_72b",
    "whisper-medium": "whisper_medium",
    "smollm-360m": "smollm_360m",
    "zamba2-1.2b": "zamba2_1p2b",
    "granite-20b": "granite_20b",
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "internvl2-2b": "internvl2_2b",
})


def get_config(arch: str, tiny: bool = False) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.TINY if tiny else mod.CONFIG


def all_configs(tiny: bool = False):
    return {a: get_config(a, tiny=tiny) for a in ARCH_IDS}
