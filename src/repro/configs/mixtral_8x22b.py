"""Mixtral 8x22B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088] 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, SWA.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    name="mixtral-8x22b-tiny",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    num_experts=4,
    num_experts_per_tok=2,
    sliding_window=64,
)
