"""Production mesh construction.

Functions, not module-level constants, so importing this module never touches
jax device state (device count is locked at first jax init).
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_info(*, multi_pod: bool = False) -> MeshInfo:
    return MeshInfo(make_production_mesh(multi_pod=multi_pod))


def make_host_mesh(data: int = 1, model: int = 1) -> MeshInfo:
    """Small mesh over however many host devices exist (tests)."""
    return MeshInfo(jax.make_mesh((data, model), ("data", "model")))
