"""Generate the EXPERIMENTS.md roofline tables from dry-run JSON artifacts,
and (optionally) the cluster-serving comparison table from the JSON that
examples/cluster_serve.py --json dumps.

    PYTHONPATH=src python -m repro.launch.report \
        --baseline experiments/dryrun --final experiments/dryrun_final \
        --cluster experiments/cluster.json
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_dir(d: str) -> dict:
    out = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
        out[key] = r
    return out


def fmt_ms(x: float) -> str:
    if x >= 1.0:
        return f"{x:.1f}s"
    return f"{x * 1e3:.2f}ms"


def roofline_table(recs: dict, mesh: str, variant: str) -> str:
    from repro.configs.base import ARCH_IDS, INPUT_SHAPES
    lines = [
        "| arch | shape | status | t_comp | t_mem | t_coll | dominant | "
        "useful | mem/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            r = recs.get((arch, shape, mesh, variant))
            if r is None:
                continue
            if r["status"] == "SKIP":
                lines.append(f"| {arch} | {shape} | SKIP (full attention; "
                             f"DESIGN.md) | — | — | — | — | — | — |")
                continue
            if r["status"] != "OK":
                lines.append(f"| {arch} | {shape} | **FAIL** | — | — | — | — "
                             f"| — | — |")
                continue
            rl = r["roofline"]
            mem = r["memory_analysis"]
            live = (mem["argument_size"] + mem["temp_size"]
                    - mem["alias_size"]) / 1e9
            lines.append(
                f"| {arch} | {shape} | OK | {fmt_ms(rl['t_compute'])} | "
                f"{fmt_ms(rl['t_memory'])} | {fmt_ms(rl['t_collective'])} | "
                f"{rl['dominant']} | {rl['useful_flops_ratio']:.2f} | "
                f"{live:.1f}GB |")
    return "\n".join(lines)


def cluster_tables(reports: dict) -> str:
    """Markdown for a multi-policy cluster run ({mode: ClusterEngine report},
    the structure examples/cluster_serve.py dumps)."""
    parts = ["| policy | aggregate thr | feasible jobs meeting SLO | "
             "instance stalls |", "|---|---|---|---|"]
    for mode, rep in reports.items():
        a = rep["aggregate"]
        parts.append(
            f"| {mode} | {a['aggregate_throughput']:.1f}/s | "
            f"{a['jobs_meeting_slo']}/{a['feasible_jobs']} | "
            f"{a['total_stall_s']:.1f}s |")
    ref = reports.get("auto") or next(iter(reports.values()))
    cmp_mode = "hybrid" if "hybrid" in reports else None
    parts.append("\n| job | dnn/dataset | device | approach | bs | mtl | "
                 "thr/s | tail p95 | SLO |")
    parts.append("|---|---|---|---|---|---|---|---|---|")
    for r in (reports.get(cmp_mode) or ref)["per_job"]:
        parts.append(
            f"| {r['job_id']} | {r['dnn']} | {r['device']} | "
            f"{r['approach']} | {r['bs']} | {r['mtl']} | "
            f"{r['throughput']:.1f} | {r['tail_p95_ms']:.1f}ms | "
            f"{r['slo_ms']:.1f}ms |")
    return "\n".join(parts)


def churn_tables(reports: dict) -> str:
    """Markdown for a churn run ({policy: ClusterEngine report}, the
    structure examples/cluster_churn.py dumps)."""
    parts = ["| policy | goodput | throughput | admissions | drains | "
             "migrations | migration stalls | conserved |",
             "|---|---|---|---|---|---|---|---|"]
    for policy, rep in reports.items():
        a = rep["aggregate"]
        parts.append(
            f"| {policy} | {a['goodput']:.1f}/s | "
            f"{a['aggregate_throughput']:.1f}/s | {a['admissions']} | "
            f"{a['drains']} | {a['migrations']} | "
            f"{a['migration_stall_s']:.1f}s | "
            f"{'yes' if a['conserved'] else 'NO'} |")
    best = reports.get("surface") or next(iter(reports.values()))
    parts.append("\n| job | dnn/dataset | device | lifetime | bs | mtl | "
                 "migs | submitted | completed | rejected | attain |")
    parts.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in best["per_job"]:
        end = (f"{r['drained_at']:.0f}s" if r["drained_at"] is not None
               else "end")
        parts.append(
            f"| {r['job_id']} | {r['dnn']} | {r['device']} | "
            f"{r['admit_s']:.0f}s-{end} | {r['bs']} | {r['mtl']} | "
            f"{r['migrations']} | {r['submitted']} | {r['completed']} | "
            f"{r['rejected']} | {r['slo_attainment']:.3f} |")
    return "\n".join(parts)


def partition_tables(reports: dict) -> str:
    """Markdown for a spatial-partitioning run ({policy: ClusterEngine
    report}, the structure examples/partition_serve.py dumps): the policy
    comparison (heterogeneous shares + cheap resizes vs the uniform-MTL
    baseline) and the per-tenant share table of the best policy."""
    parts = ["| policy | goodput | throughput | resizes | resize stalls | "
             "equiv migration stalls | migrations | migration stalls | "
             "conserved |",
             "|---|---|---|---|---|---|---|---|---|"]
    for policy, rep in reports.items():
        a = rep["aggregate"]
        parts.append(
            f"| {policy} | {a['goodput']:.1f}/s | "
            f"{a['aggregate_throughput']:.1f}/s | {a['resizes']} | "
            f"{a['resize_stall_s']:.2f}s | "
            f"{a['resize_equiv_migration_stall_s']:.1f}s | "
            f"{a['migrations']} | {a['migration_stall_s']:.1f}s | "
            f"{'yes' if a['conserved'] else 'NO'} |")
    best = reports.get("het") or next(iter(reports.values()))
    parts.append("\n| job | dnn/dataset | device | share | bs | mtl | "
                 "resizes | thr/s | attain |")
    parts.append("|---|---|---|---|---|---|---|---|---|")
    for r in best["per_job"]:
        share = f"{r['share']:.3f}" if r.get("share") is not None else "—"
        parts.append(
            f"| {r['job_id']} | {r['dnn']} | {r['device']} | {share} | "
            f"{r['bs']} | {r['mtl']} | {r.get('resizes', 0)} | "
            f"{r['throughput']:.1f} | {r['slo_attainment']:.3f} |")
    return "\n".join(parts)


def scenario_tables(reports: dict) -> str:
    """Markdown for a scenario-matrix run ({cell: ClusterEngine report},
    the structure examples/scenario_matrix.py dumps): goodput, minimum
    per-job SLO attainment, and the energy column the power-packing
    objective moves (joules per good request)."""
    parts = ["| cell | goodput | min attain | J/good req | $/good req | "
             "energy | devices powered | evacuated | killed | conserved |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for cell, rep in reports.items():
        a = rep["aggregate"]
        jpg = a.get("joules_per_good_request")
        cpg = a.get("cost_per_good_request")
        parts.append(
            f"| {cell} | {a['goodput']:.1f}/s | "
            f"{a['min_attainment']:.3f} | "
            f"{f'{jpg:.4f}J' if jpg is not None else '—'} | "
            f"{f'${cpg:.3g}' if cpg is not None else '—'} | "
            f"{a['energy_j']:.0f}J | {a['devices_powered']} | "
            f"{a['preempt_evacuated']} | {a['preempt_killed']} | "
            f"{'yes' if a['conserved'] else 'NO'} |")
    return "\n".join(parts)


def disagg_tables(reports: dict) -> str:
    """Markdown for a disaggregated-serving comparison ({mode: token
    report}, the structure examples/disagg_serve.py dumps): goodput, the
    two per-token SLO attainments, and — for the disagg row — the
    KV-transfer fabric's accounting."""
    parts = ["| mode | goodput | TTFT p95 | TTFT attain | TPOT p95 | "
             "TPOT attain | KV moved | wire time | conserved |",
             "|---|---|---|---|---|---|---|---|---|"]
    for mode, rep in reports.items():
        fab = rep.get("fabric")
        kv = f"{fab['bytes_moved'] / 1e9:.1f}GB" if fab else "—"
        wire = f"{fab['busy_s'] * 1e3:.0f}ms" if fab else "—"
        parts.append(
            f"| {mode} | {rep['goodput_tokens_s']:.0f} tok/s | "
            f"{rep['ttft_p95_s'] * 1e3:.0f}ms | "
            f"{rep['ttft_attainment']:.3f} | "
            f"{rep['tpot_p95_s'] * 1e3:.2f}ms | "
            f"{rep['tpot_attainment']:.3f} | {kv} | {wire} | "
            f"{'yes' if rep['conserved'] else 'NO'} |")
    return "\n".join(parts)


def profile_store_tables(store) -> str:
    """Markdown summary of a cross-run profile store: what knowledge the
    next run starts with (tuned tiles + generation, persisted surface
    rows, migration calibrations)."""
    import numpy as np
    s = store.stats()
    parts = [f"_store `{s['root']}` (schema {s['schema']}, tuned-tile "
             f"generation {s['generations'].get('autotune', 0)}, "
             f"{s['sections'].get('autotune', 0)} autotune entries)_\n"]
    surfaces = store.section("surfaces")
    if surfaces:
        parts.append("| surface row | device class | points | autotune gen |")
        parts.append("|---|---|---|---|")
        for sk in sorted(surfaces):
            r = surfaces[sk]
            parts.append(f"| {r.get('signature', sk)} | "
                         f"{r.get('device_class', '?')} | "
                         f"{r.get('points', '?')} | "
                         f"{r.get('autotune_generation', '?')} |")
    migrations = store.section("migrations")
    if migrations:
        parts.append("\n| migration calibration | samples | p50 | p90 |")
        parts.append("|---|---|---|---|")
        for mk in sorted(migrations):
            samples = [x for x in migrations[mk].get("samples", [])
                       if isinstance(x, (int, float))]
            if not samples:
                continue
            parts.append(
                f"| {mk} | {len(samples)} | "
                f"{float(np.quantile(samples, 0.5)) * 1e3:.1f}ms | "
                f"{float(np.quantile(samples, 0.9)) * 1e3:.1f}ms |")
    cost_models = store.section("cost_model")
    if cost_models:
        parts.append("\n| cost model | schema | trained rows | signatures | "
                     "share rungs | autotune gen |")
        parts.append("|---|---|---|---|---|---|")
        for dc in sorted(cost_models):
            r = cost_models[dc]
            if not isinstance(r, dict):
                continue
            parts.append(
                f"| {dc} | {r.get('schema', '?')} | "
                f"{r.get('n_rows', '?')} | "
                f"{len(r.get('train_signatures', []) or [])} | "
                f"{len(r.get('rung_factors', {}) or {})} | "
                f"{r.get('autotune_generation', '?')} |")
    interference = store.section("interference")
    if interference:
        parts.append("\n| partition interference | samples | "
                     "median inflation |")
        parts.append("|---|---|---|")
        for ik in sorted(interference):
            rung, _, share = ik.rpartition("|share=")
            try:
                factor = store.interference_factor(rung, float(share))
            except (TypeError, ValueError):
                continue
            if factor is None:
                continue
            n = len(interference[ik].get("samples", []))
            parts.append(f"| {ik} | {n} | x{factor:.2f} |")
    return "\n".join(parts)


def collect_summary(recs: dict, variant: str) -> str:
    n = {"OK": 0, "SKIP": 0, "FAIL": 0}
    for (a, s, m, v), r in recs.items():
        if v == variant:
            n[r["status"]] = n.get(r["status"], 0) + 1
    return f"{n['OK']} OK / {n['SKIP']} SKIP / {n['FAIL']} FAIL"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="experiments/dryrun")
    ap.add_argument("--final", default="experiments/dryrun_final")
    ap.add_argument("--cluster", default=None,
                    help="cluster_serve.py --json output to tabulate")
    ap.add_argument("--churn", default=None,
                    help="cluster_churn.py --json output to tabulate")
    ap.add_argument("--partition", default=None,
                    help="partition_serve.py --json output to tabulate")
    ap.add_argument("--scenarios", default=None,
                    help="scenario_matrix.py --json output to tabulate")
    ap.add_argument("--disagg", default=None,
                    help="examples/disagg_serve.py --json output to "
                         "tabulate (disagg vs co-tenant vs chunked)")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="cross-run profile store dir to summarize "
                         "(perf.profile_store)")
    ap.add_argument("--replay", default=None, metavar="NAME",
                    help="what-if analysis of a run recorded with "
                         "`serve --record NAME`: re-drive the trace under "
                         "counterfactual policies (uniform MTL, MIG'd "
                         "fleet, 20%% fewer devices) and print the diff "
                         "table")
    ap.add_argument("--out", default="experiments/roofline_tables.md")
    args = ap.parse_args()

    if args.replay:
        from repro.perf.profile_store import store_for
        from repro.serving import replay as rp
        store = store_for(args.store)   # None -> $REPRO_PROFILE_STORE
        trace = rp.load_trace(store, args.replay)
        meta = trace["init"].get("meta", {})
        print(f"replay of {args.replay!r} "
              f"(entry={meta.get('entry', '?')}, "
              f"{trace['event_count']} recorded events):\n")
        print(rp.diff_table(rp.replay_diff(trace)))
        return

    base = load_dir(args.baseline)
    final = load_dir(args.final)

    parts = []
    parts.append("### Baseline roofline — single-pod 16x16 (256 chips)\n")
    parts.append(f"_{collect_summary(base, 'baseline')} "
                 f"(mesh=single+multi combined)_\n")
    parts.append(roofline_table(base, "single", "baseline"))
    parts.append("\n### Baseline roofline — multi-pod 2x16x16 (512 chips)\n")
    parts.append(roofline_table(base, "multi", "baseline"))
    if final:
        parts.append("\n### Final (optimized defaults) — single-pod\n")
        parts.append(f"_{collect_summary(final, 'final')}_\n")
        parts.append(roofline_table(final, "single", "final"))
        parts.append("\n### Final (optimized defaults) — multi-pod\n")
        parts.append(roofline_table(final, "multi", "final"))
    if args.cluster and os.path.exists(args.cluster):
        parts.append("\n### Cluster serving — 30-job Table-4 trace\n")
        parts.append(cluster_tables(json.load(open(args.cluster))))
    if args.churn and os.path.exists(args.churn):
        parts.append("\n### Online churn — admission/draining with "
                     "migration-aware re-placement\n")
        parts.append(churn_tables(json.load(open(args.churn))))
    if args.partition and os.path.exists(args.partition):
        parts.append("\n### Spatial partitioning — heterogeneous shares "
                     "vs uniform multi-tenancy\n")
        parts.append(partition_tables(json.load(open(args.partition))))
    if args.scenarios and os.path.exists(args.scenarios):
        parts.append("\n### Scenario matrix — traffic shape x spot "
                     "capacity x power packing\n")
        parts.append(scenario_tables(json.load(open(args.scenarios))))
    if args.disagg and os.path.exists(args.disagg):
        parts.append("\n### Disaggregated prefill/decode — pool + "
                     "KV-transfer fabric vs single-device modes\n")
        parts.append(disagg_tables(json.load(open(args.disagg))))
    if args.store:
        from repro.perf.profile_store import ProfileStore
        parts.append("\n### Cross-run profile store\n")
        parts.append(profile_store_tables(ProfileStore(args.store)))

    text = "\n".join(parts) + "\n"
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    open(args.out, "w").write(text)
    print(f"wrote {args.out} ({len(text)} bytes)")


if __name__ == "__main__":
    main()
