"""Training launcher.

Real execution (this host):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --tiny \
        --steps 100 --batch 8 --seq 256

Production lowering check (no execution; 512 placeholder devices):
    handled by repro.launch.dryrun --shape train_4k
"""

from __future__ import annotations

import argparse

from repro.configs.base import get_config
from repro.training.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default=None, help="optional text file")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"(analytic), steps={args.steps} batch={args.batch} seq={args.seq}")
    out = train(cfg, steps=args.steps, batch_size=args.batch,
                seq_len=args.seq, lr=args.lr, seed=args.seed,
                data_path=args.data, ckpt_path=args.ckpt,
                ckpt_every=args.ckpt_every)
    print(f"done: {out['n_params']:,} params, final loss "
          f"{out['final_loss']:.4f}, wall {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
