import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 placeholder CPU devices back the production meshes:

    single-pod:  (16, 16)       ("data", "model")      256 chips
    multi-pod:   (2, 16, 16)    ("pod", "data", "model")  512 chips

For each combination this prints/records ``memory_analysis()`` (proves fit),
``cost_analysis()`` (FLOPs/bytes for the roofline) and the collective bytes
parsed from the optimized HLO.  Results land in experiments/dryrun/*.json.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch ID] [--shape NAME]
        [--mesh single|multi|both] [--out DIR]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.distributed.sharding import MeshInfo
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_lib
from repro.perf import roofline


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: long_500k requires sub-quadratic/"
                "windowed attention (see DESIGN.md)")
    return None


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            verbose: bool = True, variant: str = "baseline",
            step_kwargs: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "chips": 512 if multi_pod else 256}

    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "SKIP"
        rec["reason"] = reason
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            suffix = "" if variant == "baseline" else f"__{variant}"
            roofline.save_json(os.path.join(
                out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json"), rec)
        return rec

    t0 = time.time()
    try:
        minfo = MeshInfo(make_production_mesh(multi_pod=multi_pod))
        with minfo.mesh:
            fn, arg_specs, _, _ = steps_lib.make_step(cfg, minfo, shape,
                                                      **(step_kwargs or {}))
            lowered = fn.lower(*arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            rl = roofline.analyze(compiled, cfg, shape, rec["chips"])
        rec.update({
            "status": "OK",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": {
                "argument_size": getattr(mem, "argument_size_in_bytes", None),
                "output_size": getattr(mem, "output_size_in_bytes", None),
                "temp_size": getattr(mem, "temp_size_in_bytes", None),
                "alias_size": getattr(mem, "alias_size_in_bytes", None),
                "generated_code_size": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "roofline": rl.to_dict(),
        })
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name} x {variant}] OK "
                  f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
                  f"dominant={rl.dominant} "
                  f"t=(c {rl.t_compute*1e3:.2f} | m {rl.t_memory*1e3:.2f} | "
                  f"x {rl.t_collective*1e3:.2f}) ms "
                  f"useful={rl.useful_flops_ratio:.2f}")
            print(f"  memory_analysis: {mem}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] FAIL: {rec['error']}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        roofline.save_json(os.path.join(out_dir, fname), rec)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline",
                    help="label; combine with --windowed/--param-mode/--micro")
    ap.add_argument("--windowed", action="store_true",
                    help="ring-buffer caches for sliding-window layers (decode)")
    ap.add_argument("--param-mode", default=None,
                    help="override inference param sharding: infer|tp")
    ap.add_argument("--micro", type=int, default=None,
                    help="override train microbatch count")
    args = ap.parse_args()

    step_kwargs = {}
    if args.windowed:
        step_kwargs["windowed_cache"] = True
    if args.param_mode:
        step_kwargs["param_mode"] = args.param_mode
    if args.micro:
        step_kwargs["num_microbatches"] = args.micro

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                results.append(run_one(arch, shape, multi, args.out,
                                       variant=args.variant,
                                       step_kwargs=step_kwargs))

    ok = sum(r["status"] == "OK" for r in results)
    skip = sum(r["status"] == "SKIP" for r in results)
    fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n=== dry-run summary: {ok} OK / {skip} SKIP / {fail} FAIL "
          f"of {len(results)} ===")
    for r in results:
        if r["status"] == "FAIL":
            print(f"  FAIL {r['arch']} x {r['shape']} x {r['mesh']}: "
                  f"{r['error']}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
