"""Sharded step functions (train / prefill / decode) used by the launcher,
the dry-run, and the examples.

Every builder returns ``(fn, arg_specs, in_shardings, out_shardings)`` where
``arg_specs`` are ShapeDtypeStructs suitable for ``jax.jit(...).lower(...)``
(dry-run, no allocation) and for ``jax.eval_shape``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as shd
from repro.models import api
from repro.training import adamw

Array = jax.Array


def _named(minfo, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(minfo.mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def default_microbatches(cfg: ModelConfig, shape: InputShape,
                         minfo: shd.MeshInfo) -> int:
    """Pick gradient-accumulation so each microbatch has ~<=2 seqs/device."""
    dp = minfo.batch_size
    per_dev = shape.global_batch / dp
    # scale down further for very large models (activation pressure); hybrid
    # archs carry both attention KV and d_in=2*d SSM streams per layer, so
    # they also get 1 seq/device (zamba2: temp 29.0 -> 14.8 GB at <1% bound
    # cost — EXPERIMENTS.md §Dry-run)
    target = 1 if (cfg.param_count() >= 30e9
                   or cfg.arch_type == "hybrid") else 2
    micro = max(1, int(per_dev / target))
    while shape.global_batch % (micro * dp) and micro > 1:
        micro -= 1
    return micro


# ---------------------------------------------------------------------------
# Train step (grad-accumulation microbatching + AdamW)
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, minfo: shd.MeshInfo, shape: InputShape,
                    *, num_microbatches: Optional[int] = None,
                    lr: float = 3e-4, remat: bool = True,
                    param_mode: str = "train"):
    if num_microbatches is None:
        num_microbatches = default_microbatches(cfg, shape, minfo)
    nm = num_microbatches

    abstract_params = api.param_specs(cfg)
    p_specs = shd.param_specs(abstract_params, cfg, minfo, param_mode)
    batch_abs = api.batch_specs(cfg, shape)
    b_specs = shd.batch_input_specs(batch_abs, minfo)
    bspec = shd.batch_spec_axes(minfo, shape.global_batch // nm)

    def loss_fn(params, mb):
        loss, metrics = api.train_loss(params, mb, cfg, remat=remat,
                                       bspec=bspec)
        return loss, metrics

    def train_step(params, opt_state, batch):
        def micro(carry, mb):
            gacc, lacc = carry
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / nm,
                                gacc, grads)
            return (gacc, lacc + loss / nm), None

        if nm > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(nm, x.shape[0] // nm, *x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        # keep grads sharded like params
        grads = jax.lax.with_sharding_constraint(grads, p_specs)
        new_params, new_opt, gnorm = adamw.update(grads, opt_state, params,
                                                  lr=lr)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    opt_abs = jax.eval_shape(adamw.init, abstract_params)
    opt_specs = adamw.AdamWState(step=P(), mu=p_specs, nu=p_specs)

    in_shardings = (_named(minfo, p_specs), _named(minfo, opt_specs),
                    _named(minfo, b_specs))
    out_shardings = (_named(minfo, p_specs), _named(minfo, opt_specs),
                     _named(minfo, {"loss": P(), "grad_norm": P()}))

    fn = jax.jit(train_step, in_shardings=in_shardings,
                 out_shardings=out_shardings, donate_argnums=(0, 1))
    arg_specs = (abstract_params, opt_abs, batch_abs)
    return fn, arg_specs, in_shardings, out_shardings


# ---------------------------------------------------------------------------
# Prefill step
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, minfo: shd.MeshInfo,
                      shape: InputShape, *, capacity: Optional[int] = None):
    capacity = capacity or shape.seq_len
    abstract_params = api.param_specs(cfg)
    p_specs = shd.param_specs(abstract_params, cfg, minfo, "infer")
    batch_abs = api.batch_specs(cfg, shape)
    b_specs = shd.batch_input_specs(batch_abs, minfo)
    cache_abs = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, capacity))
    c_specs = shd.cache_specs_tree(cache_abs, cfg, minfo, shape.global_batch,
                                   capacity)
    logits_spec = P(shd.batch_spec_axes(minfo, shape.global_batch), None)

    bspec = shd.batch_spec_axes(minfo, shape.global_batch)
    # sequence-parallel attention (§Perf): when neither KV-head TP nor q-TP
    # applies, shard the prefill q-block axis over 'model' instead of
    # replicating the attention compute.
    seq_axis = None
    if (not cfg.is_encoder_decoder and cfg.num_heads
            and not shd.attn_head_tp(cfg, minfo.model)
            and cfg.num_heads % minfo.model != 0
            and (shape.seq_len // 256) % minfo.model == 0):
        seq_axis = "model"

    def prefill_step(params, batch):
        return api.prefill(params, batch, cfg, capacity, bspec=bspec,
                           seq_axis=seq_axis)

    fn = jax.jit(prefill_step,
                 in_shardings=(_named(minfo, p_specs), _named(minfo, b_specs)),
                 out_shardings=(NamedSharding(minfo.mesh, logits_spec),
                                _named(minfo, c_specs)))
    return fn, (abstract_params, batch_abs), None, None


# ---------------------------------------------------------------------------
# Decode step (serve_step for decode shapes)
# ---------------------------------------------------------------------------
def make_decode_step(cfg: ModelConfig, minfo: shd.MeshInfo,
                     shape: InputShape, *, windowed_cache: bool = False,
                     param_mode: str = "infer", sharded_append: bool = True):
    """windowed_cache / param_mode='tp' are the beyond-paper §Perf variants:
    ring-buffer caches for sliding-window layers, and TP-only inference params
    (no per-layer FSDP all-gathers at decode)."""
    B, S = shape.global_batch, shape.seq_len
    abstract_params = api.param_specs(cfg)
    p_specs = shd.param_specs(abstract_params, cfg, minfo, param_mode)
    cache_abs = jax.eval_shape(
        lambda: api.init_cache(cfg, B, S, windowed=windowed_cache))
    c_specs = shd.cache_specs_tree(cache_abs, cfg, minfo, B, S)
    tok_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = P(shd.batch_spec_axes(minfo, B))
    logits_spec = P(shd.batch_spec_axes(minfo, B), None)

    bspec = shd.batch_spec_axes(minfo, B)

    def decode(params, cache, tokens, pos):
        if not sharded_append:
            return api.decode_step(params, cache, tokens, pos, cfg, bspec=bspec,
                                   windowed=windowed_cache)
        # append-outside-scan + shard_map local write (§Perf): the cache is
        # read-only inside the layer scan; one O(token) write per group.
        from repro.distributed.cache_update import apply_cache_deltas
        logits, deltas = api.decode_step(params, cache, tokens, pos, cfg,
                                         bspec=bspec, windowed=windowed_cache,
                                         return_deltas=True)
        new_cache = apply_cache_deltas(cache, deltas, pos, c_specs, minfo)
        return logits, new_cache

    fn = jax.jit(
        decode,
        in_shardings=(_named(minfo, p_specs), _named(minfo, c_specs),
                      NamedSharding(minfo.mesh, tok_spec),
                      NamedSharding(minfo.mesh, P())),
        out_shardings=(NamedSharding(minfo.mesh, logits_spec),
                       _named(minfo, c_specs)),
        donate_argnums=(1,),
    )
    arg_specs = (abstract_params, cache_abs, tok_abs, pos_abs)
    return fn, arg_specs, None, None


def make_step(cfg: ModelConfig, minfo: shd.MeshInfo, shape: InputShape,
              **kw):
    if shape.kind == "train":
        return make_train_step(cfg, minfo, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, minfo, shape, **kw)
    return make_decode_step(cfg, minfo, shape, **kw)
