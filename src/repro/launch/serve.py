"""Serving launcher: run a job (paper DNN or assigned LLM arch) under a
controller and report throughput / p95 / power efficiency — or serve the
whole 30-job Table-4 trace on a simulated cluster.

    PYTHONPATH=src python -m repro.launch.serve --job 5 --controller dnnscaler
    PYTHONPATH=src python -m repro.launch.serve --job 5 --controller hybrid
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --controller clipper --slo-ms 50
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --tiny --real
    PYTHONPATH=src python -m repro.launch.serve --cluster --devices 12 \
        --controller hybrid --seconds 240
    PYTHONPATH=src python -m repro.launch.serve --churn --devices 5 \
        --seconds 150 --churn-policy surface
    PYTHONPATH=src python -m repro.launch.serve --partition \
        --partition-policy het --devices 3 --seconds 120
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.core.controller import (ClipperController, DNNScalerController,
                                   StaticController)
from repro.core.matrix_completion import LatencyEstimator
from repro.serving import device_model as dm
from repro.serving.engine import ServingEngine
from repro.serving.executor import RealExecutor, SimExecutor
from repro.serving.workload import PAPER_JOBS


def build_library(estimator: LatencyEstimator, exclude_id: int) -> None:
    """Seed matrix completion with 'historically profiled' jobs (each MTL
    curve priced in one vectorized mt_latency_grid call)."""
    mtls = list(range(1, 11))
    for j in PAPER_JOBS[:8]:
        if j.job_id == exclude_id:
            continue
        curve = dm.mt_latency_curve(dm.TESLA_P40, j.profile(), 1, mtls)
        estimator.add_library_row(dict(zip(mtls, curve)))


def make_controller(name: str, executor, slo_s: float, job_id: int = -1,
                    bs: int = 1, mtl: int = 1, *, surface_library=None,
                    surface_key=None):
    if name in ("dnnscaler", "hybrid"):
        est = LatencyEstimator(max_mtl=10)
        build_library(est, job_id)
        mode = "hybrid" if name == "hybrid" else "auto"
        return DNNScalerController(executor, slo_s, estimator=est, mode=mode,
                                   surface_library=surface_library,
                                   surface_key=surface_key)
    if name == "clipper":
        return ClipperController(slo_s)
    return StaticController(bs=bs, mtl=mtl)


def real_executor_for(arch: str, tiny: bool) -> tuple:
    from repro.configs.base import get_config
    from repro.models import api
    cfg = get_config(arch, tiny=tiny)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng, cfg)

    @jax.jit
    def fwd(params, batch):
        loss, _ = api.train_loss(params, batch, cfg, remat=False)
        return loss

    def make_batch(n):
        from repro.configs.base import InputShape
        shp = InputShape("serve", 128, n, "train")
        return api.make_batch(rng, cfg, shp)

    return RealExecutor(fwd, params, make_batch), cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--job", type=int, default=None, help="paper job # (1-30)")
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--real", action="store_true",
                    help="wall-clock executor (tiny models)")
    ap.add_argument("--controller", default="dnnscaler",
                    choices=["dnnscaler", "hybrid", "clipper", "static"])
    ap.add_argument("--cluster", action="store_true",
                    help="serve the full 30-job trace on a simulated fleet")
    ap.add_argument("--churn", action="store_true",
                    help="online churn: jobs admit/drain mid-run with "
                         "migration-aware re-placement")
    ap.add_argument("--churn-policy", default="surface",
                    choices=["union", "dynamic", "surface"],
                    help="placement policy for --churn (see "
                         "serving.cluster.run_churn_cluster)")
    ap.add_argument("--token-engine", action="store_true",
                    help="token-level continuous batching for a decode "
                         "job: bs = max live decode slots, admit-on-free-"
                         "slot / evict-on-EOS, TTFT+TPOT SLOs "
                         "(serving.token_engine)")
    ap.add_argument("--token-policy", default="both",
                    choices=["continuous", "static", "both"],
                    help="slot engine, fixed-shape bucketed baseline, or "
                         "both on the same ragged trace")
    ap.add_argument("--slots", type=int, default=16,
                    help="max live decode slots (continuous) / batch size "
                         "(static baseline) for --token-engine")
    ap.add_argument("--requests", type=int, default=300,
                    help="trace length for --token-engine")
    ap.add_argument("--rate-rps", type=float, default=12.0,
                    help="arrival rate for the --token-engine trace")
    ap.add_argument("--ttft-slo-ms", type=float, default=1000.0)
    ap.add_argument("--tpot-slo-ms", type=float, default=50.0)
    ap.add_argument("--prefill-mode", default="cotenant",
                    choices=["cotenant", "timeslice", "chunked", "disagg"],
                    help="prefill priced as a co-resident tenant, "
                         "time-sliced on the decode tenant, split into "
                         "fixed token-budget chunks piggybacked on decode "
                         "steps, or DISAGGREGATED onto a dedicated "
                         "prefill pool with KV streamed over the "
                         "interconnect fabric (serving.disagg)")
    ap.add_argument("--prefill-pool", type=int, default=2,
                    help="--prefill-mode disagg: prefill-pool members "
                         "(dedicated devices)")
    ap.add_argument("--prefill-chunk", type=int, default=256,
                    help="--prefill-mode chunked: prefill tokens "
                         "piggybacked per decode step")
    ap.add_argument("--scenarios", action="store_true",
                    help="one scenario-matrix cell: time-varying traffic "
                         "x spot capacity x power packing on the MPS "
                         "partition planner (see "
                         "serving.cluster.run_scenario_cluster)")
    ap.add_argument("--scenario-traffic", default="steady",
                    choices=["steady", "diurnal", "flash"],
                    help="traffic shape for --scenarios: constant, "
                         "compressed diurnal swing, or a 3x flash crowd")
    ap.add_argument("--spot", action="store_true",
                    help="--scenarios: mark one device preemptible and "
                         "revoke it once mid-run (grace window, restore)")
    ap.add_argument("--power-policy", default=None,
                    choices=["pack", "spread"],
                    help="--scenarios placement objective: consolidate "
                         "tenants to power-gate idle devices, or spread "
                         "for headroom (default: legacy scoring)")
    ap.add_argument("--partition", action="store_true",
                    help="spatial partitioning (MPS/MIG-style slices): "
                         "serve the mixed small/large trace with the "
                         "share knob active")
    ap.add_argument("--partition-policy", default="het",
                    choices=["uniform", "het", "het-mig"],
                    help="uniform = 1/k time-share baseline (same pricing "
                         "model, migrations); het = heterogeneous MPS "
                         "shares + cheap resizes; het-mig = MIG grid")
    ap.add_argument("--devices", type=int, default=None,
                    help="fleet size for --cluster / --churn "
                         "(default 12 / 5)")
    ap.add_argument("--seconds", type=float, default=None,
                    help="simulated-time horizon for --cluster / --churn "
                         "(default 90 / 150)")
    ap.add_argument("--bs", type=int, default=1)
    ap.add_argument("--mtl", type=int, default=1)
    ap.add_argument("--slo-ms", type=float, default=None)
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="tune Pallas tile sizes on cache miss (fills the "
                         "persistent autotune cache; otherwise cache-only)")
    ap.add_argument("--autotune-cache-dir", default=None, metavar="DIR",
                    help="autotune cache location (default: "
                         "$REPRO_AUTOTUNE_CACHE, $REPRO_PROFILE_STORE, or "
                         "./.profile_store)")
    ap.add_argument("--profile-store", default=None, metavar="DIR",
                    help="cross-run profile store: reload persisted "
                         "surface rows / migration calibrations before "
                         "serving and persist this run's probing "
                         "afterwards (warm start; see perf.profile_store)")
    ap.add_argument("--train-cost-model", default=None, metavar="DEVCLASS",
                    help="maintenance action: train the learned HLO cost "
                         "model for DEVCLASS (e.g. tesla-p40) from the "
                         "--profile-store's persisted surface rows, save "
                         "it into the store's cost_model section, and "
                         "exit.  The next cluster boot serves it as the "
                         "zero-probe prediction tier (perf.cost_model)")
    ap.add_argument("--record", default=None, metavar="NAME",
                    help="record this cluster/churn/partition run's inputs "
                         "and event stream into the profile store under "
                         "NAME, for later `report --replay NAME` what-if "
                         "analysis")
    ap.add_argument("--vectorized", action="store_true",
                    help="use the array-backed VectorClusterEngine "
                         "(bit-identical results, faster at fleet scale)")
    args = ap.parse_args()

    from repro.perf import autotune
    autotune.configure(cache_dir=args.autotune_cache_dir,
                       tune_on_miss=args.autotune or None)
    store = None
    if args.profile_store is not None:
        from repro.perf.profile_store import ProfileStore
        store = ProfileStore(args.profile_store)
        if args.autotune_cache_dir is None and \
                not os.environ.get("REPRO_AUTOTUNE_CACHE"):
            # one store for all three artifacts: the tuned-tile
            # generation that staleness-gates the persisted surface rows
            # must come from the SAME document the rows live in
            autotune.configure(cache_dir=args.profile_store)

    if args.record and not (args.cluster or args.churn or args.partition
                            or args.scenarios):
        ap.error("--record applies to --cluster / --churn / --partition "
                 "/ --scenarios runs only")

    if args.train_cost_model is not None:
        if store is None:
            ap.error("--train-cost-model requires --profile-store (the "
                     "model is trained from its persisted surface rows)")
        from repro.perf import cost_model as cm
        dc = args.train_cost_model
        model = cm.train_cost_model(store, dc,
                                    autotune_generation=autotune.generation())
        if model is None:
            rows = sum(1 for r in store.section("surfaces").values()
                       if isinstance(r, dict)
                       and r.get("device_class") == dc)
            print(f"cost model[{dc}]: NOT trained — {rows} surface rows "
                  f"for this device class; need >= 4 with recognizable "
                  f"signatures and a device model (tesla-p40 / tpu-v5e)")
            return
        cm.save_cost_model(store, model)
        store.save()
        print(f"cost model[{dc}]: trained on {model.n_rows} surface rows "
              f"({len(model.train_signatures)} signatures), "
              f"{len(model.rung_factors)} share-rung factors — saved to "
              f"{store.path}")
        return

    def warn_truncated(agg: dict) -> None:
        # satellite of the max_steps bugfix: a truncated run used to look
        # like a finished one; now the aggregate says so and we warn
        if agg.get("truncated"):
            print("WARNING: run truncated at max_steps — metrics cover a "
                  "partial horizon, not the full simulated window")

    if args.token_engine:
        from repro.serving.token_engine import (ragged_decode_trace,
                                                run_token_serving)
        from repro.configs.base import get_config
        cfg = get_config(args.arch or "gemma2-2b")
        prof = dm.llm_profile(cfg, mode="decode", kv_seq_budget=1024)
        trace = ragged_decode_trace(args.requests, args.seed,
                                    rate_rps=args.rate_rps)
        if args.prefill_mode == "disagg":
            from repro.serving.disagg import run_disagg_serving
            rep = run_disagg_serving(
                prof, seed=args.seed, trace=trace,
                n_prefill=args.prefill_pool, kv_seq_budget=1024,
                max_slots=args.slots, mtl=args.mtl,
                ttft_slo_s=args.ttft_slo_ms / 1e3,
                tpot_slo_s=args.tpot_slo_ms / 1e3,
                use_controller=args.controller == "hybrid")
            warn_truncated(rep)
            assert rep["conserved"], "request conservation violated"
            fab = rep["fabric"]
            print(f"token-engine[{cfg.name}] disagg: "
                  f"{args.prefill_pool}-member prefill pool over "
                  f"{fab['interconnect']} "
                  f"({fab['bw_bps'] / 1e9:.0f} GB/s): goodput "
                  f"{rep['goodput_tokens_s']:.0f} tok/s, TTFT p95 "
                  f"{rep['ttft_p95_s'] * 1e3:.0f}ms (attain "
                  f"{rep['ttft_attainment']:.3f}), TPOT p95 "
                  f"{rep['tpot_p95_s'] * 1e3:.2f}ms (attain "
                  f"{rep['tpot_attainment']:.3f}), KV moved "
                  f"{fab['bytes_moved'] / 1e9:.1f} GB in "
                  f"{fab['transfers']} transfers "
                  f"({fab['busy_s'] * 1e3:.0f}ms on the wire)")
            return
        policies = (["continuous", "static"] if args.token_policy == "both"
                    else [args.token_policy])
        print(f"token-engine[{cfg.name}]: {len(trace)} requests @ "
              f"{args.rate_rps:.1f} req/s, {args.slots} slots, "
              f"prefill={args.prefill_mode}, TTFT SLO "
              f"{args.ttft_slo_ms:.0f}ms / TPOT SLO "
              f"{args.tpot_slo_ms:.1f}ms")
        reports = {}
        for pol in policies:
            rep = run_token_serving(
                prof, policy=pol, seed=args.seed, trace=trace,
                max_slots=args.slots, static_bs=args.slots, mtl=args.mtl,
                ttft_slo_s=args.ttft_slo_ms / 1e3,
                tpot_slo_s=args.tpot_slo_ms / 1e3,
                use_controller=args.controller == "hybrid",
                prefill_mode=args.prefill_mode,
                chunk_tokens=args.prefill_chunk)
            warn_truncated(rep)
            assert rep["conserved"], "request conservation violated"
            reports[pol] = rep
            print(f"  {pol:>10}: goodput {rep['goodput_tokens_s']:.0f} "
                  f"tok/s (throughput {rep['throughput_tokens_s']:.0f}), "
                  f"TTFT p95 {rep['ttft_p95_s']*1e3:.0f}ms "
                  f"(attain {rep['ttft_attainment']:.3f}), TPOT p95 "
                  f"{rep['tpot_p95_s']*1e3:.2f}ms "
                  f"(attain {rep['tpot_attainment']:.3f}), "
                  f"mean live slots {rep['mean_live_slots']:.1f}, "
                  f"conservation OK")
        if len(reports) == 2:
            ratio = (reports["continuous"]["goodput_tokens_s"]
                     / max(reports["static"]["goodput_tokens_s"], 1e-9))
            print(f"  continuous/static goodput ratio: {ratio:.2f}x")
        return

    if args.scenarios:
        from repro.serving.cluster import run_scenario_cluster
        if args.controller not in ("dnnscaler", "hybrid"):
            ap.error("--scenarios supports --controller dnnscaler or "
                     "hybrid")
        mode = "hybrid" if args.controller == "hybrid" else "auto"
        rep = run_scenario_cluster(
            args.scenario_traffic, spot=args.spot,
            power_policy=args.power_policy,
            n_devices=args.devices or 4,
            horizon_s=args.seconds or 150.0, mode=mode, seed=args.seed,
            vectorized=args.vectorized,
            record=args.record, record_store=store)
        agg = rep["aggregate"]
        warn_truncated(agg)
        assert agg["conserved"], "request conservation violated"
        cap = "spot" if args.spot else "fixed"
        jpg = agg["joules_per_good_request"]
        print(f"scenario[{args.scenario_traffic}/{cap}/"
              f"{args.power_policy or 'legacy'}]: {agg['jobs']} tenancies "
              f"on {agg['devices']} devices — goodput {agg['goodput']:.1f}"
              f"/s, min attainment {agg['min_attainment']:.3f}, "
              f"conservation OK")
        print(f"  energy {agg['energy_j']:.0f}J (idle "
              f"{agg['idle_energy_j']:.0f}J + dynamic "
              f"{agg['dynamic_energy_j']:.0f}J) on "
              f"{agg['devices_powered']} powered devices — "
              + (f"{jpg:.4f} J per good request" if jpg is not None
                 else "no good requests"))
        if args.spot:
            print(f"  {agg['preemptions']} revocations: "
                  f"{agg['preempt_evacuated']} tenants evacuated, "
                  f"{agg['preempt_killed']} force-killed at the grace "
                  f"deadline")
        for r in rep["per_job"]:
            share = f"{r['share']:.3f}" if r["share"] is not None else "—"
            flags = "".join(("P" if r["preempted"] else "",
                             "M" if r["migrations"] else ""))
            print(f"  job {r['job_id']:>5} {r['dnn']:<26} share {share:>6} "
                  f"attain {r['slo_attainment']:.3f} {flags}")
        return

    if args.partition:
        from repro.serving.cluster import run_partition_cluster
        if args.controller not in ("dnnscaler", "hybrid"):
            ap.error("--partition supports --controller dnnscaler or hybrid")
        mode = "hybrid" if args.controller == "hybrid" else "auto"
        rep = run_partition_cluster(args.partition_policy, mode=mode,
                                    n_devices=args.devices or 3,
                                    horizon_s=args.seconds or 120.0,
                                    seed=args.seed, profile_store=store,
                                    vectorized=args.vectorized,
                                    record=args.record, record_store=store)
        agg = rep["aggregate"]
        warn_truncated(agg)
        assert agg["conserved"], "request conservation violated"
        print(f"partition[{args.partition_policy}/{mode}]: {agg['jobs']} "
              f"tenancies on {agg['devices']} devices "
              f"(kind={agg['partition']}) — goodput {agg['goodput']:.1f}/s, "
              f"throughput {agg['aggregate_throughput']:.1f}/s")
        print(f"  {agg['resizes']} resizes "
              f"({agg['resize_stall_s']:.2f}s stalls vs "
              f"{agg['resize_equiv_migration_stall_s']:.1f}s had each been "
              f"a migration), {agg['migrations']} migrations "
              f"({agg['migration_stall_s']:.1f}s)")
        for r in rep["per_job"]:
            share = f"{r['share']:.3f}" if r["share"] is not None else "—"
            print(f"  job {r['job_id']:>5} {r['dnn']:<26} share {share:>6} "
                  f"bs {r['bs']:>3} mtl {r['mtl']:>2} "
                  f"thr {r['throughput']:>7.1f}/s "
                  f"attain {r['slo_attainment']:.3f}")
        return

    if args.churn:
        from repro.serving.cluster import run_churn_cluster
        if args.controller not in ("dnnscaler", "hybrid"):
            ap.error("--churn supports --controller dnnscaler or hybrid")
        mode = "hybrid" if args.controller == "hybrid" else "auto"
        rep = run_churn_cluster(args.churn_policy, mode=mode,
                                n_devices=args.devices or 5,
                                horizon_s=args.seconds or 150.0,
                                seed=args.seed, profile_store=store,
                                vectorized=args.vectorized,
                                record=args.record, record_store=store)
        agg = rep["aggregate"]
        warn_truncated(agg)
        assert agg["conserved"], "request conservation violated"
        print(f"churn[{args.churn_policy}/{mode}]: {agg['jobs']} tenancies "
              f"on {agg['devices']} devices — goodput {agg['goodput']:.1f}"
              f"/s, throughput {agg['aggregate_throughput']:.1f}/s, "
              f"{agg['admissions']} admissions / {agg['drains']} drains / "
              f"{agg['migrations']} migrations "
              f"({agg['migration_stall_s']:.1f}s stalls), "
              f"conservation OK")
        if store is not None:
            s = store.stats()
            print(f"  profile store {s['root']}: "
                  f"{rep['aggregate'].get('store_rows_loaded', 0)} rows "
                  f"loaded / {rep['aggregate'].get('store_rows_evicted', 0)} "
                  f"evicted on load; now "
                  f"{s['sections'].get('surfaces', 0)} surface rows, "
                  f"{s['sections'].get('migrations', 0)} migration "
                  f"calibrations")
        return

    if args.cluster:
        from repro.serving.cluster import run_paper_cluster
        if args.controller == "static":
            ap.error("--controller static is not supported with --cluster "
                     "(per-job static knobs have no cluster-wide meaning); "
                     "choose dnnscaler, hybrid, or clipper")
        for flag, val, default in (("--job", args.job, None),
                                   ("--arch", args.arch, None),
                                   ("--slo-ms", args.slo_ms, None),
                                   ("--bs", args.bs, 1),
                                   ("--mtl", args.mtl, 1)):
            if val != default:
                ap.error(f"{flag} has no effect with --cluster "
                         "(jobs use their Table-4 SLOs and scaler-chosen "
                         "knobs)")
        mode = {"dnnscaler": "auto", "hybrid": "hybrid",
                "clipper": "clipper"}[args.controller]
        rep = run_paper_cluster(mode, n_devices=args.devices or 12,
                                sim_time_limit=args.seconds or 90.0,
                                seed=args.seed, vectorized=args.vectorized,
                                record=args.record, record_store=store)
        agg = rep["aggregate"]
        warn_truncated(agg)
        print(f"cluster[{mode}]: {agg['jobs']} jobs on {agg['devices']} "
              f"devices — aggregate {agg['aggregate_throughput']:.1f} "
              f"items/s, {agg['jobs_meeting_slo']}/{agg['feasible_jobs']} "
              f"feasible jobs meet SLO, stalls {agg['total_stall_s']:.1f}s")
        return

    if args.job is not None:
        job = PAPER_JOBS[args.job - 1]
        prof = job.profile()
        slo = args.slo_ms / 1e3 if args.slo_ms else job.slo_s
        executor = SimExecutor(prof, seed=args.seed)
        ctrl = make_controller(args.controller, executor, slo, job.job_id,
                               args.bs, args.mtl)
        engine = ServingEngine(SimExecutor(prof, seed=args.seed + 1), slo)
        label = f"job{job.job_id} {prof.name}"
    elif args.arch and args.real:
        executor, cfg = real_executor_for(args.arch, args.tiny)
        base = executor.mean_latency(1, 1)
        slo = args.slo_ms / 1e3 if args.slo_ms else base * 4
        lib = surface_key = None
        if store is not None and args.controller in ("dnnscaler", "hybrid"):
            # cross-run warm start: prior runs of this architecture seed
            # the scaler through the persisted shared surface
            from repro.core.matrix_completion import SurfaceLibrary
            from repro.perf import autotune as _at
            lib = SurfaceLibrary()
            surface_key = f"{cfg.name}/serve"
            res = store.load_surfaces(lib, device_class="host-cpu",
                                      autotune_generation=_at.generation())
            print(f"profile store: {len(res['loaded'])} surface rows "
                  f"loaded, {len(res['evicted'])} evicted")
        ctrl = make_controller(args.controller, executor, slo,
                               surface_library=lib, surface_key=surface_key)
        engine = ServingEngine(executor, slo, instance_launch_s=0.2)
        label = f"{cfg.name} (real)"
    else:
        from repro.configs.base import get_config
        cfg = get_config(args.arch)
        prof = dm.llm_profile(cfg, mode="decode")
        base = dm.batch_latency(dm.TPU_V5E, prof, 1)
        slo = args.slo_ms / 1e3 if args.slo_ms else base * 4
        executor = SimExecutor(prof, device=dm.TPU_V5E, seed=args.seed,
                               mesh_shape=(16, 16))
        ctrl = make_controller(args.controller, executor, slo)
        engine = ServingEngine(
            SimExecutor(prof, device=dm.TPU_V5E, seed=args.seed + 1,
                        mesh_shape=(16, 16)), slo)
        label = f"{cfg.name} (TPU submesh tenancy)"

    acc = engine.run(ctrl, max_steps=args.steps)
    s = acc.summary()
    act = ctrl.action()
    approach = getattr(ctrl, "approach", args.controller)
    print(f"{label}: controller={args.controller} approach={approach} "
          f"steady(bs={act.bs}, mtl={act.mtl})")
    print(f"  throughput {s['throughput']:.1f}/s  p95 {s['p95_s']*1e3:.1f}ms "
          f"(SLO {slo*1e3:.1f}ms)  attainment {s['slo_attainment']:.3f}  "
          f"power_eff {s['power_efficiency']:.2f}/W")
    if hasattr(executor, "cache_stats"):
        cs = executor.cache_stats
        print(f"  exec-cache hits {cs.hits} misses {cs.misses} "
              f"(hit rate {cs.hit_rate:.2f})  compile "
              f"{cs.compile_time_s:.2f}s charged "
              f"{s['compile_stall_s']:.2f}s")
    if hasattr(ctrl, "probe_count"):
        print(f"  probes: {ctrl.probe_count} distinct (bs, mtl) points")
    if store is not None and getattr(ctrl, "surface_library", None) is not None:
        from repro.perf import autotune as _at
        wrote = store.persist_surface(
            ctrl.surface_library, ctrl.surface_key,
            signature=ctrl.surface_key, device_class="host-cpu",
            autotune_generation=_at.generation())
        store.save()
        print(f"  profile store: surface row "
              f"{'persisted' if wrote else 'too sparse to persist'} "
              f"({store.path})")


if __name__ == "__main__":
    main()
