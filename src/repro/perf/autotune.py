"""Pallas kernel autotuner: per-(kernel, shape-class, dtype, backend) search
over tile parameters, with roofline-guided candidate pruning and a
persistent JSON cache.

The three Pallas kernels (flash attention, decode attention, SSD scan) ran
with hard-coded tile sizes regardless of shape or backend; every serving
configuration paid whatever that default cost.  This module searches the
small tile-parameter space per *shape class* (dims bucketed to powers of
two, so one tuning run covers a neighborhood of shapes), prunes obviously
bad tilings with the same arithmetic-intensity terms `perf/roofline.py`
uses (modeled bound time = max(flops/peak, bytes/bw), VMEM-footprint hard
limit), then wall-clock-times the survivors.  Timing is interpret-mode
safe: on CPU the kernels run in Pallas interpret mode, which is exactly
what CI exercises — the cache key carries the backend, so CPU-tuned
entries never leak onto a TPU.

Results persist in the cross-run profile store (``perf.profile_store``):
the ``autotune`` section of ``profile_store.json`` under
``configure(cache_dir=...)``, the ``REPRO_AUTOTUNE_CACHE`` env var (legacy
override), ``REPRO_PROFILE_STORE``, or ``.profile_store/`` in the working
directory — a legacy ``autotune_cache.json`` found in the same directory
is imported once on first touch.  Every persisted tuning bumps the store's
``autotune`` *generation* (``generation()``); the RealExecutor keys its
AOT executable cache on it, so a new tuning invalidates stale executables.
The ``kernels/*/ops.py`` wrappers consult ``lookup(...)`` when the caller
does not pass explicit tile kwargs: explicit kwargs always win, an empty
cache falls back to the historical hard-coded defaults, and
``tune_on_miss`` (off by default — CI must not spend minutes tuning) lets
``--autotune`` runs fill the cache.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

import numpy as np

from repro.perf import profile_store
from repro.perf.roofline import HBM_BW, PEAK_FLOPS

VMEM_BYTES = 16 * 2 ** 20       # per-core VMEM budget (TPU v5e)
PRUNE_RATIO = 3.0               # keep candidates within this factor of the
                                # best modeled bound time
DEFAULT_CACHE_DIR = profile_store.DEFAULT_STORE_DIR
_LEGACY_CACHE_FILE = "autotune_cache.json"

# Historical hard-coded defaults — the fallback when the cache is empty,
# and always kept in the candidate set so tuning can only improve on them.
DEFAULTS = {
    "flash_attention": {"block_q": 128, "block_k": 128},
    "decode_attention": {"block_k": 256},
    "paged_decode_attention": {"page_size": 64},
    "ssd_scan": {"chunk": 128},
}

_state = {
    "cache_dir": None,            # resolved lazily (env var wins)
    "tune_on_miss": False,
    "enabled": True,
    "legacy_checked": None,       # root whose legacy file was imported
    "hits": 0,
    "misses": 0,
    "timings": 0,                 # individual candidate timings run
    "tunes": 0,                   # full searches run
}


def configure(cache_dir: Optional[str] = None,
              tune_on_miss: Optional[bool] = None,
              enabled: Optional[bool] = None) -> None:
    """Set autotuner behavior; any argument left None is unchanged."""
    if cache_dir is not None:
        _state["cache_dir"] = cache_dir
        _state["legacy_checked"] = None
        _store().reload()         # re-read from the (possibly new) location
    if tune_on_miss is not None:
        _state["tune_on_miss"] = tune_on_miss
    if enabled is not None:
        _state["enabled"] = enabled


def cache_dir() -> str:
    return (_state["cache_dir"] or os.environ.get("REPRO_AUTOTUNE_CACHE")
            or profile_store.default_root())


def cache_path() -> str:
    return os.path.join(cache_dir(), profile_store.STORE_FILE)


def _store() -> profile_store.ProfileStore:
    return profile_store.store_for(cache_dir())


def generation() -> int:
    """The resident tuned-tile generation: bumped on every persisted
    tuning.  The RealExecutor folds it into its AOT executable-cache key
    so a new tuning invalidates stale executables."""
    return _store().generation("autotune")


def cache_stats() -> dict:
    mem = _load()
    return {"entries": len(mem), "hits": _state["hits"],
            "misses": _state["misses"], "timings": _state["timings"],
            "tunes": _state["tunes"], "generation": generation(),
            "cache_dir": cache_dir()}


def reset_counters() -> None:
    _state.update(hits=0, misses=0, timings=0, tunes=0)


def _load() -> dict:
    """The autotune section of the profile store, importing a legacy
    pre-store ``autotune_cache.json`` sitting in the same directory once
    (earlier PRs' tuned tiles keep working after the migration)."""
    store = _store()
    sec = store.section("autotune")
    if not sec and _state["legacy_checked"] != store.root:
        _state["legacy_checked"] = store.root
        try:
            with open(os.path.join(cache_dir(), _LEGACY_CACHE_FILE)) as f:
                legacy = json.load(f)
        except (OSError, ValueError):
            legacy = None
        if isinstance(legacy, dict):
            for k, v in legacy.items():
                store.put("autotune", k, v)
    return sec


def _save() -> None:
    _store().save()


def _backend() -> str:
    import jax
    return jax.default_backend()


def _bucket(n: int, floor: int = 8) -> int:
    """Next power of two >= n: one tuning run per shape neighborhood."""
    b = floor
    while b < n:
        b *= 2
    return b


def _dtype_name(dtype) -> str:
    return np.dtype(dtype).name if not hasattr(dtype, "name") else dtype.name


# ---------------------------------------------------------------------------
# Shape classes: the cache key dims per kernel (bucketed where continuous).
# ---------------------------------------------------------------------------
def shape_class(kernel: str, **dims) -> dict:
    # BKV / H: the parallel grid axes.  They do not change which tiling is
    # arithmetically best on TPU, but they multiply the per-grid-step
    # overhead that dominates interpret-mode timing — leaving them out made
    # the tuner pick chunk sizes that lost on the caller's real head count.
    if kernel == "flash_attention":
        return {"BKV": _bucket(dims.get("BKV", 1), 1),
                "G": dims["G"], "hd": dims["hd"],
                "Tq": _bucket(dims["Tq"]), "Tk": _bucket(dims["Tk"]),
                "causal": bool(dims["causal"])}
    if kernel == "decode_attention":
        return {"BKV": _bucket(dims.get("BKV", 1), 1),
                "G": dims["G"], "hd": dims["hd"], "S": _bucket(dims["S"])}
    if kernel == "paged_decode_attention":
        # S is the per-slot sequence BUDGET the paged cache is sized for —
        # the page size is a layout knob chosen at cache construction, so
        # the class is keyed the same way as the dense decode kernel
        return {"BKV": _bucket(dims.get("BKV", 1), 1),
                "G": dims["G"], "hd": dims["hd"], "S": _bucket(dims["S"])}
    if kernel == "ssd_scan":
        return {"H": _bucket(dims.get("H", 1), 1),
                "P": dims["P"], "N": dims["N"], "T": _bucket(dims["T"])}
    raise KeyError(kernel)


def _key(kernel: str, backend: str, dtype: str, cls: dict) -> str:
    dims = ",".join(f"{k}={v}" for k, v in sorted(cls.items()))
    return f"{kernel}|{backend}|{dtype}|{dims}"


# ---------------------------------------------------------------------------
# Candidate tilings + roofline models (bound time, VMEM footprint).
# ---------------------------------------------------------------------------
def _flash_candidates(cls: dict) -> list:
    out = []
    for bq in (32, 64, 128, 256):
        for bk in (32, 64, 128, 256):
            if bq <= cls["Tq"] and bk <= cls["Tk"]:
                out.append({"block_q": bq, "block_k": bk})
    return out or [dict(DEFAULTS["flash_attention"])]


def _flash_model(cls: dict, cand: dict, sz: int) -> tuple:
    G, hd, Tq, Tk = cls["G"], cls["hd"], cls["Tq"], cls["Tk"]
    bq, bk = cand["block_q"], cand["block_k"]
    nq, nk = Tq // bq, Tk // bk
    # q tile refetched per k step, k/v per q step; out written once
    bytes_ = sz * (G * Tq * hd * nk + 2 * Tk * hd * nq + G * Tq * hd)
    flops = 4.0 * G * Tq * Tk * hd
    eff = (min(G * bq, 128) / 128.0) * (min(bk, 128) / 128.0)
    bound = max(flops / (PEAK_FLOPS * eff), bytes_ / HBM_BW)
    vmem = (sz * (G * bq * hd + 2 * bk * hd)
            + 4 * (2 * G * bq * 128 + G * bq * hd + G * bq * bk))
    return bound, vmem


def _decode_candidates(cls: dict) -> list:
    out = [{"block_k": bk} for bk in (64, 128, 256, 512, 1024)
           if bk <= cls["S"]]
    return out or [dict(DEFAULTS["decode_attention"])]


def _decode_model(cls: dict, cand: dict, sz: int) -> tuple:
    G, hd, S = cls["G"], cls["hd"], cls["S"]
    bk = cand["block_k"]
    ns = S // bk
    bytes_ = sz * (2 * S * hd + G * hd * ns + G * hd)
    flops = 4.0 * G * S * hd
    eff = (min(G, 128) / 128.0) * (min(bk, 128) / 128.0)
    bound = max(flops / (PEAK_FLOPS * eff), bytes_ / HBM_BW)
    vmem = sz * (G * hd + 2 * bk * hd) + 4 * (2 * G * 128 + G * hd + G * bk)
    return bound, vmem


def _paged_candidates(cls: dict) -> list:
    out = [{"page_size": p} for p in (32, 64, 128, 256) if p <= cls["S"]]
    return out or [dict(DEFAULTS["paged_decode_attention"])]


def _paged_model(cls: dict, cand: dict, sz: int) -> tuple:
    # a page is the paged kernel's k-block: same arithmetic-intensity terms
    # as the dense decode kernel at block_k = page_size (the block table
    # adds only a few scalar-prefetch bytes per grid step)
    return _decode_model(cls, {"block_k": cand["page_size"]}, sz)


def _ssd_candidates(cls: dict) -> list:
    out = [{"chunk": c} for c in (32, 64, 128, 256)
           if c <= cls["T"] and cls["T"] % c == 0]
    return out or [dict(DEFAULTS["ssd_scan"])]


def _ssd_model(cls: dict, cand: dict, sz: int) -> tuple:
    P, N, T = cls["P"], cls["N"], cls["T"]
    c = cand["chunk"]
    # intra-chunk terms are quadratic in the chunk: smaller chunks do fewer
    # FLOPs, larger chunks fill the MXU — the classic SSD tradeoff
    flops = T * (2.0 * c * (N + P) + 4.0 * N * P)
    bytes_ = sz * (2 * T * P + T + 2 * T * N + P * N)
    eff = (min(c, 128) / 128.0) * (min(max(N, P), 128) / 128.0)
    bound = max(flops / (PEAK_FLOPS * eff), bytes_ / HBM_BW)
    vmem = 4 * (c * P + c + 2 * c * N + P * N + 3 * c * c)
    return bound, vmem


_KERNELS: dict = {
    "flash_attention": (_flash_candidates, _flash_model),
    "decode_attention": (_decode_candidates, _decode_model),
    "paged_decode_attention": (_paged_candidates, _paged_model),
    "ssd_scan": (_ssd_candidates, _ssd_model),
}


def prune_candidates(kernel: str, cls: dict, dtype: str,
                     ratio: float = PRUNE_RATIO) -> list:
    """Roofline-guided pruning: drop tilings whose modeled bound time is
    worse than `ratio` x the best model, or whose VMEM footprint cannot
    fit.  The hard-coded default survives unconditionally — pruning may
    only ever remove challengers, never the fallback."""
    cands_fn, model_fn = _KERNELS[kernel]
    cands = cands_fn(cls)
    sz = np.dtype(dtype).itemsize
    scored = []
    for cand in cands:
        bound, vmem = model_fn(cls, cand, sz)
        scored.append((cand, bound, vmem))
    feasible = [s for s in scored if s[2] <= VMEM_BYTES]
    if not feasible:
        feasible = scored            # degenerate: keep everything
    best = min(b for _, b, _ in feasible)
    kept = [c for c, b, _ in feasible if b <= ratio * best]
    default = DEFAULTS[kernel]
    if all(c != default for c in kept) and any(
            c == default for c in cands):
        kept.append(dict(default))
    return kept


# ---------------------------------------------------------------------------
# Timing (interpret-mode safe: runs the ops wrapper, which selects
# interpret mode on CPU automatically).
# ---------------------------------------------------------------------------
def _time_call(fn: Callable, iters: int = 3) -> float:
    import jax
    jax.block_until_ready(fn())     # compile / first-trace warmup
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    _state["timings"] += 1
    times.sort()
    return times[len(times) // 2]   # median: one OS spike must not decide


def _flash_bench(cls: dict, dtype: str, cand: dict) -> Callable:
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    B = cls["BKV"]                  # folded batch*kv heads: the parallel grid
    G, hd, Tq, Tk = cls["G"], cls["hd"], cls["Tq"], cls["Tk"]
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Tq, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Tk, 1, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Tk, 1, hd), jnp.float32).astype(dtype)
    return lambda: flash_attention(q, k, v, causal=cls["causal"],
                                   block_q=cand["block_q"],
                                   block_k=cand["block_k"])


def _decode_bench(cls: dict, dtype: str, cand: dict) -> Callable:
    import jax
    import jax.numpy as jnp
    from repro.kernels.decode_attention.ops import decode_attention
    B = cls["BKV"]
    G, hd, S = cls["G"], cls["hd"], cls["S"]
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, G, hd), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, 1, hd), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, 1, hd), jnp.float32).astype(dtype)
    pos = jnp.asarray(S - 1, jnp.int32)
    return lambda: decode_attention(q, kc, vc, pos,
                                    block_k=cand["block_k"])


def _paged_bench(cls: dict, dtype: str, cand: dict) -> Callable:
    # unlike block_k, the candidate page size changes the INPUT layout
    # (the page pool is built at that granularity), so each candidate is
    # timed end to end on its own cache layout — that IS the decision the
    # token engine makes once at cache construction
    import jax
    import jax.numpy as jnp
    from repro.kernels.decode_attention.ops import paged_decode_attention
    B = cls["BKV"]
    G, hd, S = cls["G"], cls["hd"], cls["S"]
    psz = cand["page_size"]
    npages = max(S // psz, 1)
    P = B * npages
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, G, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (P, psz, 1, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (P, psz, 1, hd), jnp.float32).astype(dtype)
    tbl = jnp.arange(P, dtype=jnp.int32).reshape(B, npages)
    lens = jnp.full((B,), S, jnp.int32)    # worst case: every slot full
    return lambda: paged_decode_attention(q, kp, vp, lens, tbl)


def _ssd_bench(cls: dict, dtype: str, cand: dict) -> Callable:
    import jax
    import jax.numpy as jnp
    from repro.kernels.ssd_scan.ops import ssd_scan
    H, P, N, T = cls["H"], cls["P"], cls["N"], cls["T"]
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (1, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (1, T, N)) * 0.5
    Cm = jax.random.normal(ks[4], (1, T, N)) * 0.5
    return lambda: ssd_scan(x, dt, A, Bm, Cm, chunk=cand["chunk"])


_BENCH = {"flash_attention": _flash_bench, "decode_attention": _decode_bench,
          "paged_decode_attention": _paged_bench, "ssd_scan": _ssd_bench}


# ---------------------------------------------------------------------------
# Public API: lookup (cache only, unless tune_on_miss) and tune (search).
# ---------------------------------------------------------------------------
def lookup(kernel: str, dtype, **dims) -> Optional[dict]:
    """Best-known tile config for this call site, or None (caller falls
    back to the hard-coded default).  Cache-only unless `tune_on_miss`."""
    if not _state["enabled"]:
        return None
    cls = shape_class(kernel, **dims)
    key = _key(kernel, _backend(), _dtype_name(dtype), cls)
    entry = _load().get(key)
    if entry is not None:
        _state["hits"] += 1
        return entry["config"]
    _state["misses"] += 1
    if _state["tune_on_miss"]:
        return tune(kernel, _dtype_name(dtype), **dims)["config"]
    return None


def tune(kernel: str, dtype: str = "float32", *, force: bool = False,
         iters: int = 3, prune: bool = True, **dims) -> dict:
    """Search tile configs for one shape class; persist and return the
    cache entry {config, us_per_call, candidates_timed, default_us}."""
    cls = shape_class(kernel, **dims)
    key = _key(kernel, _backend(), dtype, cls)
    mem = _load()
    if not force and key in mem:
        return mem[key]
    _state["tunes"] += 1
    cands = (prune_candidates(kernel, cls, dtype) if prune
             else _KERNELS[kernel][0](cls))
    bench = _BENCH[kernel]
    best, best_t, timed = None, float("inf"), {}
    for cand in cands:
        t = _time_call(bench(cls, dtype, cand), iters=iters)
        timed[json.dumps(cand, sort_keys=True)] = t * 1e6
        if t < best_t:
            best, best_t = cand, t
    default = DEFAULTS[kernel]
    default_us = timed.get(json.dumps(default, sort_keys=True))
    entry = {
        "config": dict(best),
        "us_per_call": best_t * 1e6,
        "default_us": default_us,
        "backend": _backend(),
        "shape_class": cls,
        "candidates_timed": timed,
    }
    mem[key] = entry
    # a new tuning invalidates AOT executables compiled under older tiles:
    # bumping the generation makes RealExecutor's cache key miss them
    _store().bump_generation("autotune")
    _save()
    return entry
