"""Persistent cross-run performance profile store.

Every run of this system pays probing costs — Pallas tile searches, scaler
(bs, mtl) latency probes, migration kill+relaunch stalls — and before this
module, only the autotune results outlived the process.  The store unifies
the three cross-run artifacts in ONE schema-versioned JSON document so a
fresh process starts from everything earlier runs already measured:

  * ``autotune``   — tuned tile configs per (kernel, shape-class, dtype,
    backend); ``perf.autotune`` now keeps its cache here (the legacy
    ``autotune_cache.json`` is imported once on first touch).  Every new
    tuning bumps the ``autotune`` *generation*, which the RealExecutor
    folds into its AOT executable-cache key — a re-tune invalidates stale
    executables instead of serving them forever.
  * ``surfaces``   — SurfaceLibrary rows (normalized (bs, mtl) step-latency
    sums/counts) persisted per (architecture-signature, device-class).
    ``ClusterEngine`` reloads them at construction so newly admitted jobs
    in a fresh process hit the matrix-completion fast path.  Loading is
    staleness-gated: rows recorded under a different autotune generation
    are evicted (the tiles that shaped those latencies no longer run), and
    the leave-one-out validation is re-run on load — a row the completion
    machinery itself rejects is dropped from the store, not kept to poison
    the next run too.
  * ``migrations`` — measured kill+relaunch (+ recompile) seconds per
    (signature, device-class).  Churn-mode migration stalls are charged
    from a calibrated percentile once enough measurements exist, falling
    back to the 2.3 s parallel kill+relaunch / 8 GB/s DCN modeling
    defaults otherwise.

Location: explicit ``root`` argument > ``REPRO_PROFILE_STORE`` env var >
``.profile_store/`` in the working directory.  Writes are atomic
merge-and-replace (re-read disk, our keys win on collision, ``os.replace``
of a temp file) so concurrent writers keep each other's entries and a
reader never sees a half-written document — last writer wins per key,
never a crash.  A schema-version mismatch or corrupt file is a clean cold
start: the store behaves as empty and the next save rewrites it.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Optional

import numpy as np

SCHEMA_VERSION = 1
DEFAULT_STORE_DIR = ".profile_store"
STORE_FILE = "profile_store.json"
ENV_VAR = "REPRO_PROFILE_STORE"

MIN_MIGRATION_SAMPLES = 3     # calibrated percentiles need this many
MAX_MIGRATION_SAMPLES = 64    # ring-buffer cap per calibration key
MIGRATION_QUANTILE = 0.9      # stalls are charged at this percentile


def default_root() -> str:
    return os.environ.get(ENV_VAR) or DEFAULT_STORE_DIR


_STORES: dict = {}


def store_for(root: Optional[str] = None) -> "ProfileStore":
    """Process-resident store per root dir (autotune, executors, and the
    cluster engine must all see ONE in-memory generation counter)."""
    resolved = os.path.abspath(root or default_root())
    st = _STORES.get(resolved)
    if st is None:
        st = ProfileStore(resolved)
        _STORES[resolved] = st
    return st


class ProfileStore:
    def __init__(self, root: Optional[str] = None):
        self.root = root or default_root()
        self.cold_start = False      # True when disk was absent/invalid
        self.evictions = 0           # stale/corrupt records dropped on load
        self._deleted: set = set()   # (section, key) tombstones: a merge
        #                              save must not resurrect evicted rows
        self._doc: Optional[dict] = None

    # -- document lifecycle --------------------------------------------------
    @property
    def path(self) -> str:
        return os.path.join(self.root, STORE_FILE)

    @staticmethod
    def _fresh_doc() -> dict:
        return {"schema": SCHEMA_VERSION, "generations": {}}

    def _read_disk(self) -> Optional[dict]:
        """The on-disk document, or None when absent/corrupt/mismatched —
        any invalid state means COLD START, never a crash."""
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return None
        if not isinstance(doc, dict) or doc.get("schema") != SCHEMA_VERSION:
            return None
        return doc

    def load(self) -> dict:
        if self._doc is None:
            disk = self._read_disk()
            if disk is None:
                self.cold_start = True
                self._doc = self._fresh_doc()
            else:
                self._doc = disk
        return self._doc

    def reload(self) -> None:
        """Drop the in-memory mirror; the next access re-reads disk."""
        self._doc = None
        self._deleted.clear()

    # -- generic section access ----------------------------------------------
    def section(self, name: str) -> dict:
        sec = self.load().setdefault(name, {})
        if not isinstance(sec, dict):        # tolerate hand-edited junk
            sec = {}
            self.load()[name] = sec
        return sec

    def get(self, section: str, key: str, default=None):
        return self.section(section).get(key, default)

    def put(self, section: str, key: str, value) -> None:
        self.section(section)[key] = value
        self._deleted.discard((section, key))

    def delete(self, section: str, key: str) -> None:
        self.section(section).pop(key, None)
        self._deleted.add((section, key))

    # -- recorded run traces (serving.replay) --------------------------------
    def record_trace(self, name: str, trace: dict) -> None:
        """Persist a recorded run trace (one key per run name)."""
        self.put("traces", name, trace)
        self.save()

    def get_trace(self, name: str):
        rec = self.get("traces", name)
        return rec if isinstance(rec, dict) else None

    def generation(self, name: str = "autotune") -> int:
        gens = self.load().setdefault("generations", {})
        try:
            return int(gens.get(name, 0))
        except (TypeError, ValueError):
            return 0

    def bump_generation(self, name: str = "autotune") -> int:
        gens = self.load().setdefault("generations", {})
        gens[name] = self.generation(name) + 1
        return gens[name]

    def save(self) -> None:
        """Atomic merge-and-replace.  Disk is re-read so a concurrent
        writer's keys survive; our keys win on collision (last-writer-wins
        per key); generations merge by max so a bump is never undone;
        tombstoned keys stay deleted."""
        doc = self.load()
        os.makedirs(self.root, exist_ok=True)
        disk = self._read_disk() or self._fresh_doc()
        out = {"schema": SCHEMA_VERSION}
        gens = {k: int(v) for k, v in disk.get("generations", {}).items()
                if isinstance(v, (int, float))}
        for k, v in doc.get("generations", {}).items():
            gens[k] = max(int(v), int(gens.get(k, 0)))
        out["generations"] = gens
        names = (set(disk) | set(doc)) - {"schema", "generations"}
        for name in names:
            base = disk.get(name)
            merged = dict(base) if isinstance(base, dict) else {}
            ours = doc.get(name)
            if isinstance(ours, dict):
                merged.update(ours)
            for sec, key in self._deleted:
                if sec == name:
                    merged.pop(key, None)
            out[name] = merged
        fd, tmp = tempfile.mkstemp(dir=self.root, prefix=STORE_FILE + ".tmp.")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(out, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._doc = out

    def stats(self) -> dict:
        doc = self.load()
        return {
            "root": self.root,
            "schema": doc.get("schema"),
            "cold_start": self.cold_start,
            "evictions": self.evictions,
            "generations": dict(doc.get("generations", {})),
            "sections": {k: len(v) for k, v in doc.items()
                         if isinstance(v, dict) and k != "generations"},
        }

    # -- surfaces: persisted SurfaceLibrary rows ------------------------------
    @staticmethod
    def surface_key(signature: str, device_class: str) -> str:
        return f"{signature}|{device_class}"

    def persist_surface(self, lib, key, *, signature: str, device_class: str,
                        autotune_generation: int = 0,
                        tile_dependent: bool = True,
                        min_points: int = 3) -> bool:
        """Persist one tenancy's probed (bs, mtl) row under its
        architecture signature + device class.  A record for the same
        signature recorded under the same grid and generation accumulates
        (sample sums/counts merge element-wise); anything else is
        replaced.  `tile_dependent=False` marks rows whose latencies do
        not come from tuned kernels (simulated executors) — those are
        exempt from the generation staleness gate, so a re-tune does not
        wipe a warm-start library it cannot have invalidated.  Returns
        True when something was written."""
        row = lib.export_row(key)
        if row is None:
            return False
        sum_, cnt = row
        # the (1,1) normalizer lives at the largest share rung with data
        # ((bs=1, mtl=1) itself on the default single-rung grid)
        if int((cnt > 0).sum()) < min_points or not (cnt[0, 0] > 0).any():
            return False                 # too sparse / no (1,1) normalizer
        sk = self.surface_key(signature, device_class)
        rec = self.get("surfaces", sk)
        share_values = [float(s)
                        for s in getattr(lib, "share_values", (1.0,))]
        if (isinstance(rec, dict)
                and (not tile_dependent
                     or rec.get("autotune_generation")
                     == int(autotune_generation))
                and rec.get("bs_values") == list(lib.bs_values)
                and rec.get("mtl_values") == list(lib.mtl_values)
                and rec.get("share_values", [1.0]) == share_values):
            try:
                sum_ = sum_ + np.asarray(rec["sum"], np.float64)
                cnt = cnt + np.asarray(rec["cnt"], np.int64)
            except (KeyError, TypeError, ValueError):
                pass                     # malformed record: replace it
        self.put("surfaces", sk, {
            "signature": signature,
            "device_class": device_class,
            "bs_values": list(lib.bs_values),
            "mtl_values": list(lib.mtl_values),
            "share_values": share_values,
            "sum": np.asarray(sum_, np.float64).tolist(),
            "cnt": np.asarray(cnt, np.int64).tolist(),
            "points": int((np.asarray(cnt) > 0).sum()),
            "autotune_generation": int(autotune_generation),
            "tile_dependent": bool(tile_dependent),
        })
        return True

    def _surface_record_ok(self, rec, lib, autotune_generation: int) -> bool:
        if not isinstance(rec, dict):
            return False
        if (rec.get("tile_dependent", True)
                and rec.get("autotune_generation")
                != int(autotune_generation)):
            return False                 # stale: the resident tiles changed
            #                              under these measured latencies
            #                              (sim rows are tile-independent
            #                              and skip this gate)
        if (rec.get("bs_values") != list(lib.bs_values)
                or rec.get("mtl_values") != list(lib.mtl_values)
                or rec.get("share_values", [1.0])
                != [float(s) for s in getattr(lib, "share_values", (1.0,))]):
            return False
        try:
            sum_ = np.asarray(rec["sum"], np.float64)
            cnt = np.asarray(rec["cnt"], np.int64)
        except (KeyError, ValueError, TypeError):
            return False
        if sum_.shape != lib.shape or cnt.shape != lib.shape:
            return False
        if (cnt < 0).any() or not np.isfinite(sum_).all() or (sum_ < 0).any():
            return False
        if not (cnt[0, 0] > 0).any() or (sum_[cnt > 0] <= 0).any():
            return False                 # need the (1,1) normalizer
        return True

    def load_surfaces(self, lib, *, device_class: str,
                      autotune_generation: int = 0,
                      validate: bool = True) -> dict:
        """Load persisted rows for `device_class` into `lib` as historical
        tenancies keyed ("hist", signature, device_class).

        Two gates run at load time, and a failing record is EVICTED from
        the store (not merely skipped — a bad row would fail again on
        every future load):
          * staleness — recorded under a different autotune generation, or
            structurally invalid for the library grid;
          * leave-one-out — the completion machinery's own LOO validation
            (``SurfaceLibrary.predict``) re-run against the other loaded
            rows; a row it rejects carries no transferable shape."""
        loaded, evicted = [], []
        for sk, rec in list(self.section("surfaces").items()):
            if not isinstance(rec, dict) or \
                    rec.get("device_class") != device_class:
                continue
            if not self._surface_record_ok(rec, lib, autotune_generation):
                self.delete("surfaces", sk)
                self.evictions += 1
                evicted.append(sk)
                continue
            key = ("hist", rec["signature"], device_class)
            if lib.import_row(key, rec["sum"], rec["cnt"]):
                loaded.append((sk, key))
            else:
                self.delete("surfaces", sk)
                self.evictions += 1
                evicted.append(sk)
        if validate:
            for sk, key in list(loaded):
                # library tier only: a cost-model prior answering here
                # would mask the LOO verdict this eviction gate needs
                pred = lib.predict(key, allow_model=False)
                if pred is None and lib.last_reject == "loo":
                    lib.reset_row(key)
                    self.delete("surfaces", sk)
                    self.evictions += 1
                    evicted.append(sk)
                    loaded.remove((sk, key))
        if evicted:
            self.save()
        return {"loaded": [sk for sk, _ in loaded], "evicted": evicted}

    # -- partition interference: measured slice-proxy inflation ---------------
    def record_interference(self, key: str, share: float, wall_s: float,
                            inflated_s: float) -> None:
        """One real-executor partition-proxy measurement: the raw wall
        step and the slice-inflated step actually served, per
        (signature|device-class) key and share rung.  Ring-buffered like
        the migration samples."""
        if not (np.isfinite(wall_s) and np.isfinite(inflated_s)) \
                or wall_s <= 0 or inflated_s <= 0:
            return
        rung = f"{key}|share={share:.4f}"
        rec = self.get("interference", rung)
        samples = list(rec.get("samples", [])) if isinstance(rec, dict) else []
        samples.append([float(wall_s), float(inflated_s)])
        self.put("interference", rung,
                 {"samples": samples[-MAX_MIGRATION_SAMPLES:]})

    def interference_factor(self, key: str, share: float) -> Optional[float]:
        """Median measured inflation (inflated / wall) for one rung, or
        None without samples."""
        rec = self.get("interference", f"{key}|share={share:.4f}")
        if not isinstance(rec, dict):
            return None
        ratios = [i / w for w, i in rec.get("samples", [])
                  if isinstance(w, (int, float)) and w > 0
                  and isinstance(i, (int, float)) and i > 0]
        if not ratios:
            return None
        return float(np.median(np.asarray(ratios)))

    # -- migrations: measured kill+relaunch calibration -----------------------
    def record_migration(self, key: str, seconds: float) -> None:
        if not np.isfinite(seconds) or seconds < 0:
            return
        rec = self.get("migrations", key)
        samples = list(rec.get("samples", [])) if isinstance(rec, dict) else []
        samples.append(float(seconds))
        self.put("migrations", key,
                 {"samples": samples[-MAX_MIGRATION_SAMPLES:]})

    def migration_cost(self, key: str, *, q: float = MIGRATION_QUANTILE,
                       min_samples: int = MIN_MIGRATION_SAMPLES
                       ) -> Optional[float]:
        """Calibrated stall seconds for one migration of `key`, or None
        until `min_samples` measurements exist (callers fall back to the
        modeling defaults)."""
        rec = self.get("migrations", key)
        if not isinstance(rec, dict):
            return None
        samples = [float(s) for s in rec.get("samples", [])
                   if isinstance(s, (int, float)) and np.isfinite(s)
                   and s >= 0]
        if len(samples) < min_samples:
            return None
        return float(np.quantile(np.asarray(samples), q))
