"""Roofline analysis from compiled XLA artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on the target
TPU v5e.  The compiled module after SPMD partitioning is the *per-chip*
program, so all quantities below are per chip:

    t_compute    = flops_per_chip      / PEAK_FLOPS
    t_memory     = hbm_bytes_per_chip  / HBM_BW
    t_collective = link_bytes_per_chip / ICI_BW

FLOPs / bytes / collective-bytes come from ``repro.perf.hlo_analysis`` — a
static analysis of ``compiled.as_text()`` that multiplies ``lax.scan`` while
bodies by their trip counts (XLA's own ``cost_analysis()`` visits each
instruction once and under-reports scanned layers; we record it alongside for
reference).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.perf.hlo_analysis import analyze_hlo

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link


@dataclasses.dataclass
class Roofline:
    flops: float               # per-chip FLOPs per step (scan-adjusted)
    hbm_bytes: float           # per-chip HBM traffic per step
    coll_bytes: float          # per-chip collective link bytes per step
    chips: int
    model_flops: float = 0.0   # analytic useful FLOPs (global)
    coll_detail: Optional[dict] = None
    xla_cost: Optional[dict] = None
    memory_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """(model_flops/chips) / hlo_flops_per_chip — how much of the compiled
        compute is useful; <1 means remat/replication/dispatch waste."""
        if not self.flops:
            return 0.0
        return (self.model_flops / self.chips) / self.flops

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops, "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes, "chips": self.chips,
            "model_flops_global": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "memory_per_chip": self.memory_per_chip,
            "coll_detail": self.coll_detail,
            "xla_cost": self.xla_cost,
        }


def bound_time_features(flops: float, hbm_bytes: float,
                        coll_bytes: float = 0.0, *,
                        peak_flops: float = PEAK_FLOPS,
                        hbm_bw: float = HBM_BW,
                        ici_bw: float = ICI_BW) -> dict:
    """Roofline-derived scalars for the learned cost model
    (``perf/cost_model.py``): the three bound times on the given device,
    which of them binds, and the arithmetic intensity.  Accepts explicit
    device rates so the same op counts can be priced per device class."""
    t_comp = flops / peak_flops
    t_mem = hbm_bytes / hbm_bw
    t_coll = coll_bytes / ici_bw
    return {
        "t_compute": t_comp,
        "t_memory": t_mem,
        "t_collective": t_coll,
        "bound_time": max(t_comp, t_mem, t_coll),
        # FLOP/byte; degenerate inputs fall back to balanced intensity
        "intensity": (flops / hbm_bytes) if hbm_bytes > 0
        else (peak_flops / hbm_bw),
    }


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs per step: 6*N*D train, 2*N*D inference
    (N = active params, D = tokens processed)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens


def analyze(compiled, cfg, shape, chips: int,
            hlo_text: Optional[str] = None) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    h = analyze_hlo(text)

    cost = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, list):
            c = c[0]
        cost = {"flops": float(c.get("flops", 0.0)),
                "bytes_accessed": float(c.get("bytes accessed", 0.0))}
    except Exception:
        pass

    mem = compiled.memory_analysis()
    per_chip = 0.0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes"):
        per_chip += getattr(mem, attr, 0) or 0
    per_chip -= getattr(mem, "alias_size_in_bytes", 0) or 0

    return Roofline(
        flops=h["flops"], hbm_bytes=h["hbm_bytes"],
        coll_bytes=h["total_coll_bytes"], chips=chips,
        model_flops=model_flops(cfg, shape),
        coll_detail={"bytes": h["coll_bytes"], "count": h["coll_count"]},
        xla_cost=cost, memory_per_chip=per_chip)


def save_json(path: str, record: dict) -> None:
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
