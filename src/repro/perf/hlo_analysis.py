"""Static analysis of optimized HLO text: per-chip FLOPs, HBM traffic and
collective link-bytes — *with while-loop (lax.scan) trip-count multipliers*.

``compiled.cost_analysis()`` visits each instruction once, so an 80-layer
model lowered as ``lax.scan`` under-reports by 80x.  This module parses the
module text, builds the computation call graph (while trip counts come from
the ``backend_config known_trip_count`` attached by XLA, falling back to the
largest comparison constant in the loop condition), and sums:

  * flops: 2 * prod(output dims) * prod(lhs contracting dims) per ``dot``
    (fusion internals included; convolutions unused in this codebase)
  * hbm bytes: result + operand bytes of top-level instructions, operands
    resolved through a per-computation symbol table (fusion internals are
    skipped — they live in registers/VMEM).  This matches XLA's
    "bytes accessed" convention (producer+consumer both count).
  * collective link-bytes per chip, with ring-algorithm factors:
      all-gather: 1 x result (result is the gathered full shape)
      all-reduce: 2 x result (reduce + broadcast phases)
      reduce-scatter: 1 x operand (full input crosses links)
      all-to-all / collective-permute: 1 x result
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "u1": 1, "s1": 1,
}

# dims may be dynamic in unoptimized/bounded-dynamic modules: "<=8" is a
# bounded dynamic dim, "?" fully dynamic — both degrade conservatively in
# `_dim_count` (bound / 1) with a warning instead of silently unmatching
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*[a-z0-9]*)\[([\d,<=? ]*)\]")
# computation headers: optimized text prints "%name (args) -> ... {",
# freshly LOWERED (unoptimized) text prints a bare "name {" with the
# parameters as explicit parameter(i) instructions — accept both
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*[({]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?"n"\s*:\s*"(\d+)"')

# parser-degradation notes for the current analyze_hlo() call (deduped);
# the cost model reads these to know when byte counts are estimates
_WARNINGS: set = set()


def _warn(msg: str) -> None:
    _WARNINGS.add(msg)


def _dim_count(d: str) -> int:
    """Element count of one dim literal, degrading conservatively:
    '<=N' (bounded dynamic) counts the bound, '?' (unbounded dynamic)
    counts 1, junk counts 1 — each with a warning."""
    d = d.strip()
    if not d:
        return 1
    if d.startswith("<="):
        _warn(f"dynamic dim '{d}': counted at its bound")
        d = d[2:].strip()
    elif d == "?":
        _warn("unbounded dynamic dim '?': counted as 1")
        return 1
    try:
        n = int(d)
    except ValueError:
        _warn(f"unparseable dim {d!r}: counted as 1")
        return 1
    if n == 0:
        _warn("degenerate 0-element shape")
    return n


def _split_result_opcode(rhs: str) -> tuple[str, str]:
    """Split 'f32[2,3]{1,0} dot(%a, %b), attrs' -> ('f32[2,3]{1,0} ', 'dot').

    Tuple results '(s32[], f32[2])' are handled by skipping the balanced
    leading paren group before locating the opcode token."""
    i = 0
    if rhs.startswith("("):
        depth = 0
        for j, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    i = j + 1
                    break
    p = rhs.find("(", i)
    if p < 0:
        return rhs, ""
    head = rhs[:p]
    tokens = head[i:].split()
    opcode = tokens[-1] if tokens else ""
    result_head = rhs[:i] + " ".join(tokens[:-1])
    if not re.fullmatch(r"[a-z][a-z0-9\-]*", opcode or ""):
        return rhs, ""
    return result_head, opcode

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "get-dimension-size",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# op-class buckets for the learned cost model's feature histogram
# (perf/cost_model.py): architecture "fingerprints" that predict the
# host-overhead / amortization calibration better than raw FLOP counts —
# a cell-based NAS net is thousands of tiny reshuffle-heavy ops, an RNN
# is a while loop, a transformer is dot-dominated
OP_CLASSES = ("conv", "depthwise", "dense", "rnn", "elementwise",
              "reshuffle")

_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "logistic", "rsqrt", "sqrt", "negate",
    "abs", "sign", "floor", "ceil", "compare", "select", "clamp", "convert",
    "reduce", "reduce-window", "map", "exponential-minus-one", "and", "or",
    "not", "xor",
}
_RESHUFFLE_OPS = {
    "reshape", "transpose", "broadcast", "concatenate", "slice",
    "dynamic-slice", "dynamic-update-slice", "pad", "gather", "scatter",
    "copy", "reverse", "iota", "sort",
}
_UNCLASSED_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "get-dimension-size",
    "custom-call", "fusion", "call", "conditional",
}


def _op_class(ins: "Instr"):
    """OP_CLASSES bucket for one instruction, or None for structural ops."""
    op = ins.opcode
    if op == "convolution":
        m = re.search(r"feature_group_count=(\d+)", ins.rhs)
        return "depthwise" if m and int(m.group(1)) > 1 else "conv"
    if op == "dot":
        return "dense"
    if op == "while":
        return "rnn"
    if op in _ELEMENTWISE_OPS:
        return "elementwise"
    if op in _RESHUFFLE_OPS:
        return "reshuffle"
    if not op or op in _UNCLASSED_OPS or op.endswith("-start") \
            or op.endswith("-done") or any(op.startswith(c)
                                           for c in _COLLECTIVES):
        return None
    return "elementwise"        # unrecognized compute op: least-wrong bucket


_F32_AS_BF16 = False  # set by analyze_hlo; see its docstring


def _shape_bytes_str(s: str) -> int:
    """Sum bytes of every shape literal appearing in s (tuple-shaped
    results contribute every element shape).  Unknown dtypes are charged
    conservatively at 4 bytes with a warning — silently skipping them
    under-counted HBM traffic for any dtype outside `_DTYPE_BYTES`."""
    total = 0
    matched = False
    for dtype, dims in _SHAPE_RE.findall(s):
        matched = True
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            _warn(f"unknown dtype {dtype!r}: assumed 4 bytes")
            b = 4
        if _F32_AS_BF16 and dtype == "f32":
            b = 2
        n = 1
        if dims:
            for d in dims.split(","):
                n *= _dim_count(d)
        total += n * b
    if not matched and "[" in s:
        _warn(f"unparsed shape text {s.strip()[:40]!r}: counted as 0 bytes")
    return total


def _operand_names(region: str) -> list:
    """Operand instruction names inside an operand region.  Optimized
    text prefixes every name with % ('f32[2]{0} %add.1'); freshly
    lowered text prints bare names ('add.1, Arg_0.2') — use the %-form
    when present, else the last token of each top-level comma fragment
    (the name always trails any inline shape)."""
    if "%" in region:
        return re.findall(r"%([\w.\-]+)", region)
    names, frag, depth = [], [], 0
    for ch in region + ",":
        if ch == "," and depth == 0:
            tok = "".join(frag).strip().split()
            if tok and re.fullmatch(r"[\w.\-]+", tok[-1]):
                names.append(tok[-1])
            frag = []
            continue
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        frag.append(ch)
    return names


def _operand_region(rhs: str) -> str:
    """Text inside the instruction's operand parens (handles nesting)."""
    i = rhs.find("(")
    if i < 0:
        return ""
    depth = 0
    for j in range(i, len(rhs)):
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
            if depth == 0:
                return rhs[i + 1:j]
    return rhs[i + 1:]


@dataclasses.dataclass
class Instr:
    name: str
    rhs: str
    opcode: str
    result_head: str           # text before the opcode (shapes of result)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list
    symtab: dict               # name -> shape string (results + params)


def parse_module(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _HEADER_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2), bool(m.group(1)), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                # header params: "name: f32[2,3]" pairs
                for pm in re.finditer(
                        r"([\w.\-]+):\s*([a-z0-9]+\[[\d,<=? ]*\])", line):
                    cur.symtab[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        head, opcode = _split_result_opcode(rhs)
        cur.symtab[name] = head
        cur.instrs.append(Instr(name, rhs, opcode, head))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _dot_flops(ins: Instr, symtab: dict) -> float:
    if ins.opcode != "dot":
        return 0.0
    m = _SHAPE_RE.search(ins.result_head)
    if not m:
        return 0.0
    out_elems = 1
    if m.group(2):
        for d in m.group(2).split(","):
            out_elems *= _dim_count(d)
    ops = _operand_names(_operand_region(ins.rhs))
    cd_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    if not ops or not cd_m:
        return 0.0
    lhs_shape = symtab.get(ops[0], "")
    lm = _SHAPE_RE.search(lhs_shape)
    if not lm:
        return 0.0
    lhs_dims = [_dim_count(x)
                for x in lm.group(2).split(",")] if lm.group(2) else []
    contract = 1
    for idx in (cd_m.group(1).split(",") if cd_m.group(1) else []):
        i = int(idx)
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _operand_bytes(ins: Instr, symtab: dict) -> int:
    region = _operand_region(ins.rhs)
    total = 0
    for name in _operand_names(region):
        total += _shape_bytes_str(symtab.get(name, ""))
    # inline-shaped operands (rare in optimized text)
    if not total:
        total = _shape_bytes_str(region)
    return total


def _while_trip(ins: Instr, comps: dict) -> int:
    m = _TRIP_RE.search(ins.rhs)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
    if cm and cm.group(1) in comps:
        best = 1
        for ci in comps[cm.group(1)].instrs:
            for k in re.finditer(r"constant\((\d+)\)", ci.rhs):
                best = max(best, int(k.group(1)))
        return best
    return 1


def _fusion_bytes(ins: Instr, caller_symtab: dict, callee: Computation) -> int:
    """Effective HBM bytes of a fusion call.

    A fusion reads each parameter either wholly, or — when every internal
    consumer is a (dynamic-)slice/gather — only the sliced region; a fusion
    whose root is a dynamic-update-slice writes (and reads) only the update
    region of the aliased buffer.  ``convert`` ops are traced through
    transparently (XLA-CPU bf16 legalization).  This mirrors XLA's
    HloCostAnalysis treatment and stops full KV caches being charged per
    scanned layer."""
    param_names: dict[int, str] = {}
    by_name: dict[str, Instr] = {}
    consumers: dict[str, list] = defaultdict(list)
    root: Optional[Instr] = None
    for ci in callee.instrs:
        by_name[ci.name] = ci
        if ci.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", ci.rhs)
            if m:
                param_names[int(m.group(1))] = ci.name
        for opn in _operand_names(_operand_region(ci.rhs)):
            consumers[opn].append(ci)
        root = ci  # last instr is ROOT in printed HLO
    call_ops = _operand_names(_operand_region(ins.rhs))

    def trace_operand(name: str) -> str:
        """Follow converts/copies/bitcasts back to their source name."""
        seen = 0
        while name in by_name and by_name[name].opcode in (
                "convert", "copy", "bitcast") and seen < 20:
            ops_ = _operand_names(_operand_region(by_name[name].rhs))
            if not ops_:
                break
            name = ops_[0]
            seen += 1
        return name

    def effective_consumers(name: str, depth: int = 0) -> list:
        out = []
        for c in consumers.get(name, []):
            if c.opcode in ("convert", "copy", "bitcast") and depth < 20:
                out.extend(effective_consumers(c.name, depth + 1))
            else:
                out.append(c)
        return out

    # trace root through trailing converts
    eff_root = root
    while (eff_root is not None and eff_root.opcode in ("convert", "copy",
                                                        "bitcast")):
        ops_ = _operand_names(_operand_region(eff_root.rhs))
        if not ops_ or ops_[0] not in by_name:
            break
        eff_root = by_name[ops_[0]]

    total = 0
    dus_buffer_param: Optional[str] = None
    if eff_root is not None and eff_root.opcode == "dynamic-update-slice":
        r_ops = _operand_names(_operand_region(eff_root.rhs))
        if r_ops:
            dus_buffer_param = trace_operand(r_ops[0])
        upd = callee.symtab.get(r_ops[1], "") if len(r_ops) > 1 else ""
        total += 2 * _shape_bytes_str(upd)      # read+write update region
    else:
        total += _shape_bytes_str(ins.result_head)

    for ordinal, pname in param_names.items():
        if pname == dus_buffer_param:
            continue                             # aliased in-place buffer
        cons = effective_consumers(pname)
        if cons and all(c.opcode in ("dynamic-slice", "slice", "gather")
                        for c in cons):
            total += sum(_shape_bytes_str(c.result_head) for c in cons)
        else:
            if ordinal < len(call_ops):
                total += _shape_bytes_str(
                    caller_symtab.get(call_ops[ordinal], ""))
    return total


def _is_pure_convert(comp: Computation) -> bool:
    """True for XLA-CPU bf16-legalization fusions (a lone convert)."""
    real = [i for i in comp.instrs if i.opcode not in ("parameter",)]
    return len(real) == 1 and real[0].opcode == "convert"


def analyze_hlo(text: str, f32_as_bf16: bool = True) -> dict:
    """Analyze optimized HLO text.

    f32_as_bf16: the XLA *CPU* backend legalizes every bf16 op to f32,
    inserting whole-tensor converts that would not exist on TPU.  With this
    flag (default) pure-convert instructions are skipped and f32 shapes are
    charged at 2 bytes, recovering TPU-like traffic.  Caveat: genuinely-f32
    tensors (optimizer states, softmax accumulators) are then undercounted
    2x — noted where it matters in EXPERIMENTS.md.
    """
    global _F32_AS_BF16
    _F32_AS_BF16 = f32_as_bf16
    _WARNINGS.clear()
    comps, entry = parse_module(text)

    multipliers: dict[str, float] = defaultdict(float)
    fusion_callees: set[str] = set()
    seen_stack: set[str] = set()

    def visit(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in seen_stack:
            return
        seen_stack.add(name)
        multipliers[name] += mult
        for ins in comp.instrs:
            if ins.opcode == "while":
                trip = _while_trip(ins, comps)
                bm = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                if bm:
                    visit(bm.group(1), mult * trip)
                if cm:
                    visit(cm.group(1), mult * (trip + 1))
            elif ins.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                if m:
                    fusion_callees.add(m.group(1))
                    visit(m.group(1), mult)
            elif ins.opcode == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                if m:
                    for c in m.group(1).split(","):
                        visit(c.strip().lstrip("%"), mult)
            else:
                for attr in ("to_apply", "calls"):
                    m = re.search(rf"{attr}=%?([\w.\-]+)", ins.rhs)
                    if m:
                        visit(m.group(1), mult)
        seen_stack.discard(name)

    if entry:
        visit(entry, 1.0)

    flops = 0.0
    hbm = 0.0
    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_count = {k: 0 for k in _COLLECTIVES}
    op_counts = {k: 0.0 for k in OP_CLASSES}

    for name, comp in comps.items():
        mult = multipliers.get(name, 0.0)
        if mult == 0.0:
            continue
        in_fusion = name in fusion_callees
        for ins in comp.instrs:
            op = ins.opcode
            cls = _op_class(ins)
            if cls is not None:
                op_counts[cls] += mult
            flops += mult * _dot_flops(ins, comp.symtab)
            if f32_as_bf16 and op == "convert":
                continue
            if f32_as_bf16 and op == "fusion":
                m_ = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                if m_ and m_.group(1) in comps and _is_pure_convert(
                        comps[m_.group(1)]):
                    continue
            if not in_fusion and op and op not in _SKIP_BYTES_OPS:
                if op in ("dynamic-slice", "slice", "gather"):
                    # reads only the slice, writes the slice
                    nb = 2 * _shape_bytes_str(ins.result_head)
                elif op in ("dynamic-update-slice", "scatter"):
                    # in-place: reads + writes the update region only
                    ops_ = _operand_names(_operand_region(ins.rhs))
                    upd = comp.symtab.get(ops_[1], "") if len(ops_) > 1 else ""
                    nb = 2 * _shape_bytes_str(upd)
                elif op == "fusion":
                    m_ = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                    callee = comps.get(m_.group(1)) if m_ else None
                    if callee is not None:
                        nb = _fusion_bytes(ins, comp.symtab, callee)
                    else:
                        nb = (_shape_bytes_str(ins.result_head) +
                              _operand_bytes(ins, comp.symtab))
                else:
                    nb = (_shape_bytes_str(ins.result_head) +
                          _operand_bytes(ins, comp.symtab))
                hbm += mult * nb
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                if base == "reduce-scatter":
                    nb = _operand_bytes(ins, comp.symtab)
                elif base == "all-reduce":
                    nb = 2 * _shape_bytes_str(ins.result_head)
                else:
                    nb = _shape_bytes_str(ins.result_head)
                coll_bytes[base] += mult * nb
                coll_count[base] += int(mult)

    n_ops = sum(op_counts.values())
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "coll_bytes": coll_bytes,
        "coll_count": coll_count,
        "total_coll_bytes": sum(coll_bytes.values()),
        "n_computations": len(comps),
        # trip-count-weighted op-class mix (cost-model features)
        "n_ops": n_ops,
        "op_hist": {k: (v / n_ops if n_ops else 0.0)
                    for k, v in op_counts.items()},
        # parser degradations hit during this analysis (unknown dtypes,
        # dynamic/degenerate dims, unparseable shapes) — byte counts are
        # conservative ESTIMATES whenever this is non-empty
        "warnings": sorted(_WARNINGS),
    }


def top_contributors(text: str, k: int = 15, metric: str = "hbm",
                     f32_as_bf16: bool = True) -> list:
    """Debug helper: the k instructions contributing most (metric x trip
    multiplier) — 'hbm' | 'flops' | 'coll'."""
    global _F32_AS_BF16
    _F32_AS_BF16 = f32_as_bf16
    comps, entry = parse_module(text)
    multipliers: dict[str, float] = defaultdict(float)
    fusion_callees: set[str] = set()
    stack: set[str] = set()

    def visit(name, m):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack.add(name)
        multipliers[name] += m
        for ins in comp.instrs:
            if ins.opcode == "while":
                trip = _while_trip(ins, comps)
                bm = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                if bm:
                    visit(bm.group(1), m * trip)
                if cm:
                    visit(cm.group(1), m * (trip + 1))
            elif ins.opcode == "fusion":
                mm = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                if mm:
                    fusion_callees.add(mm.group(1))
                    visit(mm.group(1), m)
            else:
                for attr in ("to_apply", "calls"):
                    mm = re.search(rf"{attr}=%?([\w.\-]+)", ins.rhs)
                    if mm:
                        visit(mm.group(1), m)
        stack.discard(name)

    visit(entry, 1.0)
    rows = []
    for name, comp in comps.items():
        mult = multipliers.get(name, 0.0)
        if not mult:
            continue
        in_fusion = name in fusion_callees
        for ins in comp.instrs:
            op = ins.opcode
            val = 0.0
            if metric == "flops":
                val = _dot_flops(ins, comp.symtab)
            elif metric == "coll":
                base = op[:-6] if op.endswith("-start") else op
                if base in _COLLECTIVES:
                    val = _shape_bytes_str(ins.result_head)
            else:
                if in_fusion or not op or op in _SKIP_BYTES_OPS:
                    continue
                if f32_as_bf16 and op == "convert":
                    continue
                if op in ("dynamic-slice", "slice", "gather"):
                    val = 2 * _shape_bytes_str(ins.result_head)
                elif op in ("dynamic-update-slice", "scatter"):
                    ops_ = _operand_names(_operand_region(ins.rhs))
                    upd = comp.symtab.get(ops_[1], "") if len(ops_) > 1 else ""
                    val = 2 * _shape_bytes_str(upd)
                elif op == "fusion":
                    m_ = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                    callee = comps.get(m_.group(1)) if m_ else None
                    if f32_as_bf16 and callee is not None and \
                            _is_pure_convert(callee):
                        continue
                    val = (_fusion_bytes(ins, comp.symtab, callee)
                           if callee else 0)
                else:
                    val = (_shape_bytes_str(ins.result_head) +
                           _operand_bytes(ins, comp.symtab))
            if val:
                rows.append((val * mult, mult, f"{name}/{ins.name}",
                             ins.rhs[:160]))
    rows.sort(reverse=True)
    return rows[:k]


# ---------------------------------------------------------------------------
# Live-module OPSIG: features from the served module's OWN HLO
# ---------------------------------------------------------------------------
def hlo_for_module(model_fn, arg_specs) -> "Optional[str]":
    """Lower `model_fn` at the given ShapeDtypeStruct specs (abstract —
    no parameters are ever materialized) and return the module's HLO
    text, or None on ANY lowering failure.  The unoptimized dialect is
    enough: the parser above accepts its bare computation headers, and
    op-class fractions barely move under fusion."""
    try:
        import jax
        lowered = jax.jit(model_fn).lower(*arg_specs)
        return lowered.compiler_ir("hlo").as_hlo_text()
    except Exception:  # noqa: BLE001 — lowering failure = no live OPSIG
        return None


def features_for_module(model_fn, arg_specs, *, param_bytes: float,
                        input_bytes: float = 600e3):
    """``ModelFeatures`` built from the served module's own HLO — the
    live replacement for the static OPSIG table: lower the module, run
    ``analyze_hlo`` over the text, keep the op-class histogram /
    trip-weighted op count / FLOPs the module actually contains.

    Returns None when lowering fails or the parse yields nothing usable;
    the caller (``cost_model.features_for_signature``) then falls back
    to the static table — live first, static as the safety net."""
    text = hlo_for_module(model_fn, arg_specs)
    if text is None:
        return None
    from repro.perf import cost_model  # deferred: cost_model imports us
    feat = cost_model.features_from_hlo(text, param_bytes=param_bytes,
                                        input_bytes=input_bytes)
    if feat.n_ops <= 1.0 or feat.flops <= 0.0:
        return None
    return feat
