"""Minimal pure-JAX AdamW (decoupled weight decay), optimizer-state pytree
mirrors the param tree so FSDP shardings apply leaf-for-leaf."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def update(grads, state: AdamWState, params, *, lr: float = 3e-4,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    step = state.step + 1

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        dp = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            dp = dp + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * dp
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
