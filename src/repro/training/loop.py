"""Host-mesh training loop (runs for real on this machine's devices)."""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import api
from repro.training import adamw, checkpoint
from repro.training.data import DataConfig, TokenStream


def train(cfg: ModelConfig, *, steps: int = 100, batch_size: int = 8,
          seq_len: int = 256, lr: float = 3e-4, seed: int = 0,
          log_every: int = 10, ckpt_path: Optional[str] = None,
          ckpt_every: int = 0, data_path: Optional[str] = None,
          remat: bool = False) -> dict:
    """Single-host training; returns the loss trace."""
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(rng, cfg)
    opt = adamw.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))

    stream = iter(TokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, batch_size=batch_size,
        seed=seed, path=data_path)))

    @jax.jit
    def step_fn(params, opt, tokens):
        def loss_fn(p):
            loss, metrics = api.train_loss(p, {"tokens": tokens}, cfg,
                                           remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, gnorm = adamw.update(grads, opt, params, lr=lr)
        return params, opt, loss, gnorm

    losses, times = [], []
    t_start = time.perf_counter()
    for i in range(steps):
        tokens = jnp.asarray(next(stream))
        t0 = time.perf_counter()
        params, opt, loss, gnorm = step_fn(params, opt, tokens)
        loss = float(loss)
        times.append(time.perf_counter() - t0)
        losses.append(loss)
        if log_every and (i % log_every == 0 or i == steps - 1):
            tok_s = batch_size * seq_len / np.mean(times[-log_every:])
            print(f"step {i:>5d}  loss {loss:7.4f}  gnorm {float(gnorm):6.2f} "
                  f" tok/s {tok_s:9.0f}")
        if ckpt_path and ckpt_every and (i + 1) % ckpt_every == 0:
            checkpoint.save(ckpt_path, i + 1, params, opt)
    wall = time.perf_counter() - t_start
    if ckpt_path:
        checkpoint.save(ckpt_path, steps, params, opt)
    return {"losses": losses, "wall_s": wall, "n_params": n_params,
            "final_loss": losses[-1], "params": params}
