"""Token data pipeline: deterministic synthetic corpus + optional text files.

The synthetic corpus is a mixture of Zipf-distributed unigrams with Markov
bigram structure, so small models show a real, monotonically-decreasing loss
(pure-uniform tokens would bottom out at ln(V) immediately).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    path: Optional[str] = None    # optional utf-8 text file (byte-level)


class TokenStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        if cfg.path:
            raw = open(cfg.path, "rb").read()
            self._corpus = np.frombuffer(raw, np.uint8).astype(np.int32)
            self._corpus = self._corpus % cfg.vocab_size
        else:
            self._corpus = None
            # Markov chain over a Zipfian vocabulary
            v = cfg.vocab_size
            self._zipf = (1.0 / np.arange(1, v + 1)) ** 1.1
            self._zipf /= self._zipf.sum()
            # each token deterministically prefers a few successors
            self._succ = self.rng.integers(0, v, size=(v, 4))

    def _synthetic_batch(self) -> np.ndarray:
        b, t, v = self.cfg.batch_size, self.cfg.seq_len, self.cfg.vocab_size
        out = np.empty((b, t), np.int32)
        cur = self.rng.choice(v, size=b, p=self._zipf)
        out[:, 0] = cur
        for i in range(1, t):
            # 70%: follow the Markov successor table; 30%: resample Zipf
            follow = self.rng.random(b) < 0.7
            pick = self._succ[cur, self.rng.integers(0, 4, size=b)]
            fresh = self.rng.choice(v, size=b, p=self._zipf)
            cur = np.where(follow, pick, fresh).astype(np.int32)
            out[:, i] = cur
        return out

    def _file_batch(self) -> np.ndarray:
        b, t = self.cfg.batch_size, self.cfg.seq_len
        n = len(self._corpus) - t - 1
        starts = self.rng.integers(0, n, size=b)
        return np.stack([self._corpus[s:s + t] for s in starts])

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield (self._file_batch() if self._corpus is not None
                   else self._synthetic_batch())
