"""Flat-npz checkpointing for param/optimizer pytrees (no external deps)."""

from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _to_savable(arr: np.ndarray) -> np.ndarray:
    # npz can't store bfloat16 (numpy sees a void dtype) — upcast losslessly
    if arr.dtype.name == "bfloat16":
        return arr.astype(np.float32)
    return arr


def save(path: str, step: int, params: Any, opt_state: Any = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params/{k}": _to_savable(v) for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": _to_savable(v)
                        for k, v in _flatten(opt_state).items()})
    payload["__step__"] = np.asarray(step)
    np.savez(path, **payload)


def load(path: str, params_template: Any, opt_template: Any = None):
    """Restores into the structure (and dtypes) of the given templates."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    step = int(data["__step__"])

    def restore(tree, prefix):
        flat_named = list(_flatten(tree).keys())
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        assert len(flat_named) == len(leaves)
        new = [jax.numpy.asarray(data[f"{prefix}/{k}"]).astype(leaf.dtype)
               for k, leaf in zip(flat_named, leaves)]
        return jax.tree_util.tree_unflatten(treedef, new)

    params = restore(params_template, "params")
    opt = restore(opt_template, "opt") if opt_template is not None else None
    return step, params, opt
